"""Mesh-sharded vector store: the cache's distributed data path.

The DB matrix [n_shards * cap, D] is sharded over the mesh `data` axis (and,
multi-pod, over `pod` — each pod's shard acts as its L1, cross-pod merge is
the L2 exchange; DESIGN.md §3). Lookup runs under shard_map:

    per shard: MXU dot [Q, cap_local] -> local top-k
    all_gather of the tiny [Q, k] candidate sets over (pod, data)
    global top-k merge (still inside the jit)

Only k candidates per shard cross the interconnect — never the [Q, N]
score matrix. This is the step the dry-run lowers on the production mesh
(`cache_lookup` rows in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.vector_store import pad_to_bucket, prepare_scatter
from repro.distributed.sharding import resolve_spec


def _shard_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_sharded_lookup(mesh, *, k: int, metric: str = "cosine", hierarchical: bool = True):
    """Builds the jitted sharded lookup: (db, valid, q) -> (scores, global idx).

    db: [N, D] sharded P(("pod","data"), None); valid: [N] likewise;
    q: [Q, D] replicated.
    """
    axes = _shard_axes(mesh)
    if not axes:
        from repro.core.similarity import top_k_scores

        return jax.jit(lambda db, valid, q: top_k_scores(db, valid, q, k, metric))

    axis_tuple = axes if len(axes) > 1 else axes[0]

    def local_lookup(db_l, valid_l, q):
        # db_l: [cap_local, D] local shard
        cap_local = db_l.shape[0]
        dbn = db_l
        qn = q
        if metric == "cosine":
            dbn = db_l / jnp.maximum(jnp.linalg.norm(db_l, axis=-1, keepdims=True), 1e-9)
            qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
        s = qn @ dbn.T  # [Q, cap_local]
        s = jnp.where(valid_l[None, :], s, -jnp.inf)
        k_eff = min(k, cap_local)
        top_s, top_i = jax.lax.top_k(s, k_eff)  # local indices
        # translate to global ids
        shard_id = jnp.zeros((), jnp.int32)
        mul = 1
        for a in reversed(axes):
            shard_id = shard_id + jax.lax.axis_index(a) * mul
            mul = mul * mesh.shape[a]  # static axis size (jax.lax.axis_size needs jax>=0.5)
        top_i = top_i + shard_id * cap_local
        if hierarchical:
            # hierarchical candidate exchange: gather k per shard over the
            # in-pod (ICI) axis first, merge back down to k, THEN cross the
            # pod (DCN) axis with only Q*k candidates instead of
            # n_data_shards*Q*k — the paper's L1 (pod-local) / L2 (cross-pod)
            # hierarchy expressed as a collective schedule (§Perf).
            gs, gi = top_s, top_i
            for a in reversed(axes):  # innermost (ICI) first, DCN last
                all_s = jax.lax.all_gather(gs, a, axis=0, tiled=False)
                all_i = jax.lax.all_gather(gi, a, axis=0, tiled=False)
                flat_s = jnp.moveaxis(all_s, 0, 1).reshape(q.shape[0], -1)
                flat_i = jnp.moveaxis(all_i, 0, 1).reshape(q.shape[0], -1)
                k_eff2 = min(k, flat_s.shape[1])
                gs, pos = jax.lax.top_k(flat_s, k_eff2)
                gi = jnp.take_along_axis(flat_i, pos, axis=1)
            return gs, gi
        # flat baseline: gather every shard's candidates everywhere, one merge
        all_s, all_i = top_s, top_i
        for a in axes:
            all_s = jax.lax.all_gather(all_s, a, axis=0, tiled=False)
            all_i = jax.lax.all_gather(all_i, a, axis=0, tiled=False)
        all_s = all_s.reshape(-1, *top_s.shape[-2:])
        all_i = all_i.reshape(-1, *top_i.shape[-2:])
        flat_s = jnp.moveaxis(all_s, 0, 1).reshape(q.shape[0], -1)
        flat_i = jnp.moveaxis(all_i, 0, 1).reshape(q.shape[0], -1)
        gs, pos = jax.lax.top_k(flat_s, k)
        gi = jnp.take_along_axis(flat_i, pos, axis=1)
        return gs, gi

    db_spec = P(axis_tuple, None)
    valid_spec = P(axis_tuple)
    fn = shard_map(
        local_lookup,
        mesh=mesh,
        in_specs=(db_spec, valid_spec, P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


class ShardedVectorStore:
    """Host-facing wrapper: functional adds into a mesh-sharded DB buffer."""

    def __init__(self, mesh, dim: int, capacity: int, *, k: int = 4, metric: str = "cosine"):
        self.mesh = mesh
        self.dim = dim
        axes = _shard_axes(mesh)
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        self.capacity = capacity - (capacity % max(n_shards, 1)) or n_shards
        self.n_shards = n_shards
        self.metric = metric
        self.k = k
        spec = P(axes if len(axes) > 1 else (axes[0] if axes else None), None)
        self._db_sharding = jax.NamedSharding(mesh, spec)
        self._valid_sharding = jax.NamedSharding(mesh, P(spec[0]))
        self._db = jax.device_put(jnp.zeros((self.capacity, dim), jnp.float32), self._db_sharding)
        self._valid = jax.device_put(jnp.zeros((self.capacity,), bool), self._valid_sharding)
        self._lookup = make_sharded_lookup(mesh, k=k, metric=metric)
        self._add = jax.jit(
            lambda db, valid, vec, idx: (db.at[idx].set(vec), valid.at[idx].set(True)),
            donate_argnums=(0, 1),
            out_shardings=(self._db_sharding, self._valid_sharding),
        )
        self._add_many = jax.jit(
            lambda db, valid, rows, idxs: (db.at[idxs].set(rows), valid.at[idxs].set(True)),
            donate_argnums=(0, 1),
            out_shardings=(self._db_sharding, self._valid_sharding),
        )
        self._invalidate = jax.jit(
            lambda valid, idx: valid.at[idx].set(False),
            donate_argnums=(0,),
            out_shardings=self._valid_sharding,
        )
        self.size = 0
        self.payloads: List[Optional[tuple]] = [None] * self.capacity
        self._rr = 0  # round-robin shard cursor for balanced placement
        # key -> slot map + freed-slot reuse (ported from InMemoryVectorStore)
        # so sharded caches can evict: remove() frees the slot, the next add
        # reclaims it before the round-robin cursor advances
        self._next_key = 0
        self._key_to_slot: Dict[int, int] = {}
        self._slot_key: List[Optional[int]] = [None] * self.capacity
        self._free: List[int] = []

    def _next_index(self) -> int:
        if self._free:
            return self._free.pop()
        cap_local = self.capacity // self.n_shards
        shard = self._rr % self.n_shards
        within = (self._rr // self.n_shards) % cap_local
        self._rr += 1
        return shard * cap_local + within

    def _claim_slot(self, idx: int, query: str, response: str) -> int:
        """Host-side bookkeeping for one placement (shared by add/add_batch)."""
        old = self._slot_key[idx]
        if old is not None:  # round-robin wrap overwrote a live entry
            self._key_to_slot.pop(old, None)
        else:
            self.size += 1
        key = self._next_key
        self._next_key += 1
        self.payloads[idx] = (query, response)
        self._slot_key[idx] = key
        self._key_to_slot[key] = idx
        return key

    def add(self, vec: np.ndarray, query: str, response: str) -> int:
        idx = self._next_index()
        key = self._claim_slot(idx, query, response)
        self._db, self._valid = self._add(self._db, self._valid, jnp.asarray(vec, jnp.float32), idx)
        return key

    def add_batch(self, vecs: np.ndarray, queries, responses) -> List[int]:
        """N round-robin placements in ONE donated scatter into the sharded DB.

        Placement order (and therefore the shard each entry lands on) matches
        N sequential ``add`` calls, freed-slot reuse included; a batch larger
        than the capacity wraps the round-robin cursor, in which case the
        last write to a slot wins — exactly what the sequential loop would
        leave behind.
        """
        n = len(queries)
        if n == 0:
            return []
        rows = np.asarray(vecs, np.float32).reshape(n, self.dim)
        idxs: List[int] = []
        keys: List[int] = []
        for j in range(n):
            idx = self._next_index()
            keys.append(self._claim_slot(idx, queries[j], responses[j]))
            idxs.append(idx)
        scatter_rows, scatter_idx = prepare_scatter(idxs, rows)
        self._db, self._valid = self._add_many(
            self._db, self._valid, jnp.asarray(scatter_rows), jnp.asarray(scatter_idx)
        )
        return keys

    def remove(self, key: int) -> bool:
        """Evict one entry: clears its validity lane on-device and frees the
        slot for reuse by the next add (before the cursor advances)."""
        idx = self._key_to_slot.pop(key, None)
        if idx is None:
            return False
        self.payloads[idx] = None
        self._slot_key[idx] = None
        self._valid = self._invalidate(self._valid, idx)
        self._free.append(idx)
        self.size -= 1
        return True

    def __len__(self) -> int:
        return self.size

    def search(self, q_vecs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        # Q padded to a power-of-two bucket so variable serving batch sizes
        # reuse O(log Q) compiled variants instead of retracing per size
        q, n_q = pad_to_bucket(np.atleast_2d(np.asarray(q_vecs, np.float32)))
        s, i = self._lookup(self._db, self._valid, jnp.asarray(q))
        return np.asarray(s)[:n_q], np.asarray(i)[:n_q]

    def search_batch(
        self, q_vecs: np.ndarray, k: Optional[int] = None, touch: bool = True
    ) -> List[List[Tuple[float, tuple]]]:
        """Batched payload-joined lookup for Q queries in ONE shard_map dot.

        The replicated [Q, D] query block rides the same per-shard MXU matmul
        and hierarchical candidate exchange as a single query — only the
        all-gathered [Q, k] candidate sets grow with Q. Returns, per query,
        the finite (score, (query, response)) candidates in score order, i.e.
        the same join ``InMemoryVectorStore.search_batch`` performs. ``k``
        caps the candidates per query (at most the configured search k);
        ``touch`` is accepted for signature uniformity — the sharded store
        keeps no recency/frequency counters yet.
        """
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        s, idx = self.search(q)
        k_eff = self.k if k is None else min(k, self.k)
        out: List[List[Tuple[float, tuple]]] = []
        for srow, irow in zip(s, idx):
            row = []
            for sc, i in zip(srow, irow):
                payload = self.payloads[int(i)] if 0 <= int(i) < self.capacity else None
                if np.isfinite(sc) and payload is not None:
                    row.append((float(sc), payload))
            out.append(row[:k_eff])
        return out

    def lookup_batch(
        self, q_vecs: np.ndarray, thresholds
    ) -> List[Optional[Tuple[float, tuple]]]:
        """Apply per-query thresholds vectorized over the batched search:
        returns the best (score, payload) when score > threshold, else None."""
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        thr = np.broadcast_to(np.asarray(thresholds, np.float32), (q.shape[0],))
        rows = self.search_batch(q)
        best = np.asarray([r[0][0] if r else -np.inf for r in rows])
        hit = best > thr
        return [rows[i][0] if hit[i] else None for i in range(q.shape[0])]
