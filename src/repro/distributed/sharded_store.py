"""Mesh-sharded vector store: the cache's distributed data path.

Since the StoreBank refactor the DB is a bank of *shard lanes*: one
[n_shards, cap_local, D] tensor whose lane axis is sharded over the mesh
`data` axis (and, multi-pod, over `pod` — each pod's lanes act as its L1,
cross-pod merge is the L2 exchange; DESIGN.md §3). Lookup runs under
shard_map:

    per shard: MXU dot [Q, cap_local] -> local top-k
    all_gather of the tiny [Q, k] candidate sets over (pod, data)
    global top-k merge (still inside the jit)

Only k candidates per shard cross the interconnect — never the [Q, N]
score matrix. This is the step the dry-run lowers on the production mesh
(`cache_lookup` rows in EXPERIMENTS.md §Dry-run).

The bank also holds per-lane recency/frequency counters, so the sharded DB
now has a real eviction *policy*: once every slot is live, adds evict by
lru/lfu/fifo using the same victim rule as ``InMemoryVectorStore``
(``search_batch(touch=...)`` and ``touch_keys`` feed the counters).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.store_bank import (
    _TICK_COMPACT_AT,
    StoreBank,
    _normalize_rows as _norm_rows,
    pad_to_bucket,
    prepare_scatter,
    select_victim,
)
from repro.distributed.sharding import resolve_spec


def _shard_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_id(mesh, axes: Tuple[str, ...]):
    """This device's linear shard index over ``axes`` inside a shard_map body
    (row-major over the axis order; matches the lane-axis sharding layout)."""
    sid = jnp.zeros((), jnp.int32)
    mul = 1
    for a in reversed(axes):
        sid = sid + jax.lax.axis_index(a) * mul
        mul = mul * mesh.shape[a]  # static axis size (jax.lax.axis_size needs jax>=0.5)
    return sid


def all_gather_merge_topk(axes, gs, gi, k: int, *, hierarchical: bool = True):
    """Merge per-shard [Q, k'] candidate (score, idx) sets into the global
    top-k inside a shard_map body — the ONE collective reduction shared by
    the flat lookup, the banked lookup, and the fused sharded read program.

    ``hierarchical=True`` gathers k candidates per shard over the in-pod
    (ICI) axis first, merges back down to k, THEN crosses the pod (DCN) axis
    with only Q*k candidates instead of n_data_shards*Q*k — the paper's L1
    (pod-local) / L2 (cross-pod) hierarchy expressed as a collective
    schedule (§Perf). ``hierarchical=False`` is the flat baseline: gather
    every shard's candidates everywhere, one merge."""
    q_n = gs.shape[0]
    if hierarchical:
        for a in reversed(axes):  # innermost (ICI) first, DCN last
            all_s = jax.lax.all_gather(gs, a, axis=0, tiled=False)
            all_i = jax.lax.all_gather(gi, a, axis=0, tiled=False)
            flat_s = jnp.moveaxis(all_s, 0, 1).reshape(q_n, -1)
            flat_i = jnp.moveaxis(all_i, 0, 1).reshape(q_n, -1)
            k_eff = min(k, flat_s.shape[1])
            gs, pos = jax.lax.top_k(flat_s, k_eff)
            gi = jnp.take_along_axis(flat_i, pos, axis=1)
        return gs, gi
    k_in = gs.shape[-1]
    for a in axes:
        gs = jax.lax.all_gather(gs, a, axis=0, tiled=False)
        gi = jax.lax.all_gather(gi, a, axis=0, tiled=False)
    flat_s = jnp.moveaxis(gs.reshape(-1, q_n, k_in), 0, 1).reshape(q_n, -1)
    flat_i = jnp.moveaxis(gi.reshape(-1, q_n, k_in), 0, 1).reshape(q_n, -1)
    gs, pos = jax.lax.top_k(flat_s, min(k, flat_s.shape[1]))
    gi = jnp.take_along_axis(flat_i, pos, axis=1)
    return gs, gi


def make_sharded_lookup(mesh, *, k: int, metric: str = "cosine", hierarchical: bool = True):
    """Builds the jitted sharded lookup: (db, valid, q) -> (scores, global idx).

    db: [N, D] sharded P(("pod","data"), None); valid: [N] likewise;
    q: [Q, D] replicated. (Flat-buffer variant, kept for the dry-run and the
    perf-iteration studies; the store itself uses ``make_banked_lookup``.)
    """
    axes = _shard_axes(mesh)
    if not axes:
        from repro.core.similarity import top_k_scores

        return jax.jit(lambda db, valid, q: top_k_scores(db, valid, q, k, metric))

    axis_tuple = axes if len(axes) > 1 else axes[0]

    def local_lookup(db_l, valid_l, q):
        # db_l: [cap_local, D] local shard
        cap_local = db_l.shape[0]
        dbn = db_l
        qn = q
        if metric == "cosine":
            dbn = _norm_rows(db_l)
            qn = _norm_rows(q)
        s = qn @ dbn.T  # [Q, cap_local]
        s = jnp.where(valid_l[None, :], s, -jnp.inf)
        k_eff = min(k, cap_local)
        top_s, top_i = jax.lax.top_k(s, k_eff)  # local indices
        # translate to global ids, then one shared collective merge
        top_i = top_i + shard_id(mesh, axes) * cap_local
        return all_gather_merge_topk(axes, top_s, top_i, k,
                                     hierarchical=hierarchical)

    db_spec = P(axis_tuple, None)
    valid_spec = P(axis_tuple)
    fn = shard_map(
        local_lookup,
        mesh=mesh,
        in_specs=(db_spec, valid_spec, P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


def make_banked_lookup(
    mesh, *, k: int, metric: str = "cosine", hierarchical: bool = True,
    prenormalized: bool = False,
):
    """Jitted lookup over a bank of shard lanes:
    (db [L, cap_local, D], valid [L, cap_local], q [Q, D]) ->
    (scores [Q, k], flat global idx [Q, k] where idx = lane*cap_local+within).

    The lane axis is sharded over the mesh, so each device flattens its
    local lanes into one [lanes_loc*cap_local, D] block and the collective
    schedule is identical to the flat-buffer lookup. ``prenormalized`` skips
    the db normalization (the bank keeps unit rows for cosine lanes).
    """
    axes = _shard_axes(mesh)
    if not axes:

        def flat(db, valid, q):
            L, capl, D = db.shape
            db2 = db.reshape(L * capl, D)
            v2 = valid.reshape(L * capl)
            dbn = db2 if (metric != "cosine" or prenormalized) else _norm_rows(db2)
            qn = _norm_rows(q) if metric == "cosine" else q
            s = jnp.where(v2[None, :], qn @ dbn.T, -jnp.inf)
            return jax.lax.top_k(s, min(k, L * capl))

        return jax.jit(flat)

    axis_tuple = axes if len(axes) > 1 else axes[0]

    def local_lookup(db_l, valid_l, q):
        # db_l: [lanes_loc, cap_local, D] — this device's lanes, flattened so
        # the per-shard math matches the flat-buffer path exactly
        lanes_loc, cap_local, D = db_l.shape
        cap_shard = lanes_loc * cap_local
        db2 = db_l.reshape(cap_shard, D)
        v2 = valid_l.reshape(cap_shard)
        dbn = db2 if (metric != "cosine" or prenormalized) else _norm_rows(db2)
        qn = _norm_rows(q) if metric == "cosine" else q
        s = jnp.where(v2[None, :], qn @ dbn.T, -jnp.inf)  # [Q, cap_shard]
        k_eff = min(k, cap_shard)
        top_s, top_i = jax.lax.top_k(s, k_eff)  # shard-local flat indices
        # shard-local flat idx -> bank-global flat idx (lane-major layout)
        top_i = top_i + shard_id(mesh, axes) * cap_shard
        return all_gather_merge_topk(axes, top_s, top_i, k,
                                     hierarchical=hierarchical)

    fn = shard_map(
        local_lookup,
        mesh=mesh,
        in_specs=(P(axis_tuple, None, None), P(axis_tuple, None), P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


class ShardedVectorStore:
    """Host-facing lane view over a mesh-sharded StoreBank (one lane per
    shard): functional adds, fused sharded lookup, and a real eviction
    policy backed by the bank's per-lane counters."""

    def __init__(
        self, mesh, dim: int, capacity: int, *, k: int = 4, metric: str = "cosine",
        eviction: str = "lru",  # lru | lfu | fifo
        default_ttl_s: Optional[float] = None,
        staleness_weight: float = 0.0,
        tier1=None,  # HostRamTier: eviction victims demote here, keyed by home shard
        fused: bool = True,  # serve reads via the collective fused program
    ):
        assert eviction in ("lru", "lfu", "fifo")
        self.mesh = mesh
        self.dim = dim
        axes = _shard_axes(mesh)
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        self.capacity = capacity - (capacity % max(n_shards, 1)) or n_shards
        self.n_shards = n_shards
        self.cap_local = self.capacity // n_shards
        self.metric = metric
        self.eviction = eviction
        self.k = k
        lane_axes = axes if len(axes) > 1 else (axes[0] if axes else None)
        self._db_sharding = jax.NamedSharding(mesh, P(lane_axes, None, None))
        self._valid_sharding = jax.NamedSharding(mesh, P(lane_axes, None))
        buf = jax.device_put(
            jnp.zeros((n_shards, self.cap_local, dim), jnp.float32), self._db_sharding
        )
        valid = jax.device_put(
            jnp.zeros((n_shards, self.cap_local), bool), self._valid_sharding
        )
        # the bank owns rows/masks/counters; this store is its sharded lane view
        self.bank = StoreBank(dim, [self.cap_local] * n_shards, metric=metric,
                              buf=buf, valid=valid)
        # counters and lifecycle stamps shard with the lanes they describe —
        # the fused read program's touch scatters land on the owning shard's
        # device slice without any cross-device counter traffic
        for name in ("d_last_access", "d_access_count", "d_insert_seq",
                     "d_created", "d_expires"):
            setattr(self.bank, name,
                    jax.device_put(getattr(self.bank, name), self._valid_sharding))
        self._lookup = make_banked_lookup(
            mesh, k=k, metric=metric, prenormalized=self.bank.prenormalized
        )
        self.fused = bool(fused) and bool(axes)
        self._srb = None  # lazy single-member ShardedReadBank (fused reads)
        self.default_ttl_s = default_ttl_s
        self.staleness_weight = float(staleness_weight)
        for lane in range(n_shards):
            self.bank.set_staleness(lane, staleness_weight)
        normalize = self.bank.prenormalized

        def _scatter(buf, valid, last, cnt, seq, created, expires, lanes, withins,
                     rows, c_lanes, c_withins, c_ticks, c_seqs, c_cnts, c_created,
                     c_expires):
            # rows, masks, AND the insert-time counter/lifecycle resets in one
            # donated update — the bank's device counters stay co-located with
            # the sharded lanes' lifecycle (counter placement is left to XLA)
            if normalize:
                rows = _norm_rows(rows)
            return (
                buf.at[lanes, withins].set(rows),
                valid.at[lanes, withins].set(True),
                last.at[c_lanes, c_withins].set(c_ticks),
                cnt.at[c_lanes, c_withins].set(c_cnts),
                seq.at[c_lanes, c_withins].set(c_seqs),
                created.at[c_lanes, c_withins].set(c_created),
                expires.at[c_lanes, c_withins].set(c_expires),
            )

        vsh = self._valid_sharding
        self._add_many = jax.jit(
            _scatter,
            donate_argnums=(0, 1, 2, 3, 4, 5, 6),
            out_shardings=(self._db_sharding, vsh, vsh, vsh, vsh, vsh, vsh),
        )

        def _free(valid, last, cnt, seq, created, expires, lanes, withins):
            # freed-slot hygiene: the full metadata row resets with the mask
            # (same contract as the in-memory lane view's _bank_free)
            return (
                valid.at[lanes, withins].set(False),
                last.at[lanes, withins].set(0),
                cnt.at[lanes, withins].set(0),
                seq.at[lanes, withins].set(0),
                created.at[lanes, withins].set(0.0),
                expires.at[lanes, withins].set(jnp.inf),
            )

        # the bank's free path must re-shard the mask AND counters like ours
        self.bank._free_jit = jax.jit(
            _free,
            donate_argnums=(0, 1, 2, 3, 4, 5),
            out_shardings=(vsh, vsh, vsh, vsh, vsh, vsh),
        )
        self.size = 0
        self.payloads: List[Optional[tuple]] = [None] * self.capacity
        # per-slot meta dicts (hierarchy promotion flags etc.) — payloads stay
        # bare (query, response) tuples for the legacy search_batch contract
        self._metas: List[Optional[dict]] = [None] * self.capacity
        self._rr = 0  # round-robin placement cursor for the first fill
        self._seq = 0  # insertion counter feeding the fifo policy
        # key -> slot map + freed-slot reuse (shared scheme with
        # InMemoryVectorStore) so sharded caches can evict: remove() frees the
        # slot, the next add reclaims it before the round-robin cursor advances
        self._next_key = 0
        self._key_to_slot: Dict[int, int] = {}
        self._slot_key: List[Optional[int]] = [None] * self.capacity
        self._free: List[int] = []
        # tier-1 demotion target + raw-row host mirror (same contract as
        # InMemoryVectorStore: eviction victims demote instead of vanishing;
        # demoted entries remember their home shard lane in TierEntry.meta)
        self.tier1 = None
        self._host_rows: Optional[np.ndarray] = None
        if tier1 is not None:
            self.attach_tier1(tier1)

    # -- tiering -------------------------------------------------------------

    def attach_tier1(self, tier) -> None:
        """Attach a host-RAM demotion tier (``repro.core.tiers.HostRamTier``).
        Eviction victims demote into it instead of vanishing — matching the
        in-memory lane view — with their home shard lane recorded in
        ``TierEntry.meta['home_shard']`` so promotions can land back on the
        shard whose counters/lifecycle they rode. A raw-row host mirror makes
        demotion a numpy copy instead of a device pull on the eviction path."""
        self.tier1 = tier
        self._host_rows = np.array(
            np.asarray(self.bank.buf).reshape(self.capacity, self.dim), np.float32
        )

    def _demote(self, idx: int) -> None:
        """Hand the (still-live) entry in flat slot ``idx`` to tier 1."""
        if self.tier1 is None:
            return
        payload = self.payloads[idx]
        key = self._slot_key[idx]
        if payload is None or key is None:
            return
        lane, within = self._lane_within(idx)
        expires_rel = float(self.bank.h_expires[lane, within])
        if expires_rel <= self.bank.rel_now():
            return  # dead entries are dropped, never demoted
        from repro.core.tiers import TierEntry

        row = (
            self._host_rows[idx]
            if self._host_rows is not None
            else np.asarray(self._db[idx])
        )
        self.tier1.put(
            TierEntry(
                key=key,
                query=payload[0],
                response=payload[1],
                meta={**(self._metas[idx] or {}), "home_shard": lane},
                created_at=self.bank.to_abs(float(self.bank.h_created[lane, within])),
                expires_at=self.bank.to_abs(expires_rel),
                access_count=int(self.bank.access_count[lane, within]),
            ),
            np.array(row, np.float32),
        )

    def _free_slot_in_lane(self, lane) -> Optional[int]:
        """A reusable freed slot on the given lane, if any — the home-shard
        preference promotions use before falling back to global placement."""
        if not isinstance(lane, int) or not 0 <= lane < self.n_shards:
            return None
        lo = lane * self.cap_local
        hi = lo + self.cap_local
        for pos in range(len(self._free) - 1, -1, -1):
            if lo <= self._free[pos] < hi:
                return self._free.pop(pos)
        return None

    def _restore_batch(self, rows: np.ndarray, tier_entries: List) -> None:
        """Promote tier-1 entries back into the sharded bank through the SAME
        donated batched scatter inserts ride. Keys, created/expires stamps,
        and access counts are preserved (a promoted hit is byte-identical to
        its pre-demotion self); each entry prefers a freed slot on its home
        shard lane and falls back to the global cursor/eviction policy."""
        n = len(tier_entries)
        if n == 0:
            return
        rows = np.asarray(rows, np.float32).reshape(n, self.dim)
        idxs: List[int] = []
        for j, te in enumerate(tier_entries):
            if self._seq >= _TICK_COMPACT_AT:
                self._seq = self.bank.compact_seqs()
            home = te.meta.get("home_shard") if isinstance(te.meta, dict) else None
            idx = self._free_slot_in_lane(home)
            if idx is None:
                idx = self._next_index()
            old = self._slot_key[idx]
            if old is not None:  # promotion displaced a live entry: demote it
                self._demote(idx)
                self._key_to_slot.pop(old, None)
            else:
                self.size += 1
            self.payloads[idx] = (te.query, te.response)
            # home_shard is placement routing, not entry state — strip it so a
            # later demotion records the slot's CURRENT lane, not a stale one
            meta = {k: v for k, v in dict(te.meta or {}).items()
                    if k != "home_shard"}
            self._metas[idx] = meta or None
            self._slot_key[idx] = te.key
            self._key_to_slot[te.key] = idx
            self._next_key = max(self._next_key, te.key + 1)
            lane, within = self._lane_within(idx)
            self.bank.note_insert(
                lane, within, self._seq,
                created=self.bank.to_rel(te.created_at),
                expires=(
                    self.bank.to_rel(te.expires_at)
                    if np.isfinite(te.expires_at)
                    else None
                ),
                count=int(te.access_count),
            )
            self._seq += 1
            idxs.append(idx)
            if self._host_rows is not None:
                # mirror immediately (not after the loop): a later placement
                # in this same batch may evict this row and demote its vector
                self._host_rows[idx] = rows[j]
        # tier-1 promotions stage through pinned host memory when the backend
        # supports it: the restore scatter's H2D copy can then overlap the
        # read dispatch it rides alongside (pageable fallback on CPU)
        self._scatter_rows(idxs, rows, pinned=True)

    # flat views of the banked buffers (the pre-bank [N, D] layout; lane-major
    # flattening preserves the old global slot numbering)
    @property
    def _db(self) -> jax.Array:
        return self.bank.buf.reshape(self.capacity, self.dim)

    @property
    def _valid(self) -> jax.Array:
        return self.bank.valid.reshape(self.capacity)

    # flat slot idx <-> (lane, within); flat layout is lane-major, matching
    # the banked lookup's global index translation
    def _lane_within(self, idx: int) -> Tuple[int, int]:
        return idx // self.cap_local, idx % self.cap_local

    def _next_index(self) -> int:
        if self._free:
            return self._free.pop()
        if self._rr < self.capacity:
            # first fill: balanced round-robin placement across shard lanes
            shard = self._rr % self.n_shards
            within = (self._rr // self.n_shards) % self.cap_local
            self._rr += 1
            return shard * self.cap_local + within
        # every slot is live: already-expired entries are free capacity — the
        # most-expired slot goes first, before any live entry is evicted
        if self.bank.lifecycle_active():
            exp = self.bank.h_expires.reshape(-1)
            dead = exp <= self.bank.rel_now()
            if dead.any():
                return int(np.argmin(np.where(dead, exp, np.inf)))
        # evict per policy over the bank's flat counter view (host mirror of
        # the device arrays, synced on demand)
        last, cnt, seq = self.bank.counters_host()
        return select_victim(
            self.eviction, last.reshape(-1), cnt.reshape(-1), seq.reshape(-1)
        )

    def _claim_slot(
        self, idx: int, query: str, response: str,
        meta: Optional[dict] = None, ttl_s: Optional[float] = None,
    ) -> int:
        """Host-side bookkeeping for one placement (shared by add/add_batch)."""
        old = self._slot_key[idx]
        if old is not None:  # policy eviction overwrote a live entry
            self._demote(idx)  # still-live victims move to tier 1, not /dev/null
            self._key_to_slot.pop(old, None)
        else:
            self.size += 1
        key = self._next_key
        self._next_key += 1
        self.payloads[idx] = (query, response)
        self._metas[idx] = dict(meta) if meta else None
        self._slot_key[idx] = key
        self._key_to_slot[key] = idx
        lane, within = self._lane_within(idx)
        if self._seq >= _TICK_COMPACT_AT:  # int32 insertion clock: rank-rebase
            self._seq = self.bank.compact_seqs()
        ttl_s = self.default_ttl_s if ttl_s is None else ttl_s
        created = self.bank.rel_now()
        expires = created + ttl_s if ttl_s is not None else None
        self.bank.note_insert(lane, within, self._seq, created=created,
                              expires=expires)
        self._seq += 1
        return key

    def _scatter_rows(self, idxs: List[int], rows: np.ndarray,
                      pinned: bool = False) -> None:
        sel_rows, sel_idx = prepare_scatter(idxs, rows)
        if pinned:
            from repro.kernels.backend import stage_pinned

            sel_rows = stage_pinned(sel_rows)
        lanes = (sel_idx // self.cap_local).astype(np.int32)
        withins = (sel_idx % self.cap_local).astype(np.int32)
        # the claims' counter + lifecycle resets ride the same donated update
        cl, ci, ct, cs, cc, ccr, cex = self.bank._drain_pending()
        bank = self.bank
        (
            bank.buf, bank.valid,
            bank.d_last_access, bank.d_access_count, bank.d_insert_seq,
            bank.d_created, bank.d_expires,
        ) = self._add_many(
            bank.buf, bank.valid,
            bank.d_last_access, bank.d_access_count, bank.d_insert_seq,
            bank.d_created, bank.d_expires,
            jnp.asarray(lanes), jnp.asarray(withins), jnp.asarray(sel_rows),
            jnp.asarray(cl), jnp.asarray(ci), jnp.asarray(ct), jnp.asarray(cs),
            jnp.asarray(cc), jnp.asarray(ccr), jnp.asarray(cex),
        )

    def add(self, vec: np.ndarray, query: str, response: str,
            meta: Optional[dict] = None, ttl_s: Optional[float] = None) -> int:
        idx = self._next_index()
        key = self._claim_slot(idx, query, response, meta, ttl_s)
        row = np.asarray(vec, np.float32).reshape(1, self.dim)
        if self._host_rows is not None:
            self._host_rows[idx] = row[0]
        self._scatter_rows([idx], row)
        return key

    def add_batch(self, vecs: np.ndarray, queries, responses,
                  metas: Optional[List[Optional[dict]]] = None,
                  ttls: Optional[List[Optional[float]]] = None) -> List[int]:
        """N placements in ONE donated scatter into the sharded bank.

        Placement order (and therefore the shard lane each entry lands on)
        matches N sequential ``add`` calls, freed-slot reuse and policy
        eviction included; if the batch overwrites one slot twice, the last
        write wins — exactly what the sequential loop would leave behind.
        ``metas``/``ttls`` carry optional per-entry meta dicts and TTLs
        (None = no meta / default_ttl_s) — the ``InMemoryVectorStore``
        signature, so ``SemanticCache`` levels can sit on a sharded store.
        """
        n = len(queries)
        if n == 0:
            return []
        rows = np.asarray(vecs, np.float32).reshape(n, self.dim)
        metas = list(metas) if metas is not None else [None] * n
        ttls = list(ttls) if ttls is not None else [None] * n
        idxs: List[int] = []
        keys: List[int] = []
        for j in range(n):
            idx = self._next_index()
            keys.append(self._claim_slot(idx, queries[j], responses[j],
                                         metas[j], ttls[j]))
            idxs.append(idx)
            if self._host_rows is not None:
                # mirror immediately (not after the loop): a later claim in
                # this same batch may evict this row and demote its vector
                self._host_rows[idx] = rows[j]
        self._scatter_rows(idxs, rows)
        return keys

    def remove(self, key: int) -> bool:
        """Evict one entry: clears its validity lane AND the slot's
        counter/lifecycle metadata on-device, then frees the slot for reuse
        by the next add (before the cursor advances)."""
        idx = self._key_to_slot.pop(key, None)
        if idx is None:
            return False
        self.payloads[idx] = None
        self._metas[idx] = None
        self._slot_key[idx] = None
        lane, within = self._lane_within(idx)
        self.bank.free_slots([lane], [within])
        self._free.append(idx)
        self.size -= 1
        return True

    def clear(self, older_than: Optional[float] = None) -> int:
        """Drop entries older than ``older_than`` seconds (None = everything);
        already-expired entries always qualify. One batched free update."""
        cutoff = self.bank.rel_now() - (older_than if older_than is not None else 0)
        rel_now = self.bank.rel_now()
        lanes: List[int] = []
        withins: List[int] = []
        for idx, key in enumerate(self._slot_key):
            if key is None:
                continue
            lane, within = self._lane_within(idx)
            created = self.bank.h_created[lane, within]
            expired = self.bank.h_expires[lane, within] <= rel_now
            if older_than is None or created <= cutoff or expired:
                self._key_to_slot.pop(key, None)
                self.payloads[idx] = None
                self._metas[idx] = None
                self._slot_key[idx] = None
                self._free.append(idx)
                self.size -= 1
                lanes.append(lane)
                withins.append(within)
        if lanes:
            self.bank.free_slots(lanes, withins)
        dropped = len(lanes)
        if self.tier1 is not None:  # age-based clears prune the tiers together
            dropped += self.tier1.clear(older_than=older_than)
        return dropped

    def __len__(self) -> int:
        return self.size

    def touch_keys(self, keys) -> None:
        """Deferred recency/frequency bookkeeping (same contract as
        ``InMemoryVectorStore.touch_keys``): one bump per occurrence, one
        device scatter for the whole key list; keys overwritten since the
        search are skipped."""
        pairs = [
            self._lane_within(idx)
            for idx in (self._key_to_slot.get(key) for key in keys)
            if idx is not None
        ]
        if pairs:
            self.bank.touch_slots([p[0] for p in pairs], [p[1] for p in pairs])

    # -- fused collective read path (1 dispatch / 0 host hops) -----------------

    def _fused_decision(self, q: np.ndarray, thr, k_eff: int, touch: bool):
        """One collective fused read over this store's lanes via a
        single-member ``ShardedReadBank``: local top-k, candidate exchange,
        pre-top-k lifecycle, threshold decide, and the in-program counter
        touches — all in ONE dispatch with zero host hops in between."""
        from repro.core.read_path import LevelSpec
        from repro.distributed.sharded_read import ShardedReadBank

        if self._srb is None or not self._srb.intact([self]):
            self._srb = ShardedReadBank(self.mesh, [("sh", self)])
        spec = LevelSpec(False, True, 0.0, float("inf"), 0, int(k_eff))
        n = q.shape[0]
        if thr is None:
            thr_arr = np.full((n, 1), -np.inf, np.float32)
        else:
            thr_arr = np.broadcast_to(
                np.asarray(thr, np.float32), (n,)
            ).reshape(n, 1)
        self.bank.dispatches += 1  # this store's share of the ONE dispatch
        return self._srb.fused_read(None, [None] * n, thr_arr, (spec,),
                                    vecs=q, touch=touch)

    def search(self, q_vecs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over every shard: (scores [Q, k], global flat idx [Q, k]).
        Served by the collective fused program (lifecycle applied pre-top-k,
        on device); ``fused=False`` stores keep the pre-PR host walk."""
        if not self.fused:
            return self.search_host(q_vecs)
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        dec = self._fused_decision(q, None, self.k, touch=False)
        return dec.scores[:, 0], dec.idx[:, 0]

    def search_host(self, q_vecs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """The pre-fused read path — device search, HOST-side lifecycle
        rescore (2 host hops) — kept as the parity-test / benchmark
        reference and the ``fused=False`` escape hatch."""
        # Q padded to a power-of-two bucket so variable serving batch sizes
        # reuse O(log Q) compiled variants instead of retracing per size
        self.bank.flush_pending()
        q, n_q = pad_to_bucket(np.atleast_2d(np.asarray(q_vecs, np.float32)))
        self.bank.dispatches += 1
        self.bank.host_hops += 2
        s, i = self._lookup(self.bank.buf, self.bank.valid, jnp.asarray(q))
        s, i = np.asarray(s)[:n_q], np.asarray(i)[:n_q]
        # entry lifecycle: expired candidates drop out, TTL'd ones pay the
        # staleness penalty (host-side on the tiny [Q, k] candidate sets —
        # the global flat idx decomposes into the bank's (lane, within))
        s_eff = self.bank.lifecycle_rescore(
            s, np.asarray(i) // self.cap_local, np.asarray(i) % self.cap_local
        )
        if s_eff is not None:
            s, i = self.bank.resort_desc(s_eff, i)
        return s, i

    def _join_payloads(
        self, scores: np.ndarray, idx: np.ndarray, k_eff: int,
    ) -> List[List[Tuple[float, tuple]]]:
        out: List[List[Tuple[float, tuple]]] = []
        for srow, irow in zip(scores, idx):
            row = []
            for sc, i in zip(srow, irow):
                payload = (
                    self.payloads[int(i)] if 0 <= int(i) < self.capacity else None
                )
                if np.isfinite(sc) and payload is not None:
                    row.append((float(sc), payload))
            out.append(row[:k_eff])
        return out

    def search_batch(
        self, q_vecs: np.ndarray, k: Optional[int] = None, touch: bool = True
    ) -> List[List[Tuple[float, tuple]]]:
        """Batched payload-joined lookup for Q queries in ONE shard_map
        program — including, on the fused path, the LRU/LFU touch scatters
        (each shard bumps the counters of the slots it owns, inside the same
        dispatch). Returns, per query, the finite (score, (query, response))
        candidates in score order — the same join
        ``InMemoryVectorStore.search_batch`` performs. ``k`` caps the
        candidates per query (at most the configured search k);
        ``touch=False`` defers the counter bumps to ``touch_keys``."""
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        k_eff = self.k if k is None else min(k, self.k)
        if not self.fused:
            return self.search_batch_host(q, k=k_eff, touch=touch)
        dec = self._fused_decision(q, None, k_eff, touch=touch)
        return self._join_payloads(dec.scores[:, 0], dec.idx[:, 0], k_eff)

    def search_batch_host(
        self, q_vecs: np.ndarray, k: Optional[int] = None, touch: bool = True
    ) -> List[List[Tuple[float, tuple]]]:
        """Host-walk reference twin of ``search_batch``: device search, then
        join + touch decided in host Python (one extra counter scatter)."""
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        s, idx = self.search_host(q)
        k_eff = self.k if k is None else min(k, self.k)
        out: List[List[Tuple[float, tuple]]] = []
        touched: List[Tuple[int, int]] = []
        for srow, irow in zip(s, idx):
            row = []
            for sc, i in zip(srow, irow):
                payload = self.payloads[int(i)] if 0 <= int(i) < self.capacity else None
                if np.isfinite(sc) and payload is not None:
                    if len(row) < k_eff and touch:
                        touched.append(self._lane_within(int(i)))
                    row.append((float(sc), payload))
            out.append(row[:k_eff])
        if touched:
            # one scatter (one shared tick) for the whole batch's bumps
            self.bank.touch_slots([p[0] for p in touched], [p[1] for p in touched])
        return out

    def lookup_batch(
        self, q_vecs: np.ndarray, thresholds
    ) -> List[Optional[Tuple[float, tuple]]]:
        """Apply per-query thresholds vectorized over the batched search:
        returns the best (score, payload) when score > threshold, else None.
        On the fused path the threshold compare happens IN the device
        program (the decide stage's hit mask) — the host only joins
        payloads for the winning rows."""
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        thr = np.broadcast_to(np.asarray(thresholds, np.float32), (q.shape[0],))
        if not self.fused:
            return self.lookup_batch_host(q, thr)
        dec = self._fused_decision(q, thr, self.k, touch=True)
        out: List[Optional[Tuple[float, tuple]]] = []
        for qi in range(q.shape[0]):
            if not dec.hit[qi, 0]:
                out.append(None)
                continue
            i = int(dec.idx[qi, 0, 0])
            payload = self.payloads[i] if 0 <= i < self.capacity else None
            out.append(
                (float(dec.scores[qi, 0, 0]), payload)
                if payload is not None else None
            )
        return out

    def lookup_batch_host(
        self, q_vecs: np.ndarray, thresholds
    ) -> List[Optional[Tuple[float, tuple]]]:
        """Host-walk reference twin of ``lookup_batch`` (threshold compare
        in host numpy over the host-joined candidate rows)."""
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        thr = np.broadcast_to(np.asarray(thresholds, np.float32), (q.shape[0],))
        rows = self.search_batch_host(q)
        best = np.asarray([r[0][0] if r else -np.inf for r in rows])
        hit = best > thr
        return [rows[i][0] if hit[i] else None for i in range(q.shape[0])]

    def join_candidates(
        self, scores: np.ndarray, idx: np.ndarray, touch: bool = True
    ) -> List[List[Tuple[float, "object"]]]:
        """Join raw (scores [Q, k], GLOBAL flat idx [Q, k]) search output
        into (score, ``Entry``) rows — the hierarchy-facing twin of
        ``InMemoryVectorStore.join_candidates``, reconstructing Entries from
        the host payload/meta/lifecycle state the sharded store keeps.
        ``touch=True`` bumps the joined slots' counters in one scatter (the
        fused read path passes ``touch=False`` — its bumps already happened
        inside the read program)."""
        from repro.core.vector_store import Entry

        out: List[List[Tuple[float, Entry]]] = []
        touched: List[Tuple[int, int]] = []
        for srow, irow in zip(scores, idx):
            row = []
            for sc, i in zip(srow, irow):
                i = int(i)
                if not 0 <= i < self.capacity:
                    continue
                payload = self.payloads[i]
                key = self._slot_key[i]
                if not np.isfinite(sc) or payload is None or key is None:
                    continue
                lane, within = self._lane_within(i)
                if touch:
                    touched.append((lane, within))
                row.append((
                    float(sc),
                    Entry(
                        key, payload[0], payload[1],
                        dict(self._metas[i] or {}),
                        self.bank.to_abs(float(self.bank.h_created[lane, within])),
                        self.bank.to_abs(float(self.bank.h_expires[lane, within])),
                    ),
                ))
            out.append(row)
        if touched:
            self.bank.touch_slots([p[0] for p in touched], [p[1] for p in touched])
        return out
