from repro.distributed.sharding import (  # noqa: F401
    BATCH,
    FSDP,
    SEQ,
    TP,
    constrain,
    current_mesh,
    device_put_tree,
    named_sharding,
    resolve_spec,
    shardings_for,
    use_mesh,
)
