"""Logical-axis sharding rules resolved against whatever mesh is in use.

Specs in this codebase are written against the *production* axis names
``("pod", "data", "model")``. ``resolve_spec`` adapts a spec to the actual
mesh: axes absent from the mesh are dropped (single-pod mesh has no "pod";
unit-test meshes may have neither), and axes that do not divide the concrete
dimension are dropped (e.g. 4 KV heads cannot shard over model=16 — the
sequence axis picks up the slack instead).

``data`` doubles as the FSDP axis: parameters and optimizer state are sharded
over it on a non-TP dimension (ZeRO-3); GSPMD inserts the per-layer
all-gathers, which overlap with the previous layer's compute under
scan-over-layers.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axis = Union[None, str, Tuple[str, ...]]

# Canonical logical axes.
BATCH: Axis = ("pod", "data")  # data-parallel batch dim
FSDP: Axis = "data"  # parameter/optimizer fsdp dim
TP: Axis = "model"  # tensor-parallel dim (heads / d_ff / vocab / experts)
SEQ: Axis = "data"  # context-parallel sequence dim (long-context KV)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _filter_entry(entry: Axis, mesh: Mesh, dim: Optional[int], used: set) -> Axis:
    """Drop mesh-absent / non-dividing / already-used axes."""
    if entry is None:
        return None
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    kept = []
    prod = 1
    for n in names:
        if n not in mesh.axis_names or n in used:
            continue
        size = _axis_size(mesh, n)
        if dim is not None and dim % (prod * size) != 0:
            continue
        kept.append(n)
        used.add(n)
        prod *= size
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def resolve_spec(
    spec: Sequence[Axis], mesh: Mesh, shape: Optional[Sequence[int]] = None
) -> PartitionSpec:
    """Two-pass resolution with cross-dim axis tracking:

    Pass 1 gives plain-string dims their axis (primary assignments, e.g.
    KV heads -> model); pass 2 lets tuple dims pick up whatever remains
    (fallbacks, e.g. the KV sequence axis takes `model` only when the head
    count couldn't use it). An axis is never assigned to two dims — specs
    may therefore freely list fallbacks without risking invalid
    PartitionSpecs.
    """
    used: set = set()
    entries: list = [None] * len(spec)
    order = sorted(range(len(spec)), key=lambda i: isinstance(spec[i], tuple))
    for i in order:
        dim = None if shape is None else shape[i]
        entries[i] = _filter_entry(spec[i], mesh, dim, used)
    return PartitionSpec(*entries)


def named_sharding(
    mesh: Mesh, spec: Sequence[Axis], shape: Optional[Sequence[int]] = None
) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(spec, mesh, shape))


def is_spec_leaf(x: Any) -> bool:
    """A spec leaf is None or a plain tuple of axis entries (NOT a NamedTuple
    like TrainState, which is also a tuple subclass)."""
    if x is None:
        return True
    return (
        isinstance(x, tuple)
        and not hasattr(x, "_fields")
        and all(e is None or isinstance(e, (str, tuple)) for e in x)
    )


def shardings_for(mesh: Mesh, specs: Any, shapes: Any = None) -> Any:
    """Map a pytree of raw specs (tuples) + matching shape tree to NamedShardings."""
    if shapes is None:
        return jax.tree.map(lambda s: named_sharding(mesh, s), specs, is_leaf=is_spec_leaf)

    def _one(spec, shaped):
        shape = shaped.shape if hasattr(shaped, "shape") else tuple(shaped)
        return named_sharding(mesh, spec, shape)

    return jax.tree.map(_one, specs, shapes, is_leaf=is_spec_leaf)


_MESH: Optional[Mesh] = None


class use_mesh:
    """Context manager: make `mesh` the target of ``constrain`` constraints.

    Models call ``constrain`` on activations; outside a mesh context (unit
    tests, single device) it is a no-op.
    """

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh
        self._prev: Optional[Mesh] = None

    def __enter__(self):
        global _MESH
        self._prev, _MESH = _MESH, self.mesh
        return self.mesh

    def __exit__(self, *exc):
        global _MESH
        _MESH = self._prev
        return False


def current_mesh() -> Optional[Mesh]:
    return _MESH


def constrain(x: jax.Array, spec: Sequence[Axis]) -> jax.Array:
    """with_sharding_constraint against the active ``use_mesh`` mesh."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, resolve_spec(spec, _MESH, x.shape))
    )


def device_put_tree(tree: Any, specs: Any, mesh: Mesh) -> Any:
    shardings = shardings_for(mesh, specs, tree)
    return jax.tree.map(jax.device_put, tree, shardings)


def mesh_num_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
