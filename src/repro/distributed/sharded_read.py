"""Sharded zero-host-hop read path: ONE collective device program for the
whole mesh.

``repro.core.read_path`` fuses embed -> search -> decide -> touch for a
single-host bank; this module is its ``shard_map`` twin for deployments
whose DB lanes are sharded over the mesh. One jitted dispatch covers:

    embed forward                       (replicated — every shard embeds)
    replicated hot lanes  [Lr, cap, D]  per-level top-k on every device
    sharded cold lanes    [n, capl, D]  local MXU dot + local top-k per
                                        mesh slice (make_banked_lookup's
                                        kernel body), then all_gather of
                                        only the tiny [B, k] candidate sets
                                        (hierarchical ICI-then-DCN schedule)
    device-side router mask             lane visibility per query — no
                                        per-shard host loop
    threshold + generative-rule masks   repro.core.read_path.make_decide —
    + L1 > L2 > peers winner walk       the SAME traced body as the
                                        single-host program
    recency/frequency touch scatters    replicated lanes update identically
                                        everywhere; sharded lanes apply an
                                        ownership-masked local scatter into
                                        their own device-resident counters

Only compact decision tensors ([B, L, K] scores/slots, winner, hit /
generative masks, and the embeddings) return to host: zero host hops
between embed and decide, exactly one dispatch including the touches.

Entry lifecycle (TTL expiry + staleness penalty) runs in-program too, but
— unlike the single-host program, which rescores only the top-K candidates
— the penalty applies to the full per-shard score matrix BEFORE the local
top-k. Pre-top-k rescoring is strictly more faithful (a stale high-raw
score can no longer crowd a fresher entry out of the candidate set) and
makes ``host_reference_read`` an exact numpy mirror.

The pre-PR host walk (device search, host-side staleness rescore +
threshold decide + separate touch scatter) survives as
``ShardedVectorStore.search_host``/``search_batch_host``/
``lookup_batch_host`` and as ``host_reference_read`` below — references
for parity tests and the benchmark baseline, not serving paths.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.read_path import (
    _INT32_MIN,
    _NEG_FINITE,
    LevelSpec,
    ReadDecision,
    make_decide,
)
from repro.core.store_bank import (
    StoreBank,
    _lane_scores,
    _normalize_rows as _norm_rows,
    pad_to_bucket,
)
from repro.distributed.sharded_store import (
    _shard_axes,
    all_gather_merge_topk,
    shard_id,
)


def _pad_cols(ts, ti, K: int):
    """Pad merged candidate columns up to K with -inf/slot-0 sentinels (the
    decide/touch masks treat non-finite scores as absent, and a slot-0 index
    under a False touch mask is a no-op scatter)."""
    pad = K - ts.shape[-1]
    if pad <= 0:
        return ts, ti
    ts = jnp.concatenate(
        [ts, jnp.full((*ts.shape[:-1], pad), -jnp.inf, ts.dtype)], -1
    )
    ti = jnp.concatenate([ti, jnp.zeros((*ti.shape[:-1], pad), ti.dtype)], -1)
    return ts, ti


@functools.lru_cache(maxsize=32)
def _build_sharded_program(
    forward,
    mesh,
    layout: Tuple[Tuple[str, int], ...],  # per level: ("rep", lane) | ("sh", member)
    specs: Tuple[LevelSpec, ...],
    K: int,
    rep_meta: Optional[Tuple[Tuple[str, ...], Tuple[bool, ...]]],
    sh_meta: Tuple[Tuple[str, bool], ...],  # (metric, prenormalized) per member
    lifecycle: bool,
    touch: bool,
    hierarchical: bool = True,
):
    """Compile-cached sharded fused read program (same bounded-key scheme as
    ``read_path._build_program``: forward identity + level specs + bank
    layout + mesh; jax.jit adds shape bucketing). The decide stage is
    ``read_path.make_decide`` — literally the same traced body as the
    single-host program, so the two paths cannot drift."""
    axes = _shard_axes(mesh)
    ax = axes if len(axes) > 1 else axes[0]
    L = len(specs)
    decide = make_decide(specs, K)
    rep_levels = [(li, j) for li, (kind, j) in enumerate(layout) if kind == "rep"]
    sh_levels = [(li, j) for li, (kind, j) in enumerate(layout) if kind == "sh"]
    rep_metrics, rep_prenorm = rep_meta if rep_meta is not None else ((), ())
    tick_off = 1 if rep_levels else 0

    def body(embed_args, thr, qmask, router, rep_arrays, rep_life, sh_arrays,
             sh_life, now, counters, ticks, shard_ok):
        q = forward(*embed_args)  # replicated: embeds never leave the device
        level_s: List = [None] * L
        level_i: List = [None] * L
        if rep_levels:
            buf, valid = rep_arrays
            cap = buf.shape[1]
            if lifecycle:
                created, expires, w = rep_life
                # expiry mask + staleness penalty PRE-top-k (module docstring)
                valid_eff = valid & (expires > now)
                frac = jnp.clip(
                    (now - created) / jnp.maximum(expires - created, 1e-6),
                    0.0, 1.0,
                )
                pen = jnp.where(jnp.isfinite(expires), w[:, None] * frac, 0.0)
            else:
                valid_eff, pen = valid, None
            # fused_search_body's scoring with the optional pre-top-k penalty
            if len(set(rep_metrics)) == 1:
                s = _lane_scores(buf, q, rep_metrics[0], all(rep_prenorm))
            else:
                s = jnp.stack([
                    _lane_scores(buf[r], q, rep_metrics[r], rep_prenorm[r])
                    for r in range(len(rep_metrics))
                ])
            if pen is not None:
                s = s - pen[:, None, :]
            s = jnp.where(valid_eff[:, None, :], s, -jnp.inf)  # [Lr, Q, cap]
            ts, ti = jax.lax.top_k(s, min(K, cap))
            ts, ti = ts.transpose(1, 0, 2), ti.transpose(1, 0, 2)
            ts, ti = _pad_cols(ts, ti, K)
            for li, j in rep_levels:
                level_s[li], level_i[li] = ts[:, j], ti[:, j]
        for li, j in sh_levels:
            db_l, valid_l = sh_arrays[j]
            lanes_loc, cap_local, dim = db_l.shape
            cap_shard = lanes_loc * cap_local
            metric_j, prenorm_j = sh_meta[j]
            db2 = db_l.reshape(cap_shard, dim)
            v2 = valid_l.reshape(cap_shard)
            # make_banked_lookup's kernel body: per-shard MXU dot, local top-k
            dbn = db2 if (metric_j != "cosine" or prenorm_j) else _norm_rows(db2)
            qn = _norm_rows(q) if metric_j == "cosine" else q
            s = qn @ dbn.T  # [Q, cap_shard]
            if lifecycle:
                created_l, expires_l, w_l = sh_life[j]
                c2 = created_l.reshape(cap_shard)
                e2 = expires_l.reshape(cap_shard)
                w2 = jnp.repeat(w_l, cap_local)
                v2 = v2 & (e2 > now)
                frac = jnp.clip(
                    (now - c2) / jnp.maximum(e2 - c2, 1e-6), 0.0, 1.0
                )
                s = s - jnp.where(jnp.isfinite(e2), w2 * frac, 0.0)[None, :]
            s = jnp.where(v2[None, :], s, -jnp.inf)
            # shard-availability mask (resilience): a shard marked dead
            # contributes only -inf candidates, so after the merge the
            # surviving shards' winners serve the lookup instead of the
            # whole collective failing — degraded, not down
            s = jnp.where(shard_ok[shard_id(mesh, axes)], s, -jnp.inf)
            ts, ti = jax.lax.top_k(s, min(K, cap_shard))
            # shard-local flat idx -> store-global flat idx, then the tiny
            # [B, k] candidate exchange (ICI first, DCN last)
            ti = ti + shard_id(mesh, axes) * cap_shard
            ts, ti = all_gather_merge_topk(axes, ts, ti, K,
                                           hierarchical=hierarchical)
            level_s[li], level_i[li] = _pad_cols(ts, ti, K)
        s_all = jnp.stack(level_s, 1)  # [B, L, K]
        idx_all = jnp.stack(level_i, 1)
        # device-side router: an invisible lane's candidates can neither win
        # nor be touched (the decide masks key off finite scores)
        s_all = jnp.where(router[:, :, None], s_all, -jnp.inf)
        winner, hit, generative, tmask = decide(s_all, thr, qmask)
        rep_c, sh_c = counters
        if touch and rep_levels:
            # replicated counters: every device applies the identical full
            # scatter, so the arrays stay replicated without a collective
            last, cnt = rep_c
            idx_r = jnp.stack([idx_all[:, li] for li, _ in rep_levels], 1)
            tm_r = jnp.stack([tmask[:, li] for li, _ in rep_levels], 1)
            lane_ids = jnp.asarray([j for _, j in rep_levels], jnp.int32)
            lanes3 = jnp.broadcast_to(lane_ids[None, :, None], idx_r.shape)
            cnt = cnt.at[lanes3, idx_r].add(tm_r.astype(jnp.int32))
            stamp = jnp.where(tm_r, ticks[0], jnp.int32(_INT32_MIN))
            last = last.at[lanes3, idx_r].max(stamp)
            rep_c = (last, cnt)
        if touch and sh_levels:
            out_sh = []
            for li, j in sh_levels:
                # ownership-masked local scatter: each shard bumps only the
                # slots it owns — no cross-device counter traffic at all
                last, cnt = sh_c[j]
                lanes_loc, cap_local = last.shape
                idxg = idx_all[:, li]
                within = idxg % cap_local
                ll = idxg // cap_local - shard_id(mesh, axes) * lanes_loc
                # a dead shard must not move its counters either (its -inf
                # candidates never win, but tmask covers probed levels)
                own = tmask[:, li] & (ll >= 0) & (ll < lanes_loc)
                own = own & shard_ok[shard_id(mesh, axes)]
                llc = jnp.clip(ll, 0, lanes_loc - 1)
                cnt = cnt.at[llc, within].add(own.astype(jnp.int32))
                stamp = jnp.where(own, ticks[tick_off + j], jnp.int32(_INT32_MIN))
                last = last.at[llc, within].max(stamp)
                out_sh.append((last, cnt))
            sh_c = tuple(out_sh)
        return q, s_all, idx_all, winner, hit, generative, (rep_c, sh_c)

    REP3, REP2, REP1 = P(None, None, None), P(None, None), P(None)
    SH3, SH2, SH1 = P(ax, None, None), P(ax, None), P(ax)
    rep_arr_spec = (REP3, REP2) if rep_levels else ()
    rep_life_spec = (REP2, REP2, REP1) if (rep_levels and lifecycle) else ()
    sh_arr_spec = tuple((SH3, SH2) for _ in sh_meta)
    sh_life_spec = tuple((SH2, SH2, SH1) for _ in sh_meta) if lifecycle else ()
    counters_spec = (
        (REP2, REP2) if (touch and rep_levels) else (),
        tuple((SH2, SH2) for _ in sh_meta) if touch else (),
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), rep_arr_spec, rep_life_spec,
                  sh_arr_spec, sh_life_spec, P(), counters_spec, P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P(), counters_spec),
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(9,))


class ShardedReadBank:
    """Device-resident view of a sharded hierarchy behind ONE collective
    read program: hot levels backed by ``InMemoryVectorStore`` are adopted
    into a replicated ``StoreBank`` (their full lanes live on every device),
    levels backed by ``ShardedVectorStore`` stay sharded by key over the
    mesh. ``fused_read`` then serves the whole hierarchy — embed, per-level
    candidates, candidate exchange, router, decide, winner walk, and both
    banks' counter touches — in a single dispatch.

    ``members`` is the level list in L1 > L2 > peers order, each entry
    ``("rep", InMemoryVectorStore)`` or ``("sh", ShardedVectorStore)``."""

    def __init__(self, mesh, members: Sequence[Tuple[str, object]]):
        axes = _shard_axes(mesh)
        if not axes:
            raise ValueError("sharded read path needs a mesh with a pod/data axis")
        self.mesh = mesh
        self.axes = axes
        self.members = list(members)
        self.rep_stores = [s for kind, s in self.members if kind == "rep"]
        self.sh_stores = [s for kind, s in self.members if kind == "sh"]
        if not self.sh_stores:
            raise ValueError("no sharded member — use read_path.fused_read")
        for s in self.sh_stores:
            if s.mesh is not mesh:
                raise ValueError("sharded members must share the program mesh")
        self.rep_bank: Optional[StoreBank] = (
            StoreBank.adopt(self.rep_stores) if self.rep_stores else None
        )
        if self.rep_bank is not None:
            self._replicate(self.rep_bank)
        layout: List[Tuple[str, int]] = []
        ri = si = 0
        for kind, _ in self.members:
            if kind == "rep":
                layout.append(("rep", ri))
                ri += 1
            else:
                layout.append(("sh", si))
                si += 1
        self.layout = tuple(layout)
        self.dim = (self.rep_bank or self.sh_stores[0].bank).dim
        # dataflow counters (same contract as StoreBank's): the collective
        # program counts ONE dispatch however many mesh slices it spans
        self.dispatches = 0
        self.host_hops = 0
        self.counter_scatters = 0
        # resilience: reads served with >= 1 shard masked dead (survivors'
        # candidates answered instead of the collective failing)
        self.degraded_reads = 0

    @property
    def n_shards(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    @property
    def degraded(self) -> bool:
        """True once any read ran with a shard masked out."""
        return self.degraded_reads > 0

    def _replicate(self, bank: StoreBank) -> None:
        """Pin the hot bank's arrays to an every-device replicated layout so
        the per-dispatch shard_map never pays a broadcast."""
        rspec = jax.NamedSharding(self.mesh, P())
        bank.buf = jax.device_put(bank.buf, rspec)
        bank.valid = jax.device_put(bank.valid, rspec)
        bank.d_last_access = jax.device_put(bank.d_last_access, rspec)
        bank.d_access_count = jax.device_put(bank.d_access_count, rspec)
        bank.d_insert_seq = jax.device_put(bank.d_insert_seq, rspec)
        bank.d_created = jax.device_put(bank.d_created, rspec)
        bank.d_expires = jax.device_put(bank.d_expires, rspec)

    def banks(self) -> List[StoreBank]:
        head = [self.rep_bank] if self.rep_bank is not None else []
        return head + [s.bank for s in self.sh_stores]

    def intact(self, stores: Sequence) -> bool:
        """The given level stores (in order) still match this adoption —
        same objects, replicated members still pointing at our shared bank
        lanes (a swapped/re-adopted store forces a rebuild)."""
        if len(stores) != len(self.members):
            return False
        ri = 0
        for (kind, s0), s in zip(self.members, stores):
            if s is not s0:
                return False
            if kind == "rep":
                if s._bank is not self.rep_bank or s._lane != ri:
                    return False
                ri += 1
        return True

    def lifecycle_active(self) -> bool:
        return any(b.lifecycle_active() for b in self.banks())

    def fused_read(
        self,
        embedder,
        texts: Sequence[str],
        thresholds: np.ndarray,  # [n, L] per-query/per-level effective t_s
        specs: Sequence[LevelSpec],
        vecs: Optional[np.ndarray] = None,
        router: Optional[np.ndarray] = None,  # [n, L] lane visibility
        touch: bool = True,
        shard_mask: Optional[np.ndarray] = None,  # [n_shards] bool; False = dead
    ) -> ReadDecision:
        """One collective read over the whole sharded hierarchy. Returns the
        same ``ReadDecision`` contract as ``read_path.fused_read``; sharded
        levels report store-global flat slot indices (what their
        ``join_candidates`` expects), replicated levels lane-local ones.

        ``shard_mask`` marks shards unavailable (False): their candidates
        score -inf inside the program and their counters stay untouched, so
        a lookup degrades to the surviving shards' winners instead of the
        whole collective failing — the read-path leg of the resilience
        degradation ladder."""
        from repro.core.embeddings import _identity_forward

        n = len(texts)
        specs = tuple(specs)
        L = len(specs)
        K = max(sp.k for sp in specs)
        if vecs is not None:
            v, _ = pad_to_bucket(np.asarray(vecs, np.float32).reshape(n, self.dim))
            args, B, forward = (v,), v.shape[0], _identity_forward
        else:
            prepare, forward = embedder.fused_forward()
            args, n_prep, B = prepare(list(texts))
            assert n_prep == n
        qmask = np.arange(B) < n
        thr = np.full((B, L), np.inf, np.float32)
        thr[:n] = np.asarray(thresholds, np.float32).reshape(n, L)
        rmask = np.ones((B, L), bool)
        if router is not None:
            rmask[:n] = np.asarray(router, bool).reshape(n, L)

        banks = self.banks()
        for b in banks:
            b.flush_pending()
        lifecycle = self.lifecycle_active()
        rb = self.rep_bank
        rep_meta = (rb.metrics, rb.prenorm) if rb is not None else None
        sh_meta = tuple(
            (s.metric, s.bank.prenormalized) for s in self.sh_stores
        )
        program = _build_sharded_program(
            forward, self.mesh, self.layout, specs, K, rep_meta, sh_meta,
            lifecycle, touch,
        )
        rep_arrays = (rb.buf, rb.valid) if rb is not None else ()
        rep_life = (
            (rb.d_created, rb.d_expires, rb.d_staleness())
            if (rb is not None and lifecycle) else ()
        )
        sh_arrays = tuple((s.bank.buf, s.bank.valid) for s in self.sh_stores)
        sh_life = tuple(
            (s.bank.d_created, s.bank.d_expires, s.bank.d_staleness())
            for s in self.sh_stores
        ) if lifecycle else ()
        if touch:
            ticks = tuple(np.int32(b.next_tick()) for b in banks)
            counters = (
                (rb.d_last_access, rb.d_access_count) if rb is not None else (),
                tuple(
                    (s.bank.d_last_access, s.bank.d_access_count)
                    for s in self.sh_stores
                ),
            )
        else:
            ticks = ()
            counters = ((), ())
        if shard_mask is None:
            shard_ok = np.ones(self.n_shards, bool)
        else:
            shard_ok = np.asarray(shard_mask, bool).reshape(self.n_shards)
            if not shard_ok.any():
                raise ValueError("shard_mask marks every shard dead")
            if not shard_ok.all():
                self.degraded_reads += 1
        self.dispatches += 1
        q, s, idx, winner, hit, gen, new_counters = program(
            args, thr, qmask, rmask, rep_arrays, rep_life, sh_arrays, sh_life,
            np.float32(StoreBank.rel_now()), counters, ticks, shard_ok,
        )
        if touch:
            rep_c, sh_c = new_counters
            if rb is not None:
                rb.adopt_fused_counters(*rep_c)
            for store, (last, cnt) in zip(self.sh_stores, sh_c):
                store.bank.adopt_fused_counters(last, cnt)
        # ONE host fetch for all decision tensors (counters stay on device;
        # vector-ingress callers already hold the embeddings, so the
        # replicated q never crosses back — identity forward means q == v)
        if vecs is not None:
            s, idx, winner, hit, gen = jax.device_get((s, idx, winner, hit, gen))
            q = v
        else:
            q, s, idx, winner, hit, gen = jax.device_get(
                (q, s, idx, winner, hit, gen)
            )
        return ReadDecision(q[:n], s[:n], idx[:n], winner[:n], hit[:n], gen[:n])


# -- host reference walk (parity tests + benchmark baseline only) --------------


def _np_scores(db: np.ndarray, q: np.ndarray, metric: str, prenormalized: bool):
    """Numpy float32 mirror of the program's scoring leg (cosine/dot)."""
    db = np.asarray(db, np.float32)
    q = np.asarray(q, np.float32)
    if metric == "cosine":
        if not prenormalized:
            db = db / np.maximum(
                np.linalg.norm(db, axis=-1, keepdims=True), np.float32(1e-9)
            )
        q = q / np.maximum(
            np.linalg.norm(q, axis=-1, keepdims=True), np.float32(1e-9)
        )
    return q @ db.T


def _np_decide(specs: Tuple[LevelSpec, ...], K: int, s: np.ndarray,
               thr: np.ndarray):
    """Numpy mirror of ``read_path.make_decide`` (no padding rows here, so
    qmask is implicit all-True)."""
    L = len(specs)
    t_single = np.asarray([sp.t_single for sp in specs], np.float32)
    t_comb = np.asarray(
        [sp.t_combined if sp.generative else np.inf for sp in specs], np.float32
    )
    msl = np.asarray([min(sp.max_sources, sp.k) for sp in specs], np.int32)
    ks = np.asarray([sp.k for sp in specs], np.int32)
    gen_l = np.asarray([sp.generative for sp in specs])
    sec_l = np.asarray([(not sp.generative) or sp.secondary for sp in specs])
    colK = np.arange(K)
    finite = s > np.float32(_NEG_FINITE)
    best = s[:, :, 0]
    sem_direct = sec_l[None, :] & (best > thr)
    in_x = (
        finite
        & (s > t_single[None, :, None])
        & (colK[None, None, :] < msl[None, :, None])
        & gen_l[None, :, None]
    )
    combined = np.sum(np.where(in_x, s, np.float32(0.0)), axis=-1,
                      dtype=np.float32)
    gen_ok = in_x.any(-1) & (combined > t_comb[None, :])
    semantic = sem_direct | (gen_ok & (best > thr))
    hit = semantic | gen_ok
    generative = gen_ok & ~semantic
    winner = np.where(hit.any(1), np.argmax(hit, axis=1), L).astype(np.int32)
    probed = np.arange(L)[None, :] <= winner[:, None]
    tmask = probed[:, :, None] & finite & (colK[None, None, :] < ks[None, :, None])
    return winner, hit, generative, tmask


def host_reference_read(
    srb: ShardedReadBank,
    vecs: np.ndarray,
    thresholds: np.ndarray,
    specs: Sequence[LevelSpec],
    router: Optional[np.ndarray] = None,
    now: Optional[float] = None,
    shard_mask: Optional[np.ndarray] = None,
) -> dict:
    """The host walk, kept as the parity reference: a pure-numpy mirror of
    the sharded fused program over device-fetched state. Computes the FULL
    per-level effective-score matrices (so the pre-top-k lifecycle semantics
    are reproduced exactly), per-level top-K with jax's tie order (stable,
    ascending slot), the router mask, the shared decide/winner walk, and the
    touch mask — without mutating any device state. Returns a dict with
    ``scores``/``idx``/``winner``/``hit``/``generative``/``tmask``."""
    specs = tuple(specs)
    L = len(specs)
    K = max(sp.k for sp in specs)
    q = np.atleast_2d(np.asarray(vecs, np.float32))
    n = q.shape[0]
    lifecycle = srb.lifecycle_active()
    now32 = np.float32(StoreBank.rel_now() if now is None else now)
    level_s: List[np.ndarray] = []
    level_i: List[np.ndarray] = []
    rb = srb.rep_bank
    ri = 0
    for kind, store in srb.members:
        if kind == "rep":
            buf = np.asarray(rb.buf[ri])
            valid = np.asarray(rb.valid[ri]).copy()
            s = _np_scores(buf, q, rb.metrics[ri], rb.prenorm[ri])
            if lifecycle:
                c = np.asarray(rb.d_created[ri])
                e = np.asarray(rb.d_expires[ri])
                w = np.float32(rb.staleness_w[ri])
                valid &= e > now32
                with np.errstate(invalid="ignore"):
                    frac = np.clip(
                        (now32 - c) / np.maximum(e - c, np.float32(1e-6)),
                        np.float32(0.0), np.float32(1.0),
                    )
                s = s - np.where(np.isfinite(e), w * frac, np.float32(0.0))[None, :]
            ri += 1
        else:
            bank = store.bank
            buf = np.asarray(bank.buf).reshape(store.capacity, store.dim)
            valid = np.asarray(bank.valid).reshape(store.capacity).copy()
            if shard_mask is not None:
                # shard sid owns the contiguous global flat slots
                # [sid*cap_shard, (sid+1)*cap_shard) — mirror the program's
                # availability mask by invalidating dead shards' slots
                m = np.asarray(shard_mask, bool).ravel()
                valid &= np.repeat(m, store.capacity // m.size)
            s = _np_scores(buf, q, store.metric, bank.prenormalized)
            if lifecycle:
                c = np.asarray(bank.d_created).reshape(-1)
                e = np.asarray(bank.d_expires).reshape(-1)
                w = np.repeat(
                    bank.staleness_w.astype(np.float32), store.cap_local
                )
                valid &= e > now32
                with np.errstate(invalid="ignore"):
                    frac = np.clip(
                        (now32 - c) / np.maximum(e - c, np.float32(1e-6)),
                        np.float32(0.0), np.float32(1.0),
                    )
                s = s - np.where(np.isfinite(e), w * frac, np.float32(0.0))[None, :]
        s = np.where(valid[None, :], s, -np.inf).astype(np.float32)
        order = np.argsort(-s, axis=-1, kind="stable")[:, : min(K, s.shape[1])]
        ts = np.take_along_axis(s, order, -1)
        ti = order.astype(np.int32)
        if ts.shape[1] < K:
            pad = K - ts.shape[1]
            ts = np.concatenate([ts, np.full((n, pad), -np.inf, np.float32)], 1)
            ti = np.concatenate([ti, np.zeros((n, pad), np.int32)], 1)
        level_s.append(ts)
        level_i.append(ti)
    s_all = np.stack(level_s, 1)
    idx_all = np.stack(level_i, 1)
    if router is not None:
        s_all = np.where(
            np.asarray(router, bool).reshape(n, L)[:, :, None], s_all, -np.inf
        ).astype(np.float32)
    thr = np.asarray(thresholds, np.float32).reshape(n, L)
    winner, hit, generative, tmask = _np_decide(specs, K, s_all, thr)
    return {
        "scores": s_all, "idx": idx_all, "winner": winner, "hit": hit,
        "generative": generative, "tmask": tmask,
    }
