"""Vector stores for the cache.

``InMemoryVectorStore`` is the paper's "lighter weight ... single process"
option (§5.3): a preallocated device-resident [capacity, D] buffer searched
by one jitted masked matmul + top-k (exact search — see DESIGN.md §3 for why
exact brute-force is the TPU-native replacement for Redis/Milvus ANN).
Adds are O(1) jitted functional updates with buffer donation. Contents can
be persisted to disk and warm-started (§4 "bring a cache to a warm state").

The mesh-sharded variant used by the serving stack lives in
repro.distributed.sharded_store.
"""
from __future__ import annotations

import functools
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import similarity as sim


@dataclass
class Entry:
    key: int
    query: str
    response: str
    meta: Dict[str, Any] = field(default_factory=dict)


# module-level jits: compiled once per (capacity, dim) shape and shared by
# every store instance — a 4-level hierarchy's stores reuse one executable
@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_one(buf, valid, vec, idx):
    return buf.at[idx].set(vec), valid.at[idx].set(True)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_rows(buf, valid, rows, idxs):
    return buf.at[idxs].set(rows), valid.at[idxs].set(True)


def pad_to_bucket(rows: np.ndarray) -> Tuple[np.ndarray, int]:
    """Zero-pad a [N, D] block to the next power-of-two row bucket.

    Serving drains variable-size micro-batches; an unbucketed jit would
    recompile per distinct N (stalling the lookup scheduler for hundreds of
    ms at each new size). Returns the padded block and the original N so the
    caller can slice the result back down. Shared by the in-memory and
    sharded search paths.
    """
    n = rows.shape[0]
    bucket = 1 << (n - 1).bit_length() if n > 1 else 1
    if bucket > n:
        rows = np.concatenate(
            [rows, np.zeros((bucket - n, *rows.shape[1:]), rows.dtype)]
        )
    return rows, n


def prepare_scatter(idxs: List[int], rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Build the (rows, idxs) update for a multi-row ``buf.at[idxs].set``.

    Deduplicates repeated slots last-write-wins (a batch that wraps capacity
    may pick the same victim twice; XLA scatter order for conflicting updates
    is implementation-defined, the sequential loop's is not) and pads to the
    next power-of-two bucket by repeating the final update (identical
    duplicate writes are order-independent) so the scatter jit compiles per
    bucket, not per batch size. Shared by the in-memory and sharded stores.
    """
    slot_to_row: Dict[int, int] = {}
    for j, idx in enumerate(idxs):
        slot_to_row[idx] = j
    out_idx = np.fromiter(slot_to_row.keys(), np.int32, len(slot_to_row))
    out_rows = rows[np.fromiter(slot_to_row.values(), np.int64, len(slot_to_row))]
    bucket = 1 << (len(out_idx) - 1).bit_length() if len(out_idx) > 1 else 1
    if bucket > len(out_idx):
        pad = bucket - len(out_idx)
        out_idx = np.concatenate([out_idx, np.repeat(out_idx[-1:], pad)])
        out_rows = np.concatenate([out_rows, np.repeat(out_rows[-1:], pad, axis=0)])
    return out_rows, out_idx


class InMemoryVectorStore:
    def __init__(
        self,
        dim: int,
        capacity: int = 4096,
        metric: str = "cosine",
        eviction: str = "lru",  # lru | lfu | fifo
        use_pallas: bool = False,
    ):
        assert eviction in ("lru", "lfu", "fifo")
        self.dim = dim
        self.capacity = capacity
        self.metric = metric
        self.eviction = eviction
        self.use_pallas = use_pallas
        self._buf = jnp.zeros((capacity, dim), jnp.float32)
        self._valid = jnp.zeros((capacity,), bool)
        self._entries: List[Optional[Entry]] = [None] * capacity
        self._last_access = np.zeros((capacity,), np.float64)
        self._access_count = np.zeros((capacity,), np.int64)
        self._insert_seq = np.zeros((capacity,), np.int64)
        self._seq = 0
        self.size = 0  # live entries
        self._next_key = 0
        self._key_to_slot: Dict[int, int] = {}
        self._free: List[int] = []  # slots freed by remove(), reused before eviction
        self._tail = 0  # slots ever occupied; grows monotonically to capacity

        self._add_fn = _scatter_one
        # multi-row scatter for add_batch; rows/idxs are padded to power-of-two
        # buckets so the jit only retraces per bucket, not per batch size
        self._add_batch_fn = _scatter_rows
        self._search_fns: Dict[int, Any] = {}

    # -- internals ----------------------------------------------------------

    def _victim(self) -> int:
        if self._free:
            return self._free.pop()
        if self._tail < self.capacity:
            return self._tail
        # every slot holds a live entry: evict per policy
        if self.eviction == "fifo":
            return int(np.argmin(self._insert_seq))
        if self.eviction == "lfu":
            return int(np.argmin(self._access_count))
        return int(np.argmin(self._last_access))

    def _search_fn(self, k: int):
        if k not in self._search_fns:
            metric = self.metric
            if self.use_pallas:
                from repro.kernels.similarity_topk import ops as st_ops

                self._search_fns[k] = jax.jit(
                    lambda buf, valid, q: st_ops.similarity_topk(
                        buf, valid, q, k=k, metric=metric, interpret=True
                    )
                )
            else:
                self._search_fns[k] = jax.jit(
                    lambda buf, valid, q: sim.top_k_scores(buf, valid, q, k, metric)
                )
        return self._search_fns[k]

    # -- API -----------------------------------------------------------------

    def add(self, vec: np.ndarray, query: str, response: str, meta: Optional[dict] = None) -> int:
        idx = self._victim()
        evicted = self._entries[idx]
        if evicted is not None:
            self._key_to_slot.pop(evicted.key, None)
            self.size -= 1
        if idx == self._tail:
            self._tail += 1
        self._buf, self._valid = self._add_fn(
            self._buf, self._valid, jnp.asarray(vec, jnp.float32), idx
        )
        key = self._next_key
        self._next_key += 1
        self._entries[idx] = Entry(key, query, response, dict(meta or {}))
        self._key_to_slot[key] = idx
        now = time.monotonic()
        self._last_access[idx] = now
        self._access_count[idx] = 0
        self._insert_seq[idx] = self._seq
        self._seq += 1
        self.size += 1
        return key

    def add_batch(
        self,
        vecs: np.ndarray,
        queries: List[str],
        responses: List[str],
        metas: Optional[List[Optional[dict]]] = None,
    ) -> List[int]:
        """Insert N rows with ONE jitted scatter instead of N device updates.

        Victim selection, eviction bookkeeping, and key assignment run
        host-side in insertion order, so the result is entry-for-entry
        identical to N sequential ``add`` calls (freed-slot reuse, tail
        growth, and policy eviction included); only the device work is fused
        into a single donated ``buf.at[idxs].set(rows)``.
        """
        n = len(queries)
        if n == 0:
            return []
        metas = list(metas) if metas is not None else [None] * n
        rows = np.asarray(vecs, np.float32).reshape(n, self.dim)
        keys: List[int] = []
        idxs: List[int] = []
        for j in range(n):
            idx = self._victim()
            evicted = self._entries[idx]
            if evicted is not None:
                self._key_to_slot.pop(evicted.key, None)
                self.size -= 1
            if idx == self._tail:
                self._tail += 1
            key = self._next_key
            self._next_key += 1
            self._entries[idx] = Entry(key, queries[j], responses[j], dict(metas[j] or {}))
            self._key_to_slot[key] = idx
            self._last_access[idx] = time.monotonic()
            self._access_count[idx] = 0
            self._insert_seq[idx] = self._seq
            self._seq += 1
            self.size += 1
            keys.append(key)
            idxs.append(idx)
        sel, scatter_idx = prepare_scatter(idxs, rows)
        self._buf, self._valid = self._add_batch_fn(
            self._buf, self._valid, jnp.asarray(sel), jnp.asarray(scatter_idx)
        )
        return keys

    def search(self, q_vec: np.ndarray, k: int = 4) -> List[Tuple[float, Entry]]:
        return self.search_batch(np.asarray(q_vec)[None], k)[0]

    def search_batch(
        self, q_vecs: np.ndarray, k: int = 4, touch: bool = True
    ) -> List[List[Tuple[float, Entry]]]:
        """Top-k candidates for Q queries in one device dispatch.

        ``touch=False`` returns candidates without bumping LRU/LFU
        recency/frequency counters — callers that search speculatively (the
        hierarchy probes every level up front) apply ``touch_keys`` later,
        only on the levels a sequential walk would actually have probed.
        """
        if self.size == 0:
            return [[] for _ in range(len(q_vecs))]
        k_eff = min(k, self.capacity)
        q, n_q = pad_to_bucket(np.asarray(q_vecs, np.float32))
        s, idx = self._search_fn(k_eff)(self._buf, self._valid, jnp.asarray(q))
        s, idx = np.asarray(s)[:n_q], np.asarray(idx)[:n_q]
        now = time.monotonic()
        out: List[List[Tuple[float, Entry]]] = []
        for srow, irow in zip(s, idx):
            row = []
            for sc, i in zip(srow, irow):
                e = self._entries[int(i)]
                if not np.isfinite(sc) or e is None:
                    continue
                # same recency/frequency bookkeeping as the single-query path,
                # so eviction behaves identically under batched lookups
                if touch:
                    self._last_access[int(i)] = now
                    self._access_count[int(i)] += 1
                row.append((float(sc), e))
            out.append(row)
        return out

    def touch_keys(self, keys) -> None:
        """Deferred LRU/LFU bookkeeping: one bump per occurrence, matching
        what per-query sequential probes would have recorded. Keys evicted
        since the search are skipped."""
        now = time.monotonic()
        for key in keys:
            idx = self._key_to_slot.get(key)
            if idx is not None:
                self._last_access[idx] = now
                self._access_count[idx] += 1

    def remove(self, key: int) -> bool:
        idx = self._key_to_slot.pop(key, None)
        if idx is None:
            return False
        self._entries[idx] = None
        self._valid = self._valid.at[idx].set(False)
        self._free.append(idx)
        self.size -= 1
        return True

    def __len__(self) -> int:
        return self.size

    # -- persistence (fault tolerance / warm start) ---------------------------

    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.savez(
            os.path.join(path, "vectors.npz"),
            buf=np.asarray(self._buf),
            valid=np.asarray(self._valid),
            last_access=self._last_access,
            access_count=self._access_count,
            insert_seq=self._insert_seq,
        )
        manifest = {
            "dim": self.dim,
            "capacity": self.capacity,
            "metric": self.metric,
            "eviction": self.eviction,
            "size": self.size,
            "tail": self._tail,
            "next_key": self._next_key,
            "seq": self._seq,
            "entries": [
                None if e is None else {"key": e.key, "query": e.query, "response": e.response, "meta": e.meta}
                for e in self._entries
            ],
        }
        tmp = os.path.join(path, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit

    @classmethod
    def load(cls, path: str, **kwargs) -> "InMemoryVectorStore":
        with open(os.path.join(path, "manifest.json")) as f:
            m = json.load(f)
        store = cls(m["dim"], m["capacity"], m["metric"], m["eviction"], **kwargs)
        z = np.load(os.path.join(path, "vectors.npz"))
        store._buf = jnp.asarray(z["buf"])
        store._valid = jnp.asarray(z["valid"])
        store._last_access = z["last_access"]
        store._access_count = z["access_count"]
        store._insert_seq = z["insert_seq"]
        store.size = m["size"]
        store._next_key = m["next_key"]
        store._seq = m["seq"]
        store._entries = [
            None if e is None else Entry(e["key"], e["query"], e["response"], e.get("meta", {}))
            for e in m["entries"]
        ]
        store._tail = m.get("tail", m["size"])
        store._key_to_slot = {
            e.key: i for i, e in enumerate(store._entries) if e is not None
        }
        store._free = [i for i in range(store._tail) if store._entries[i] is None]
        return store
