"""Vector stores for the cache.

``InMemoryVectorStore`` is the paper's "lighter weight ... single process"
option (§5.3): a preallocated device-resident [capacity, D] buffer searched
by one jitted masked matmul + top-k (exact search — see DESIGN.md §3 for why
exact brute-force is the TPU-native replacement for Redis/Milvus ANN).
Adds are O(1) jitted functional updates with buffer donation. Contents can
be persisted to disk and warm-started (§4 "bring a cache to a warm state").

The mesh-sharded variant used by the serving stack lives in
repro.distributed.sharded_store.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import similarity as sim


@dataclass
class Entry:
    key: int
    query: str
    response: str
    meta: Dict[str, Any] = field(default_factory=dict)


class InMemoryVectorStore:
    def __init__(
        self,
        dim: int,
        capacity: int = 4096,
        metric: str = "cosine",
        eviction: str = "lru",  # lru | lfu | fifo
        use_pallas: bool = False,
    ):
        assert eviction in ("lru", "lfu", "fifo")
        self.dim = dim
        self.capacity = capacity
        self.metric = metric
        self.eviction = eviction
        self.use_pallas = use_pallas
        self._buf = jnp.zeros((capacity, dim), jnp.float32)
        self._valid = jnp.zeros((capacity,), bool)
        self._entries: List[Optional[Entry]] = [None] * capacity
        self._last_access = np.zeros((capacity,), np.float64)
        self._access_count = np.zeros((capacity,), np.int64)
        self._insert_seq = np.zeros((capacity,), np.int64)
        self._seq = 0
        self.size = 0  # live entries
        self._next_key = 0
        self._key_to_slot: Dict[int, int] = {}
        self._free: List[int] = []  # slots freed by remove(), reused before eviction
        self._tail = 0  # slots ever occupied; grows monotonically to capacity

        self._add_fn = jax.jit(
            lambda buf, valid, vec, idx: (buf.at[idx].set(vec), valid.at[idx].set(True)),
            donate_argnums=(0, 1),
        )
        self._search_fns: Dict[int, Any] = {}

    # -- internals ----------------------------------------------------------

    def _victim(self) -> int:
        if self._free:
            return self._free.pop()
        if self._tail < self.capacity:
            return self._tail
        # every slot holds a live entry: evict per policy
        if self.eviction == "fifo":
            return int(np.argmin(self._insert_seq))
        if self.eviction == "lfu":
            return int(np.argmin(self._access_count))
        return int(np.argmin(self._last_access))

    def _search_fn(self, k: int):
        if k not in self._search_fns:
            metric = self.metric
            if self.use_pallas:
                from repro.kernels.similarity_topk import ops as st_ops

                self._search_fns[k] = jax.jit(
                    lambda buf, valid, q: st_ops.similarity_topk(
                        buf, valid, q, k=k, metric=metric, interpret=True
                    )
                )
            else:
                self._search_fns[k] = jax.jit(
                    lambda buf, valid, q: sim.top_k_scores(buf, valid, q, k, metric)
                )
        return self._search_fns[k]

    # -- API -----------------------------------------------------------------

    def add(self, vec: np.ndarray, query: str, response: str, meta: Optional[dict] = None) -> int:
        idx = self._victim()
        evicted = self._entries[idx]
        if evicted is not None:
            self._key_to_slot.pop(evicted.key, None)
            self.size -= 1
        if idx == self._tail:
            self._tail += 1
        self._buf, self._valid = self._add_fn(
            self._buf, self._valid, jnp.asarray(vec, jnp.float32), idx
        )
        key = self._next_key
        self._next_key += 1
        self._entries[idx] = Entry(key, query, response, dict(meta or {}))
        self._key_to_slot[key] = idx
        now = time.monotonic()
        self._last_access[idx] = now
        self._access_count[idx] = 0
        self._insert_seq[idx] = self._seq
        self._seq += 1
        self.size += 1
        return key

    def search(self, q_vec: np.ndarray, k: int = 4) -> List[Tuple[float, Entry]]:
        return self.search_batch(np.asarray(q_vec)[None], k)[0]

    def search_batch(self, q_vecs: np.ndarray, k: int = 4) -> List[List[Tuple[float, Entry]]]:
        if self.size == 0:
            return [[] for _ in range(len(q_vecs))]
        k_eff = min(k, self.capacity)
        s, idx = self._search_fn(k_eff)(self._buf, self._valid, jnp.asarray(q_vecs, jnp.float32))
        s, idx = np.asarray(s), np.asarray(idx)
        now = time.monotonic()
        out: List[List[Tuple[float, Entry]]] = []
        for srow, irow in zip(s, idx):
            row = []
            for sc, i in zip(srow, irow):
                e = self._entries[int(i)]
                if not np.isfinite(sc) or e is None:
                    continue
                # same recency/frequency bookkeeping as the single-query path,
                # so eviction behaves identically under batched lookups
                self._last_access[int(i)] = now
                self._access_count[int(i)] += 1
                row.append((float(sc), e))
            out.append(row)
        return out

    def remove(self, key: int) -> bool:
        idx = self._key_to_slot.pop(key, None)
        if idx is None:
            return False
        self._entries[idx] = None
        self._valid = self._valid.at[idx].set(False)
        self._free.append(idx)
        self.size -= 1
        return True

    def __len__(self) -> int:
        return self.size

    # -- persistence (fault tolerance / warm start) ---------------------------

    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.savez(
            os.path.join(path, "vectors.npz"),
            buf=np.asarray(self._buf),
            valid=np.asarray(self._valid),
            last_access=self._last_access,
            access_count=self._access_count,
            insert_seq=self._insert_seq,
        )
        manifest = {
            "dim": self.dim,
            "capacity": self.capacity,
            "metric": self.metric,
            "eviction": self.eviction,
            "size": self.size,
            "tail": self._tail,
            "next_key": self._next_key,
            "seq": self._seq,
            "entries": [
                None if e is None else {"key": e.key, "query": e.query, "response": e.response, "meta": e.meta}
                for e in self._entries
            ],
        }
        tmp = os.path.join(path, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit

    @classmethod
    def load(cls, path: str, **kwargs) -> "InMemoryVectorStore":
        with open(os.path.join(path, "manifest.json")) as f:
            m = json.load(f)
        store = cls(m["dim"], m["capacity"], m["metric"], m["eviction"], **kwargs)
        z = np.load(os.path.join(path, "vectors.npz"))
        store._buf = jnp.asarray(z["buf"])
        store._valid = jnp.asarray(z["valid"])
        store._last_access = z["last_access"]
        store._access_count = z["access_count"]
        store._insert_seq = z["insert_seq"]
        store.size = m["size"]
        store._next_key = m["next_key"]
        store._seq = m["seq"]
        store._entries = [
            None if e is None else Entry(e["key"], e["query"], e["response"], e.get("meta", {}))
            for e in m["entries"]
        ]
        store._tail = m.get("tail", m["size"])
        store._key_to_slot = {
            e.key: i for i, e in enumerate(store._entries) if e is not None
        }
        store._free = [i for i in range(store._tail) if store._entries[i] is None]
        return store
