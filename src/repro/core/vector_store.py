"""Vector stores for the cache.

``InMemoryVectorStore`` is the paper's "lighter weight ... single process"
option (§5.3): a preallocated device-resident [capacity, D] lane searched
by one fused top-k dispatch (exact search — see DESIGN.md §3 for why exact
brute-force is the TPU-native replacement for Redis/Milvus ANN). Since the
StoreBank refactor the store is a thin *lane view*: device rows, validity
masks, and eviction counters live in a ``repro.core.store_bank.StoreBank``
(a standalone store owns a 1-lane bank; a hierarchy stacks its levels into
one shared [L, cap, D] bank via ``StoreBank.adopt`` so the whole hierarchy
is searched in ONE dispatch). The store keeps the host-side entry metadata,
victim selection, and the public add/search/remove/save/load API.

Adds are O(1) jitted functional updates with buffer donation. Contents can
be persisted to disk and warm-started (§4 "bring a cache to a warm state").

The mesh-sharded variant used by the serving stack lives in
repro.distributed.sharded_store.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store_bank import (  # noqa: F401 — re-exported for back-compat
    _TICK_COMPACT_AT,
    StoreBank,
    pad_to_bucket,
    prepare_scatter,
    select_victim,
)


@dataclass
class Entry:
    key: int
    query: str
    response: str
    meta: Dict[str, Any] = field(default_factory=dict)
    created_at: float = 0.0  # absolute unix seconds
    expires_at: float = float("inf")  # absolute; inf = never expires

    def expired(self, now: Optional[float] = None) -> bool:
        return self.expires_at <= (time.time() if now is None else now)


class InMemoryVectorStore:
    def __init__(
        self,
        dim: int,
        capacity: int = 4096,
        metric: str = "cosine",
        eviction: str = "lru",  # lru | lfu | fifo
        use_pallas: bool = False,
        default_ttl_s: Optional[float] = None,
        staleness_weight: float = 0.0,
        tier1=None,  # HostRamTier: eviction victims demote here (repro.core.tiers)
    ):
        assert eviction in ("lru", "lfu", "fifo")
        self.dim = dim
        self.capacity = capacity
        self.metric = metric
        self.eviction = eviction
        self.use_pallas = use_pallas
        # entry lifecycle knobs: default TTL stamped on inserts that don't
        # carry their own, and the staleness penalty weight for this lane
        self.default_ttl_s = default_ttl_s
        self.staleness_weight = float(staleness_weight)
        # lane view: device rows/masks/counters live in the bank; a fresh
        # store owns a private 1-lane bank until a hierarchy adopts it
        self._bank = StoreBank(dim, [capacity], metric=metric, use_pallas=use_pallas)
        self._lane = 0
        self._bank.set_staleness(self._lane, staleness_weight)
        self._entries: List[Optional[Entry]] = [None] * capacity
        self._seq = 0
        self.size = 0  # live entries
        self._next_key = 0
        self._key_to_slot: Dict[int, int] = {}
        self._free: List[int] = []  # slots freed by remove(), reused before eviction
        self._tail = 0  # slots ever occupied; grows monotonically to capacity
        # tier-1 demotion target + the raw-row host mirror that feeds it
        # (rows arrive on host at add time anyway; the mirror makes demotion
        # a numpy copy instead of a device pull on the eviction path)
        self.tier1 = None
        self._host_rows: Optional[np.ndarray] = None
        if tier1 is not None:
            self.attach_tier1(tier1)

    # -- lane views (device rows + counters live in the bank) -------------------

    @property
    def _buf(self) -> jax.Array:
        return self._bank.lane_buf(self._lane, self.capacity)

    @property
    def _valid(self) -> jax.Array:
        return self._bank.lane_valid(self._lane, self.capacity)

    @property
    def _last_access(self) -> np.ndarray:  # host view of the bank's device counters
        return self._bank.last_access[self._lane][: self.capacity]

    @property
    def _access_count(self) -> np.ndarray:
        return self._bank.access_count[self._lane][: self.capacity]

    @property
    def _insert_seq(self) -> np.ndarray:
        return self._bank.insert_seq[self._lane][: self.capacity]

    # -- tiering -------------------------------------------------------------

    def attach_tier1(self, tier) -> None:
        """Attach a host-RAM demotion tier (``repro.core.tiers.HostRamTier``).
        From now on eviction victims demote into it instead of vanishing, and
        a raw-row host mirror is kept so demotion is a numpy copy rather than
        a device pull on the eviction path."""
        self.tier1 = tier
        self._host_rows = np.array(np.asarray(self._buf), np.float32)

    def _demote(self, idx: int, entry: Entry) -> None:
        if self.tier1 is None or entry.expired():
            return  # dead entries are dropped, never demoted
        from repro.core.tiers import TierEntry

        row = (
            self._host_rows[idx]
            if self._host_rows is not None
            else np.asarray(self._buf[idx])
        )
        self.tier1.put(
            TierEntry(
                key=entry.key,
                query=entry.query,
                response=entry.response,
                meta=dict(entry.meta),
                created_at=entry.created_at,
                expires_at=entry.expires_at,
                access_count=int(self._access_count[idx]),
            ),
            np.array(row, np.float32),
        )

    # -- internals ----------------------------------------------------------

    def _victim(self) -> int:
        if self._free:
            return self._free.pop()
        if self._tail < self.capacity:
            return self._tail
        # every slot holds a live entry: prefer reclaiming an expired one
        # (most-expired first) before evicting anything still alive
        if self._bank.lifecycle_active():
            exp = self._bank.h_expires[self._lane][: self.capacity]
            dead = exp <= self._bank.rel_now()
            if dead.any():
                return int(np.argmin(np.where(dead, exp, np.inf)))
        return select_victim(
            self.eviction, self._last_access, self._access_count, self._insert_seq
        )

    def _claim(
        self,
        idx: int,
        query: str,
        response: str,
        meta: Optional[dict],
        ttl_s: Optional[float] = None,
    ) -> int:
        """Host-side bookkeeping for one placement (shared by add/add_batch)."""
        if self._seq >= _TICK_COMPACT_AT:
            self._seq = self._bank.compact_seqs()
        evicted = self._entries[idx]
        if evicted is not None:
            self._demote(idx, evicted)
            self._key_to_slot.pop(evicted.key, None)
            self.size -= 1
        if idx == self._tail:
            self._tail += 1
        key = self._next_key
        self._next_key += 1
        ttl_s = self.default_ttl_s if ttl_s is None else ttl_s
        created = time.time()
        expires = created + ttl_s if ttl_s is not None else float("inf")
        self._entries[idx] = Entry(
            key, query, response, dict(meta or {}), created, expires
        )
        self._key_to_slot[key] = idx
        self._bank.note_insert(
            self._lane,
            idx,
            self._seq,
            created=self._bank.to_rel(created),
            expires=self._bank.to_rel(expires) if np.isfinite(expires) else None,
        )
        self._seq += 1
        self.size += 1
        return key

    # -- API -----------------------------------------------------------------

    def add(
        self,
        vec: np.ndarray,
        query: str,
        response: str,
        meta: Optional[dict] = None,
        ttl_s: Optional[float] = None,
    ) -> int:
        idx = self._victim()
        key = self._claim(idx, query, response, meta, ttl_s)
        row = np.asarray(vec, np.float32).reshape(1, self.dim)
        if self._host_rows is not None:
            self._host_rows[idx] = row[0]
        self._bank.set_rows(self._lane, [idx], row)
        return key

    def add_batch(
        self,
        vecs: np.ndarray,
        queries: List[str],
        responses: List[str],
        metas: Optional[List[Optional[dict]]] = None,
        ttls: Optional[List[Optional[float]]] = None,
    ) -> List[int]:
        """Insert N rows with ONE jitted scatter instead of N device updates.

        Victim selection, eviction bookkeeping, and key assignment run
        host-side in insertion order, so the result is entry-for-entry
        identical to N sequential ``add`` calls (freed-slot reuse, tail
        growth, and policy eviction included); only the device work is fused
        into a single donated scatter into the bank lane.
        """
        n = len(queries)
        if n == 0:
            return []
        metas = list(metas) if metas is not None else [None] * n
        ttls = list(ttls) if ttls is not None else [None] * n
        rows = np.asarray(vecs, np.float32).reshape(n, self.dim)
        keys: List[int] = []
        idxs: List[int] = []
        for j in range(n):
            idx = self._victim()
            keys.append(self._claim(idx, queries[j], responses[j], metas[j], ttls[j]))
            idxs.append(idx)
            if self._host_rows is not None:
                # mirror immediately (not after the loop): a later claim in
                # this same batch may evict this row and demote its vector
                self._host_rows[idx] = rows[j]
        self._bank.set_rows(self._lane, idxs, rows)
        return keys

    def _restore_batch(self, rows: np.ndarray, tier_entries: List) -> None:
        """Promote tier-1 entries back into the device lane via the SAME
        batched row-scatter path inserts use (one donated scatter). Original
        keys, created/expires stamps, and access counts are preserved, so a
        promoted hit is byte-identical to its pre-demotion self."""
        n = len(tier_entries)
        if n == 0:
            return
        rows = np.asarray(rows, np.float32).reshape(n, self.dim)
        idxs: List[int] = []
        for j, te in enumerate(tier_entries):
            if self._seq >= _TICK_COMPACT_AT:
                self._seq = self._bank.compact_seqs()
            idx = self._victim()
            evicted = self._entries[idx]
            if evicted is not None:
                self._demote(idx, evicted)
                self._key_to_slot.pop(evicted.key, None)
                self.size -= 1
            if idx == self._tail:
                self._tail += 1
            self._entries[idx] = Entry(
                te.key, te.query, te.response, dict(te.meta),
                te.created_at, te.expires_at,
            )
            self._key_to_slot[te.key] = idx
            self._next_key = max(self._next_key, te.key + 1)
            self._bank.note_insert(
                self._lane,
                idx,
                self._seq,
                created=self._bank.to_rel(te.created_at),
                expires=(
                    self._bank.to_rel(te.expires_at)
                    if np.isfinite(te.expires_at)
                    else None
                ),
                count=int(te.access_count),
            )
            self._seq += 1
            self.size += 1
            idxs.append(idx)
            if self._host_rows is not None:
                self._host_rows[idx] = rows[j]
        # promotions stage through pinned host memory where available so the
        # restore scatter's H2D copy overlaps the read dispatch (CPU: pageable)
        self._bank.set_rows(self._lane, idxs, rows, pinned=True)

    def search(self, q_vec: np.ndarray, k: int = 4) -> List[Tuple[float, Entry]]:
        return self.search_batch(np.asarray(q_vec)[None], k)[0]

    def search_batch(
        self, q_vecs: np.ndarray, k: int = 4, touch: bool = True
    ) -> List[List[Tuple[float, Entry]]]:
        """Top-k candidates for Q queries in one device dispatch.

        ``touch=False`` returns candidates without bumping LRU/LFU
        recency/frequency counters — callers that search speculatively (the
        hierarchy probes every level up front) apply ``touch_keys`` later,
        only on the levels a sequential walk would actually have probed.
        """
        if self.size == 0:
            return [[] for _ in range(len(q_vecs))]
        k_eff = min(k, self.capacity)
        s, idx = self._bank.search_lane(
            self._lane, np.asarray(q_vecs, np.float32), k_eff
        )
        return self.join_candidates(s, idx, touch=touch)

    def join_candidates(
        self, scores: np.ndarray, idx: np.ndarray, touch: bool = True
    ) -> List[List[Tuple[float, Entry]]]:
        """Join raw (scores [Q, k], slot idx [Q, k]) search output against the
        host-side entries — the step shared by this store's ``search_batch``
        and the hierarchy's fused all-lanes lookup, which searches the whole
        bank in one dispatch and joins each lane's slice here. (The fully
        fused read path never comes through here for touches — its bumps are
        a scatter-add inside the read program itself.)"""
        out: List[List[Tuple[float, Entry]]] = []
        touched: List[int] = []
        for srow, irow in zip(scores, idx):
            row = []
            for sc, i in zip(srow, irow):
                if int(i) >= self.capacity:
                    continue  # shared-bank padding lane rows beyond our capacity
                e = self._entries[int(i)]
                if not np.isfinite(sc) or e is None:
                    continue
                # same recency/frequency bookkeeping as the single-query path,
                # so eviction behaves identically under batched lookups — now
                # ONE device scatter for the whole join instead of a host loop
                if touch:
                    touched.append(int(i))
                row.append((float(sc), e))
            out.append(row)
        if touched:
            self._bank.touch_slots([self._lane] * len(touched), touched)
        return out

    def touch_keys(self, keys) -> None:
        """Deferred LRU/LFU bookkeeping: one bump per occurrence (one device
        scatter for the whole key list), matching what per-query sequential
        probes would have recorded. Keys evicted since the search are
        skipped."""
        idxs = [
            idx for idx in (self._key_to_slot.get(key) for key in keys)
            if idx is not None
        ]
        if idxs:
            self._bank.touch_slots([self._lane] * len(idxs), idxs)

    def remove(self, key: int) -> bool:
        idx = self._key_to_slot.pop(key, None)
        if idx is None:
            return False
        self._entries[idx] = None
        # free_slots resets the ENTIRE metadata row (validity + recency/
        # frequency/insertion counters + created/expires), so a reused slot
        # is indistinguishable from a fresh one
        self._bank.free_slots([self._lane], [idx])
        self._free.append(idx)
        self.size -= 1
        return True

    def clear(self, older_than: Optional[float] = None) -> int:
        """Drop entries: all of them, or — with ``older_than`` (seconds) —
        entries created more than that long ago plus anything already
        expired. One batched free scatter; cascades into the attached
        tier-1 ring. Returns the number of entries dropped across tiers."""
        now = time.time()
        cutoff = None if older_than is None else now - float(older_than)
        drop: List[int] = []
        for idx, e in enumerate(self._entries):
            if e is None:
                continue
            if cutoff is None or e.created_at <= cutoff or e.expires_at <= now:
                drop.append(idx)
        for idx in drop:
            self._key_to_slot.pop(self._entries[idx].key, None)
            self._entries[idx] = None
            self._free.append(idx)
            self.size -= 1
        if drop:
            self._bank.free_slots([self._lane] * len(drop), drop)
        dropped = len(drop)
        if self.tier1 is not None:
            dropped += self.tier1.clear(older_than=older_than)
        return dropped

    def __len__(self) -> int:
        return self.size

    # -- persistence (fault tolerance / warm start) ---------------------------

    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.savez(
            os.path.join(path, "vectors.npz"),
            buf=np.asarray(self._buf),
            valid=np.asarray(self._valid),
            last_access=np.asarray(self._last_access),
            access_count=np.asarray(self._access_count),
            insert_seq=np.asarray(self._insert_seq),
            # absolute unix stamps (f64): snapshots survive process restarts,
            # so the bank-relative clock cannot be persisted directly
            created_at=np.array(
                [0.0 if e is None else e.created_at for e in self._entries],
                np.float64,
            ),
            expires_at=np.array(
                [np.inf if e is None else e.expires_at for e in self._entries],
                np.float64,
            ),
        )
        manifest = {
            "dim": self.dim,
            "capacity": self.capacity,
            "metric": self.metric,
            "eviction": self.eviction,
            "size": self.size,
            "tail": self._tail,
            "next_key": self._next_key,
            "seq": self._seq,
            # cosine banks persist unit rows; loaders skip re-normalization
            "normalized": self._bank.prenorm[self._lane],
            # device counters persist as logical int32 ticks (order-preserving);
            # loaders rank-transform legacy wall-clock float stamps
            "counter_rep": "tick",
            "default_ttl_s": self.default_ttl_s,
            "staleness_weight": self.staleness_weight,
            "entries": [
                None if e is None else {"key": e.key, "query": e.query, "response": e.response, "meta": e.meta}
                for e in self._entries
            ],
        }
        tmp = os.path.join(path, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit

    @classmethod
    def load(cls, path: str, **kwargs) -> "InMemoryVectorStore":
        with open(os.path.join(path, "manifest.json")) as f:
            m = json.load(f)
        kwargs.setdefault("default_ttl_s", m.get("default_ttl_s"))
        kwargs.setdefault("staleness_weight", m.get("staleness_weight", 0.0) or 0.0)
        store = cls(m["dim"], m["capacity"], m["metric"], m["eviction"], **kwargs)
        z = np.load(os.path.join(path, "vectors.npz"))
        buf = np.asarray(z["buf"], np.float32)
        if store._bank.prenormalized and not m.get("normalized", False):
            # pre-bank snapshot: raw rows on disk, the bank expects unit rows
            norms = np.maximum(np.linalg.norm(buf, axis=-1, keepdims=True), 1e-9)
            buf = buf / norms
        store._bank.buf = jnp.asarray(buf)[None]
        store._bank.valid = jnp.asarray(z["valid"])[None]
        last = np.asarray(z["last_access"])
        if m.get("counter_rep") != "tick":
            # pre-device-counter snapshot: float wall-clock stamps on disk.
            # Rank-transform into the tick representation — order (and ties)
            # preserved, which is all lru/fifo argmin victim selection uses.
            last = np.unique(last, return_inverse=True)[1].astype(np.int64)
        store._bank.set_counters(
            last[None], np.asarray(z["access_count"])[None],
            np.asarray(z["insert_seq"])[None],
        )
        store.size = m["size"]
        store._next_key = m["next_key"]
        store._seq = m["seq"]
        # lifecycle stamps ride in the npz as absolute f64 (legacy snapshots
        # lack them: created 0 / expires inf, i.e. immortal)
        cap = m["capacity"]
        created = np.asarray(z["created_at"], np.float64) if "created_at" in z else np.zeros(cap)
        expires = np.asarray(z["expires_at"], np.float64) if "expires_at" in z else np.full(cap, np.inf)
        store._entries = [
            None
            if e is None
            else Entry(
                e["key"], e["query"], e["response"], e.get("meta", {}),
                float(created[i]), float(expires[i]),
            )
            for i, e in enumerate(m["entries"])
        ]
        rel_c = np.array([StoreBank.to_rel(c) for c in created], np.float64)
        rel_e = np.array([StoreBank.to_rel(x) for x in expires], np.float64)
        store._bank.set_lifecycle(rel_c[None], rel_e[None])
        store._tail = m.get("tail", m["size"])
        store._key_to_slot = {
            e.key: i for i, e in enumerate(store._entries) if e is not None
        }
        store._free = [i for i in range(store._tail) if store._entries[i] is None]
        return store
