"""Vector stores for the cache.

``InMemoryVectorStore`` is the paper's "lighter weight ... single process"
option (§5.3): a preallocated device-resident [capacity, D] lane searched
by one fused top-k dispatch (exact search — see DESIGN.md §3 for why exact
brute-force is the TPU-native replacement for Redis/Milvus ANN). Since the
StoreBank refactor the store is a thin *lane view*: device rows, validity
masks, and eviction counters live in a ``repro.core.store_bank.StoreBank``
(a standalone store owns a 1-lane bank; a hierarchy stacks its levels into
one shared [L, cap, D] bank via ``StoreBank.adopt`` so the whole hierarchy
is searched in ONE dispatch). The store keeps the host-side entry metadata,
victim selection, and the public add/search/remove/save/load API.

Adds are O(1) jitted functional updates with buffer donation. Contents can
be persisted to disk and warm-started (§4 "bring a cache to a warm state").

The mesh-sharded variant used by the serving stack lives in
repro.distributed.sharded_store.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store_bank import (  # noqa: F401 — re-exported for back-compat
    StoreBank,
    pad_to_bucket,
    prepare_scatter,
    select_victim,
)


@dataclass
class Entry:
    key: int
    query: str
    response: str
    meta: Dict[str, Any] = field(default_factory=dict)


class InMemoryVectorStore:
    def __init__(
        self,
        dim: int,
        capacity: int = 4096,
        metric: str = "cosine",
        eviction: str = "lru",  # lru | lfu | fifo
        use_pallas: bool = False,
    ):
        assert eviction in ("lru", "lfu", "fifo")
        self.dim = dim
        self.capacity = capacity
        self.metric = metric
        self.eviction = eviction
        self.use_pallas = use_pallas
        # lane view: device rows/masks/counters live in the bank; a fresh
        # store owns a private 1-lane bank until a hierarchy adopts it
        self._bank = StoreBank(dim, [capacity], metric=metric, use_pallas=use_pallas)
        self._lane = 0
        self._entries: List[Optional[Entry]] = [None] * capacity
        self._seq = 0
        self.size = 0  # live entries
        self._next_key = 0
        self._key_to_slot: Dict[int, int] = {}
        self._free: List[int] = []  # slots freed by remove(), reused before eviction
        self._tail = 0  # slots ever occupied; grows monotonically to capacity

    # -- lane views (device rows + counters live in the bank) -------------------

    @property
    def _buf(self) -> jax.Array:
        return self._bank.lane_buf(self._lane, self.capacity)

    @property
    def _valid(self) -> jax.Array:
        return self._bank.lane_valid(self._lane, self.capacity)

    @property
    def _last_access(self) -> np.ndarray:  # host view of the bank's device counters
        return self._bank.last_access[self._lane][: self.capacity]

    @property
    def _access_count(self) -> np.ndarray:
        return self._bank.access_count[self._lane][: self.capacity]

    @property
    def _insert_seq(self) -> np.ndarray:
        return self._bank.insert_seq[self._lane][: self.capacity]

    # -- internals ----------------------------------------------------------

    def _victim(self) -> int:
        if self._free:
            return self._free.pop()
        if self._tail < self.capacity:
            return self._tail
        # every slot holds a live entry: evict per policy
        return select_victim(
            self.eviction, self._last_access, self._access_count, self._insert_seq
        )

    def _claim(self, idx: int, query: str, response: str, meta: Optional[dict]) -> int:
        """Host-side bookkeeping for one placement (shared by add/add_batch)."""
        evicted = self._entries[idx]
        if evicted is not None:
            self._key_to_slot.pop(evicted.key, None)
            self.size -= 1
        if idx == self._tail:
            self._tail += 1
        key = self._next_key
        self._next_key += 1
        self._entries[idx] = Entry(key, query, response, dict(meta or {}))
        self._key_to_slot[key] = idx
        self._bank.note_insert(self._lane, idx, self._seq)
        self._seq += 1
        self.size += 1
        return key

    # -- API -----------------------------------------------------------------

    def add(self, vec: np.ndarray, query: str, response: str, meta: Optional[dict] = None) -> int:
        idx = self._victim()
        key = self._claim(idx, query, response, meta)
        self._bank.set_rows(
            self._lane, [idx], np.asarray(vec, np.float32).reshape(1, self.dim)
        )
        return key

    def add_batch(
        self,
        vecs: np.ndarray,
        queries: List[str],
        responses: List[str],
        metas: Optional[List[Optional[dict]]] = None,
    ) -> List[int]:
        """Insert N rows with ONE jitted scatter instead of N device updates.

        Victim selection, eviction bookkeeping, and key assignment run
        host-side in insertion order, so the result is entry-for-entry
        identical to N sequential ``add`` calls (freed-slot reuse, tail
        growth, and policy eviction included); only the device work is fused
        into a single donated scatter into the bank lane.
        """
        n = len(queries)
        if n == 0:
            return []
        metas = list(metas) if metas is not None else [None] * n
        rows = np.asarray(vecs, np.float32).reshape(n, self.dim)
        keys: List[int] = []
        idxs: List[int] = []
        for j in range(n):
            idx = self._victim()
            keys.append(self._claim(idx, queries[j], responses[j], metas[j]))
            idxs.append(idx)
        self._bank.set_rows(self._lane, idxs, rows)
        return keys

    def search(self, q_vec: np.ndarray, k: int = 4) -> List[Tuple[float, Entry]]:
        return self.search_batch(np.asarray(q_vec)[None], k)[0]

    def search_batch(
        self, q_vecs: np.ndarray, k: int = 4, touch: bool = True
    ) -> List[List[Tuple[float, Entry]]]:
        """Top-k candidates for Q queries in one device dispatch.

        ``touch=False`` returns candidates without bumping LRU/LFU
        recency/frequency counters — callers that search speculatively (the
        hierarchy probes every level up front) apply ``touch_keys`` later,
        only on the levels a sequential walk would actually have probed.
        """
        if self.size == 0:
            return [[] for _ in range(len(q_vecs))]
        k_eff = min(k, self.capacity)
        s, idx = self._bank.search_lane(
            self._lane, np.asarray(q_vecs, np.float32), k_eff
        )
        return self.join_candidates(s, idx, touch=touch)

    def join_candidates(
        self, scores: np.ndarray, idx: np.ndarray, touch: bool = True
    ) -> List[List[Tuple[float, Entry]]]:
        """Join raw (scores [Q, k], slot idx [Q, k]) search output against the
        host-side entries — the step shared by this store's ``search_batch``
        and the hierarchy's fused all-lanes lookup, which searches the whole
        bank in one dispatch and joins each lane's slice here. (The fully
        fused read path never comes through here for touches — its bumps are
        a scatter-add inside the read program itself.)"""
        out: List[List[Tuple[float, Entry]]] = []
        touched: List[int] = []
        for srow, irow in zip(scores, idx):
            row = []
            for sc, i in zip(srow, irow):
                if int(i) >= self.capacity:
                    continue  # shared-bank padding lane rows beyond our capacity
                e = self._entries[int(i)]
                if not np.isfinite(sc) or e is None:
                    continue
                # same recency/frequency bookkeeping as the single-query path,
                # so eviction behaves identically under batched lookups — now
                # ONE device scatter for the whole join instead of a host loop
                if touch:
                    touched.append(int(i))
                row.append((float(sc), e))
            out.append(row)
        if touched:
            self._bank.touch_slots([self._lane] * len(touched), touched)
        return out

    def touch_keys(self, keys) -> None:
        """Deferred LRU/LFU bookkeeping: one bump per occurrence (one device
        scatter for the whole key list), matching what per-query sequential
        probes would have recorded. Keys evicted since the search are
        skipped."""
        idxs = [
            idx for idx in (self._key_to_slot.get(key) for key in keys)
            if idx is not None
        ]
        if idxs:
            self._bank.touch_slots([self._lane] * len(idxs), idxs)

    def remove(self, key: int) -> bool:
        idx = self._key_to_slot.pop(key, None)
        if idx is None:
            return False
        self._entries[idx] = None
        self._bank.invalidate(self._lane, idx)
        self._free.append(idx)
        self.size -= 1
        return True

    def __len__(self) -> int:
        return self.size

    # -- persistence (fault tolerance / warm start) ---------------------------

    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.savez(
            os.path.join(path, "vectors.npz"),
            buf=np.asarray(self._buf),
            valid=np.asarray(self._valid),
            last_access=np.asarray(self._last_access),
            access_count=np.asarray(self._access_count),
            insert_seq=np.asarray(self._insert_seq),
        )
        manifest = {
            "dim": self.dim,
            "capacity": self.capacity,
            "metric": self.metric,
            "eviction": self.eviction,
            "size": self.size,
            "tail": self._tail,
            "next_key": self._next_key,
            "seq": self._seq,
            # cosine banks persist unit rows; loaders skip re-normalization
            "normalized": self._bank.prenorm[self._lane],
            # device counters persist as logical int32 ticks (order-preserving);
            # loaders rank-transform legacy wall-clock float stamps
            "counter_rep": "tick",
            "entries": [
                None if e is None else {"key": e.key, "query": e.query, "response": e.response, "meta": e.meta}
                for e in self._entries
            ],
        }
        tmp = os.path.join(path, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit

    @classmethod
    def load(cls, path: str, **kwargs) -> "InMemoryVectorStore":
        with open(os.path.join(path, "manifest.json")) as f:
            m = json.load(f)
        store = cls(m["dim"], m["capacity"], m["metric"], m["eviction"], **kwargs)
        z = np.load(os.path.join(path, "vectors.npz"))
        buf = np.asarray(z["buf"], np.float32)
        if store._bank.prenormalized and not m.get("normalized", False):
            # pre-bank snapshot: raw rows on disk, the bank expects unit rows
            norms = np.maximum(np.linalg.norm(buf, axis=-1, keepdims=True), 1e-9)
            buf = buf / norms
        store._bank.buf = jnp.asarray(buf)[None]
        store._bank.valid = jnp.asarray(z["valid"])[None]
        last = np.asarray(z["last_access"])
        if m.get("counter_rep") != "tick":
            # pre-device-counter snapshot: float wall-clock stamps on disk.
            # Rank-transform into the tick representation — order (and ties)
            # preserved, which is all lru/fifo argmin victim selection uses.
            last = np.unique(last, return_inverse=True)[1].astype(np.int64)
        store._bank.set_counters(
            last[None], np.asarray(z["access_count"])[None],
            np.asarray(z["insert_seq"])[None],
        )
        store.size = m["size"]
        store._next_key = m["next_key"]
        store._seq = m["seq"]
        store._entries = [
            None if e is None else Entry(e["key"], e["query"], e["response"], e.get("meta", {}))
            for e in m["entries"]
        ]
        store._tail = m.get("tail", m["size"])
        store._key_to_slot = {
            e.key: i for i, e in enumerate(store._entries) if e is not None
        }
        store._free = [i for i in range(store._tail) if store._entries[i] is None]
        return store
