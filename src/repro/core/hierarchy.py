"""Hierarchical / cooperative caching (§4, Figure 1).

Client-local L1 caches front shared L2 caches; L2 caches cooperate with peer
L2s. On a lower-level hit the query-response pair is promoted into the upper
levels (the paper: "If the L2 cache is able to satisfy the request with a
query-response pair q1, q1 is then stored in the L1 cache"). The same
similarity threshold t_s(1) (the requesting client's effective threshold) is
used at every level. Privacy hints let users keep personal entries out of
the shared levels (§4) — and they always win: ``cache_l2=False`` is a hard
veto, even in an inclusive hierarchy. ``inclusive=True`` makes the shared L2
a superset of what this client serves: peer-level winners are mirrored into
L2 alongside their L1 promotion (safe — they already live in a shared
level), so cooperating clients converge on one shared working set.

``lookup_batch`` serves B queries with one embed forward and ONE fused
search dispatch for the WHOLE hierarchy: the level stores are stacked into
a shared ``StoreBank`` ([L, cap, D]; see repro.core.store_bank), a single
``search_lanes`` dispatch returns [B, L, k] candidates, and each level's
slice goes through that level's own decision rule
(``SemanticCache._decide_batch`` / the generative override). The per-query
winning level is resolved host-side (L1 beats L2 beats peers) on the
returned scores — masking lower levels for queries L1 already answered
costs no extra dispatch — lower-level winners are promoted into L1 via one
``add_batch`` scatter, and residual misses get a batched cross-level
generative pass over the already searched candidates. Levels that cannot
share a bank fall back to one dispatch per level.

On the TPU mesh this topology maps to pod-local L1 shards and cross-pod L2
exchange (DESIGN.md §3); this module is the level-coordination logic, shared
by the host-side client and the mesh-sharded store.
"""
from __future__ import annotations

import time
from typing import List, Optional

import numpy as np

from repro.core.generative_cache import GenerativeCache
from repro.core.semantic_cache import CacheResult, SemanticCache
from repro.core.store_bank import StoreBank
from repro.core.vector_store import InMemoryVectorStore


class HierarchicalCache:
    def __init__(
        self,
        l1: GenerativeCache,
        l2: Optional[GenerativeCache] = None,
        peers: Optional[List[GenerativeCache]] = None,
        inclusive: bool = False,
        promote: bool = True,
        generative_across_levels: bool = True,
        fused: bool = True,
        device_decide: bool = True,
        router=None,
    ):
        self.l1 = l1
        self.l2 = l2
        self.peers = peers or []
        self.inclusive = inclusive
        self.promote = promote
        self.generative_across_levels = generative_across_levels
        # optional lane-visibility policy for sharded deployments: a callable
        # ``(queries, contexts) -> [n, L] bool`` mask; a False cell hides that
        # level's candidates from that query inside the device program (the
        # mask rides the fused dispatch — no per-shard host loop). Only the
        # sharded read tier consults it; host tiers ignore the knob.
        self.router = router
        # fused=True stacks the level stores into one StoreBank so a batched
        # lookup searches every level in ONE device dispatch; levels whose
        # stores cannot be banked (custom subclass, mixed dim, aliased
        # stores) transparently keep the per-level search loop.
        # device_decide=True additionally runs the whole read path — embed,
        # search, per-level thresholds + winner walk, and the LRU/LFU touch
        # scatter — as ONE device program (repro.core.read_path); levels with
        # customized decide logic fall back to the banked host-decide path.
        self.fused = fused
        self.device_decide = device_decide
        self._shared_bank: Optional[StoreBank] = None
        self._sharded_bank = None  # ShardedReadBank when a level is sharded

    def _levels(self):
        out = [("L1", self.l1)]
        if self.l2 is not None:
            out.append(("L2", self.l2))
        out.extend((f"L2-peer{i}", p) for i, p in enumerate(self.peers))
        return out

    def ensure_bank(self) -> Optional[StoreBank]:
        """Stack the level stores into one shared [L, cap, D] StoreBank (or
        return the current one if every level still points at its lane).

        Returns None — keeping the per-level search loop — when the levels
        cannot share a bank: fewer than two levels, a store subclass that
        overrides the search/join path, mixed dim/metric, or the same store
        object mounted at two levels (its lane view can only track one).
        A level whose store was swapped (e.g. ``load_store``) or adopted by
        another hierarchy triggers a re-adoption, which copies the stores'
        CURRENT lanes — never stale data."""
        caches = [c for _, c in self._levels()]
        stores = [c.store for c in caches]
        if len(stores) < 2:
            return None
        for c in caches:
            # the fused path replaces the cache-level retrieval hook too
            if type(c).search_candidates is not SemanticCache.search_candidates:
                return None
        for s in stores:
            if not isinstance(s, InMemoryVectorStore):
                return None
            if (
                type(s).search_batch is not InMemoryVectorStore.search_batch
                or type(s).join_candidates is not InMemoryVectorStore.join_candidates
            ):
                return None  # custom search semantics must keep running
        if len({id(s) for s in stores}) != len(stores):
            return None
        if len({s.dim for s in stores}) != 1:
            return None
        # per-lane metric tags cover mixed cosine/dot/euclidean hierarchies
        # in one bank; an unknown metric string keeps the per-level loop
        if any(s.metric not in ("cosine", "dot", "euclidean") for s in stores):
            return None
        bank = self._shared_bank
        if bank is not None and all(
            s._bank is bank and s._lane == li for li, s in enumerate(stores)
        ):
            return bank
        self._shared_bank = StoreBank.adopt(stores)
        return self._shared_bank

    def ensure_sharded_bank(self):
        """Build (or revalidate) the ``ShardedReadBank`` serving this
        hierarchy's mixed replicated/sharded deployment: levels backed by a
        ``ShardedVectorStore`` keep their key-sharded device lanes, hot
        levels backed by a stock ``InMemoryVectorStore`` are adopted into a
        bank replicated on every mesh device, and one collective program
        reads them all (repro.distributed.sharded_read).

        Returns None — keeping the single-host tiers — when no level is
        sharded, or when the levels cannot share one program: a customized
        cache/store subclass, stores on different meshes, the same store at
        two levels, mixed dim, or a metric outside cosine/dot."""
        from repro.distributed.sharded_read import ShardedReadBank
        from repro.distributed.sharded_store import ShardedVectorStore, _shard_axes

        caches = [c for _, c in self._levels()]
        stores = [c.store for c in caches]
        for c in caches:
            if type(c).search_candidates is not SemanticCache.search_candidates:
                return None
        members = []
        meshes = []
        for s in stores:
            if type(s) is ShardedVectorStore:
                members.append(("sh", s))
                meshes.append(s.mesh)
            elif (
                isinstance(s, InMemoryVectorStore)
                and type(s).search_batch is InMemoryVectorStore.search_batch
                and type(s).join_candidates is InMemoryVectorStore.join_candidates
            ):
                members.append(("rep", s))
            else:
                return None
        if not meshes:  # all-replicated hierarchy: ensure_bank covers it
            return None
        if len({id(m) for m in meshes}) != 1 or not _shard_axes(meshes[0]):
            return None
        if len({id(s) for s in stores}) != len(stores):
            return None
        if len({s.dim for s in stores}) != 1:
            return None
        if any(s.metric not in ("cosine", "dot") for s in stores):
            return None
        srb = self._sharded_bank
        if srb is not None and srb.intact(stores):
            return srb
        self._sharded_bank = ShardedReadBank(meshes[0], members)
        return self._sharded_bank

    # -- stale-if-error walk (degraded path; resilience subsystem) -------------

    def lookup_stale(
        self, queries, vecs, contexts, now=None, max_stale_s=None, l2_ok=None
    ):
        """Serve expired entries when every backend is down: walk the levels
        in hierarchy order (L1 > L2 > peers — the same priority a live
        lookup uses) and take the first level whose stale inventory clears
        that level's threshold for a row. ``l2_ok`` (per-row bools) carries
        the ``cache_l2`` privacy hint: a False row consults ONLY L1 — the
        degraded path must not leak a private query into shared levels. No
        promotion, no counter movement — see ``SemanticCache.lookup_stale``.
        Returns row -> CacheResult with the level name folded into
        ``level``."""
        out = {}
        for li, (name, cache) in enumerate(self._levels()):
            remaining = [
                r
                for r in range(len(queries))
                if r not in out and (li == 0 or l2_ok is None or l2_ok[r])
            ]
            if not remaining:
                continue
            thr = [
                cache.effective_threshold(queries[r], contexts[r]) for r in remaining
            ]
            sub_vecs = np.asarray(vecs, np.float32)[remaining]
            stales = (
                max_stale_s
                if max_stale_s is None or np.isscalar(max_stale_s)
                else [max_stale_s[r] for r in remaining]
            )
            found = cache.lookup_stale(
                [queries[r] for r in remaining], sub_vecs, thr,
                now=now, max_stale_s=stales,
            )
            for j, res in found.items():
                res.level = f"stale:{name}:{res.level.split(':', 1)[1]}"
                out[remaining[j]] = res
        return out

    # -- cross-level generative pool (§3 rule applied over every level) --------

    def _pool_candidates(self, level_matches: List[list]) -> List[tuple]:
        """Merge one query's per-level candidates into the generative pool:
        filter by the requesting client's t_single, dedupe across levels,
        best-first, capped at L1's max_sources (so N levels x k weak matches
        cannot clear t_combined when no single level would)."""
        pooled = []
        seen = set()
        for m in level_matches:
            for s, e in m:
                sig = (e.query, e.response[:64])
                if s > self.l1.t_single and sig not in seen:
                    seen.add(sig)
                    pooled.append((s, e))
        pooled.sort(key=lambda se: se[0], reverse=True)
        return pooled[: self.l1.max_sources]

    def lookup(
        self, query: str, context: Optional[dict] = None, vec: Optional[np.ndarray] = None
    ) -> CacheResult:
        t0 = time.perf_counter()
        if vec is None:
            vec = self.l1.embed(query)  # embed once; levels share the embedder space
        levels = self._levels()
        for name, cache in levels:
            res = cache.lookup(query, context, vec=vec)
            if res.hit:
                if self.promote and cache is not self.l1:
                    self.l1.insert(query, res.response, {"promoted_from": name}, vec=vec)
                    if self.inclusive and self.l2 is not None and cache is not self.l2:
                        # inclusive hierarchy: peer winners also land in our
                        # shared L2 (they came from a shared level, so the
                        # copy exposes nothing new)
                        self.l2.insert(query, res.response, {"promoted_from": name}, vec=vec)
                res.level = f"{name}:{res.level}"
                res.latency_s = time.perf_counter() - t0
                return res

        if self.generative_across_levels and len(levels) > 1:
            # pool candidates from every level and apply the generative rule
            pooled = self._pool_candidates([
                cache.store.search(vec, k=getattr(cache, "max_sources", 4))
                for _, cache in levels
            ])
            combined = float(sum(s for s, _ in pooled))
            if pooled and combined > self.l1.t_combined:
                from repro.core import synthesis

                response = synthesis.combine(query, pooled, self.l1.synthesis_mode, self.l1.summarizer)
                self.l1.insert(query, response, {"generative": True}, vec=vec)
                self.l1.stats.generative_hits += 1
                return CacheResult(
                    True, response, pooled[0][0], combined, True, pooled,
                    self.l1.effective_threshold(query, context),
                    time.perf_counter() - t0, "multi-level:generative",
                )
        res = CacheResult(False)
        res.latency_s = time.perf_counter() - t0
        return res

    def lookup_batch(
        self,
        queries: List[str],
        contexts: Optional[List[Optional[dict]]] = None,
        vecs: Optional[np.ndarray] = None,
        return_vecs: bool = False,
    ):
        """Serve B queries; the whole read path is ONE device program.

        Decision-identical to B sequential ``lookup`` calls against the same
        level snapshots: every level is searched once for the whole batch,
        each level's decision rule runs over its own candidates, and the
        first level in L1 -> L2 -> peers order that hits wins. All store
        mutations (L1 promotion of lower-level winners, per-level synthesized
        answers, cross-level synthesized answers) are deferred past the last
        decision and applied as ``add_batch`` scatters, so in-batch queries
        never observe each other.

        Read tiers, fastest eligible wins: (a0) the SHARDED fused program —
        when a level's store is key-sharded over a mesh, one collective
        ``shard_map`` dispatch embeds, searches replicated hot lanes and
        sharded cold lanes, exchanges only tiny [B, k] candidate sets,
        applies the router mask + decide + winner walk + counter touches on
        device (repro.distributed.sharded_read); (a) the single-host fused
        read program — embed forward, banked [L, cap, D] search, per-level
        decide masks, the L1>L2>peers winner walk, and the
        recency/frequency touch scatter in a single jitted dispatch, with
        host code only materializing ``CacheResult``s for decided winners
        and residual-miss pool rows; (b) the banked host-decide path (one
        fused search dispatch, decide on host) when a level customizes its
        decide rule; (c) the per-level search loop when stores cannot share
        a bank. ``return_vecs=True``
        additionally returns the [B, D] embeddings (serving reuses them for
        dedup/backfill without a second forward).
        """
        t0 = time.perf_counter()
        n = len(queries)
        if n == 0:
            empty = np.zeros((0, self.l1.embedder.dim), np.float32)
            return ([], empty) if return_vecs else []
        contexts = list(contexts) if contexts is not None else [None] * n
        levels = self._levels()
        # THE per-level candidate-count policy, shared by all three read
        # tiers (capacity cap only where the store exposes one — custom
        # stores without .capacity keep the uncapped per-level-loop k)
        ks = []
        for _, c in levels:
            k = max(getattr(c, "max_sources", 4), 1)
            cap = getattr(c.store, "capacity", None)
            ks.append(min(k, cap) if cap else k)
        # [n, L] per-query/per-level effective thresholds (host policy calls,
        # same call order as the per-level loop: levels outer, queries inner)
        thr = np.asarray(
            [
                [c.effective_threshold(q, ctx) for q, ctx in zip(queries, contexts)]
                for _, c in levels
            ],
            np.float64,
        ).T
        # sharded tier first: when any level's store is key-sharded over a
        # mesh, the whole hierarchy reads through ONE collective program
        srb = self.ensure_sharded_bank() if self.fused else None
        bank = self.ensure_bank() if (self.fused and srb is None) else None
        dec = None
        if (srb is not None or bank is not None) and self.device_decide:
            from repro.core import read_path

            specs = [
                read_path.level_spec(c, ks[li]) for li, (_, c) in enumerate(levels)
            ]
            if all(sp is not None for sp in specs):
                t0s = time.perf_counter()
                if srb is not None:
                    router = (
                        self.router(queries, contexts)
                        if self.router is not None else None
                    )
                    dec = srb.fused_read(
                        self.l1.embedder, queries, thr, specs,
                        vecs=vecs, router=router,
                    )
                else:
                    dec = read_path.fused_read(
                        bank, self.l1.embedder, queries, thr, specs, vecs=vecs
                    )
                # the program is indivisible, so search_time_s absorbs the
                # whole fused wall time (embed leg included) split evenly —
                # slightly broader than the host tiers' search-only share
                share = (time.perf_counter() - t0s) / len(levels)
                for _, c in levels:
                    c.stats.search_time_s += share
        if dec is not None:
            vecs = dec.vecs
            out, promotions, l2_copies, deferred = self._materialize_fused(
                queries, contexts, thr, levels, ks, dec
            )
        else:
            if vecs is None:
                vecs = self.l1.embed_batch(list(queries))
            vecs = np.asarray(vecs)
            out, promotions, l2_copies, deferred = self._decide_host(
                queries, contexts, thr, levels, ks, vecs, bank
            )
        # residual misses consult each level's host-RAM demotion tier, in the
        # same L1 > L2 > peers priority as tier 0 (host-side; the fused
        # dispatch above is untouched). A tier-1 winner promotes into its own
        # level's device lane, and — like any lower-level winner — into L1.
        for li, (name, cache) in enumerate(levels):
            rows = [i for i in range(n) if out[i] is None]
            if not rows:
                break
            for i, res in cache.consult_tier1(queries, vecs, thr[:, li], rows).items():
                res.level = f"{name}:{res.level}"
                if self.promote and cache is not self.l1:
                    promotions.append((i, res.response, name))
                    if self.inclusive and self.l2 is not None and cache is not self.l2:
                        l2_copies.append((i, res.response, name))
                out[i] = res
        self._apply_writebacks(queries, vecs, promotions, l2_copies, deferred)
        per_query_s = (time.perf_counter() - t0) / n
        for i in range(n):
            if out[i] is None:
                out[i] = CacheResult(False)
            out[i].latency_s = per_query_s
        return (out, np.asarray(vecs)) if return_vecs else out

    def _materialize_fused(self, queries, contexts, thr, levels, ks, dec):
        """Host stage of the fused read: turn the program's decision tensors
        into CacheResults, joining ONLY the rows that materialize (each
        query's winning level, plus every level for residual misses feeding
        the cross-level generative pool). Stats land where the sequential
        walk would have put them; touches already happened on device."""
        from repro.core import read_path

        n = len(queries)
        L = len(levels)
        winner = dec.winner
        # the sequential walk reaches level li only while every level above
        # missed — credit lookups accordingly (hits are credited by
        # _materialize_one on the winning level only)
        for li, (_, cache) in enumerate(levels):
            cache.stats.lookups += int(np.sum(winner >= li))
        need_pool = self.generative_across_levels and L > 1
        miss_rows = [i for i in range(n) if winner[i] >= L]
        rows_by_level: List[dict] = []
        for li, (_, cache) in enumerate(levels):
            rows = [i for i in range(n) if winner[i] == li]
            if need_pool:
                rows = rows + miss_rows
            rows_by_level.append(
                read_path.join_rows(
                    cache.store, dec.scores[:, li], dec.idx[:, li], rows, ks[li]
                )
            )
        out: List[Optional[CacheResult]] = [None] * n
        promotions: List[tuple] = []
        l2_copies: List[tuple] = []
        deferred: List[tuple] = []
        synth_memo: dict = {}  # duplicate in-batch queries synthesize once
        for i in range(n):
            li = int(winner[i])
            if li >= L:
                continue
            name, cache = levels[li]
            res, _ = cache._materialize_one(
                queries[i], float(thr[i, li]), rows_by_level[li][i],
                True, bool(dec.generative[i, li]), lazy_synth=True,
            )
            if res.generative and res.response is None:
                key = (id(cache), queries[i])
                if key not in synth_memo:
                    from repro.core import synthesis

                    synth_memo[key] = synthesis.combine(
                        queries[i], res.sources, cache.synthesis_mode, cache.summarizer
                    )
                    if cache.cache_synthesized:
                        deferred.append((cache, i, synth_memo[key], {"generative": True}))
                res.response = synth_memo[key]
            if self.promote and cache is not self.l1:
                promotions.append((i, res.response, name))
                if self.inclusive and self.l2 is not None and cache is not self.l2:
                    l2_copies.append((i, res.response, name))
            res.level = f"{name}:{res.level}"
            out[i] = res
        if need_pool:
            for i in miss_rows:
                pooled = self._pool_candidates(
                    [rows_by_level[li].get(i, []) for li in range(L)]
                )
                combined = float(sum(s for s, _ in pooled))
                if pooled and combined > self.l1.t_combined:
                    key = ("multi-level", queries[i])
                    if key not in synth_memo:
                        from repro.core import synthesis

                        synth_memo[key] = synthesis.combine(
                            queries[i], pooled, self.l1.synthesis_mode, self.l1.summarizer
                        )
                        deferred.append((self.l1, i, synth_memo[key], {"generative": True}))
                    self.l1.stats.generative_hits += 1
                    out[i] = CacheResult(
                        True, synth_memo[key], pooled[0][0], combined, True, pooled,
                        self.l1.effective_threshold(queries[i], contexts[i]),
                        0.0, "multi-level:generative",
                    )
        return out, promotions, l2_copies, deferred

    def _decide_host(self, queries, contexts, thr, levels, ks, vecs, bank):
        """The banked host-decide path (one fused search dispatch, decisions
        in host Python) and the per-level loop fallback — the pre-fused-read
        pipeline, kept for levels/stores with customized semantics and as
        the benchmark baseline."""
        n = len(queries)
        level_results: List[List[CacheResult]] = []
        level_matches: List[list] = []
        if bank is not None:
            # banked path: every level's candidates come out of ONE stacked
            # [L, cap, D] x [B, D] top-k dispatch; per-level decision rules
            # (and the L1-beats-L2-beats-peers walk below) run host-side on
            # the returned scores — no extra dispatches
            t0s = time.perf_counter()
            s_all, i_all = bank.search_lanes(vecs, max(ks))  # [B, L, k_fused]
            search_share = (time.perf_counter() - t0s) / len(levels)
            for li, (_, cache) in enumerate(levels):
                # touch=False equivalent: the join skips the recency bump;
                # counters move below, only on levels the walk would probe
                matches = cache.store.join_candidates(
                    s_all[:, li], i_all[:, li], touch=False
                )
                if ks[li] < max(ks):  # this level's own k, like its solo search
                    matches = [m[: ks[li]] for m in matches]
                cache.stats.search_time_s += search_share
                results, _ = cache._decide_batch(queries, thr[:, li], matches, lazy_synth=True)
                level_results.append(results)
                level_matches.append(matches)
        else:
            for li, (_, cache) in enumerate(levels):
                # touch=False: every level is probed speculatively here, but the
                # sequential walk stops at the winning level — recency/frequency
                # bookkeeping is applied after winners resolve, only on levels
                # the walk would actually have searched (eviction hygiene)
                matches = cache.search_candidates(vecs, k=ks[li], touch=False)
                # lazy_synth: only levels that win a query synthesize (below)
                results, _ = cache._decide_batch(queries, thr[:, li], matches, lazy_synth=True)
                level_results.append(results)
                level_matches.append(matches)

        out: List[Optional[CacheResult]] = [None] * n
        winner_idx = [len(levels)] * n  # level index that served each query
        promotions: List[tuple] = []  # (query index, response, from_name)
        l2_copies: List[tuple] = []  # inclusive: peer winners mirrored into L2
        synth_memo: dict = {}  # duplicate in-batch queries synthesize once
        # (cache, index, response, meta): deferred writebacks. A level's
        # synthesized answer only lands if that level actually won the query —
        # sequentially, levels below a hit are never probed.
        deferred: List[tuple] = []
        for i in range(n):
            for li, ((name, cache), results) in enumerate(zip(levels, level_results)):
                res = results[i]
                if res.hit:
                    if res.generative and res.response is None:
                        key = (id(cache), queries[i])
                        if key not in synth_memo:
                            from repro.core import synthesis

                            synth_memo[key] = synthesis.combine(
                                queries[i], res.sources, cache.synthesis_mode, cache.summarizer
                            )
                            if cache.cache_synthesized:
                                deferred.append((cache, i, synth_memo[key], {"generative": True}))
                        res.response = synth_memo[key]
                    if self.promote and cache is not self.l1:
                        promotions.append((i, res.response, name))
                        if self.inclusive and self.l2 is not None and cache is not self.l2:
                            l2_copies.append((i, res.response, name))
                    res.level = f"{name}:{res.level}"
                    winner_idx[i] = li
                    out[i] = res
                    break

        # stats fidelity: the sequential walk stops at the winning level, so
        # levels below it were never looked up — retract the counters the
        # all-levels batch decision provisionally credited them with
        for li, ((_, cache), results) in enumerate(zip(levels, level_results)):
            cache.stats.lookups += sum(1 for i in range(n) if winner_idx[i] >= li)
            for i in range(n):
                if winner_idx[i] < li and results[i].hit:
                    cache.stats.hits -= 1
                    if results[i].generative:
                        cache.stats.generative_hits -= 1

        # eviction hygiene: level li's LRU/LFU counters only see query i's
        # candidates when the sequential walk would have probed level li,
        # i.e. every level above it missed (winner_idx[i] >= li)
        for li, ((_, cache), matches_l) in enumerate(zip(levels, level_matches)):
            cache.touch(
                [e.key for i in range(n) if winner_idx[i] >= li
                 for _, e in matches_l[i] if hasattr(e, "key")]
            )

        if self.generative_across_levels and len(levels) > 1:
            for i in range(n):
                if out[i] is not None:
                    continue
                pooled = self._pool_candidates([m[i] for m in level_matches])
                combined = float(sum(s for s, _ in pooled))
                if pooled and combined > self.l1.t_combined:
                    key = ("multi-level", queries[i])
                    if key not in synth_memo:
                        from repro.core import synthesis

                        synth_memo[key] = synthesis.combine(
                            queries[i], pooled, self.l1.synthesis_mode, self.l1.summarizer
                        )
                        deferred.append((self.l1, i, synth_memo[key], {"generative": True}))
                    response = synth_memo[key]
                    self.l1.stats.generative_hits += 1
                    out[i] = CacheResult(
                        True, response, pooled[0][0], combined, True, pooled,
                        self.l1.effective_threshold(queries[i], contexts[i]),
                        0.0, "multi-level:generative",
                    )
        return out, promotions, l2_copies, deferred

    def _apply_writebacks(self, queries, vecs, promotions, l2_copies, deferred):
        """Batched writebacks: one scatter per destination cache. Dedupe
        repeated in-batch queries first — sequentially only the first
        occurrence writes (later ones would hit the fresh L1 copy), and a
        coalesced batch of duplicates must not flush L1 with clones."""

        def _dedupe(items: List[tuple]) -> List[tuple]:
            seen, out = set(), []
            for it in items:
                key = (queries[it[0]], it[1])
                if key not in seen:
                    seen.add(key)
                    out.append(it)
            return out

        promotions = _dedupe(promotions)
        l2_copies = _dedupe(l2_copies)
        if promotions:
            self.l1.insert_batch(
                [queries[i] for i, _, _ in promotions],
                [r for _, r, _ in promotions],
                metas=[{"promoted_from": name} for _, _, name in promotions],
                vecs=np.stack([vecs[i] for i, _, _ in promotions]),
            )
        if l2_copies:
            self.l2.insert_batch(
                [queries[i] for i, _, _ in l2_copies],
                [r for _, r, _ in l2_copies],
                metas=[{"promoted_from": name} for _, _, name in l2_copies],
                vecs=np.stack([vecs[i] for i, _, _ in l2_copies]),
            )
        by_cache: dict = {}
        for cache, i, r, meta in deferred:
            by_cache.setdefault(id(cache), (cache, []))[1].append((i, r, meta))
        for cache, items in by_cache.values():
            items = _dedupe(items)
            cache.insert_batch(
                [queries[i] for i, _, _ in items],
                [r for _, r, _ in items],
                metas=[m for _, _, m in items],
                vecs=np.stack([vecs[i] for i, _, _ in items]),
            )

    def insert(
        self,
        query: str,
        response: str,
        meta: Optional[dict] = None,
        cache_l1: bool = True,
        cache_l2: bool = True,
        vec: Optional[np.ndarray] = None,
        ttl_s: Optional[float] = None,
    ) -> None:
        """Privacy hints (§4): callers may exclude either level.

        ``cache_l2=False`` is absolute — inclusivity never copies a private
        entry into the shared level.
        """
        if vec is None:
            vec = self.l1.embed(query)
        if cache_l1:
            self.l1.insert(query, response, meta, vec=vec, ttl_s=ttl_s)
        if cache_l2 and self.l2 is not None:
            self.l2.insert(query, response, meta, vec=vec, ttl_s=ttl_s)

    def insert_batch(
        self,
        queries: List[str],
        responses: List[str],
        metas: Optional[List[Optional[dict]]] = None,
        cache_l1: bool = True,
        cache_l2: bool = True,
        vecs: Optional[np.ndarray] = None,
        ttls: Optional[List[Optional[float]]] = None,
    ) -> None:
        """Batched ``insert``: one embed forward + one scatter per level the
        privacy hints allow (same veto semantics as ``insert``)."""
        if not queries:
            return
        if vecs is None:
            vecs = self.l1.embed_batch(list(queries))
        vecs = np.asarray(vecs)
        if cache_l1:
            self.l1.insert_batch(list(queries), list(responses), metas, vecs=vecs, ttls=ttls)
        if cache_l2 and self.l2 is not None:
            self.l2.insert_batch(list(queries), list(responses), metas, vecs=vecs, ttls=ttls)

    def clear(self, older_than: Optional[float] = None) -> int:
        """Prune every level (tier-1 rings included). Returns total entries
        dropped across levels."""
        return sum(cache.clear(older_than=older_than) for _, cache in self._levels())
