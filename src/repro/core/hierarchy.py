"""Hierarchical / cooperative caching (§4, Figure 1).

Client-local L1 caches front shared L2 caches; L2 caches cooperate with peer
L2s. On a lower-level hit the query-response pair is promoted into the upper
levels (the paper: "If the L2 cache is able to satisfy the request with a
query-response pair q1, q1 is then stored in the L1 cache"). The same
similarity threshold t_s(1) (the requesting client's effective threshold) is
used at every level. Privacy hints let users keep personal entries out of
the shared levels (§4).

On the TPU mesh this topology maps to pod-local L1 shards and cross-pod L2
exchange (DESIGN.md §3); this module is the level-coordination logic, shared
by the host-side client and the mesh-sharded store.
"""
from __future__ import annotations

import time
from typing import List, Optional

import numpy as np

from repro.core.generative_cache import GenerativeCache
from repro.core.semantic_cache import CacheResult


class HierarchicalCache:
    def __init__(
        self,
        l1: GenerativeCache,
        l2: Optional[GenerativeCache] = None,
        peers: Optional[List[GenerativeCache]] = None,
        inclusive: bool = False,
        promote: bool = True,
        generative_across_levels: bool = True,
    ):
        self.l1 = l1
        self.l2 = l2
        self.peers = peers or []
        self.inclusive = inclusive
        self.promote = promote
        self.generative_across_levels = generative_across_levels

    def _levels(self):
        out = [("L1", self.l1)]
        if self.l2 is not None:
            out.append(("L2", self.l2))
        out.extend((f"L2-peer{i}", p) for i, p in enumerate(self.peers))
        return out

    def lookup(
        self, query: str, context: Optional[dict] = None, vec: Optional[np.ndarray] = None
    ) -> CacheResult:
        t0 = time.perf_counter()
        if vec is None:
            vec = self.l1.embed(query)  # embed once; levels share the embedder space
        levels = self._levels()
        for name, cache in levels:
            res = cache.lookup(query, context, vec=vec)
            if res.hit:
                if self.promote and cache is not self.l1:
                    self.l1.insert(query, res.response, {"promoted_from": name}, vec=vec)
                res.level = f"{name}:{res.level}"
                res.latency_s = time.perf_counter() - t0
                return res

        if self.generative_across_levels and len(levels) > 1:
            # pool candidates from every level and apply the generative rule
            pooled = []
            seen = set()
            for _, cache in levels:
                for s, e in cache.store.search(vec, k=cache.max_sources if hasattr(cache, "max_sources") else 4):
                    sig = (e.query, e.response[:64])
                    if s > self.l1.t_single and sig not in seen:
                        seen.add(sig)
                        pooled.append((s, e))
            combined = float(sum(s for s, _ in pooled))
            if pooled and combined > self.l1.t_combined:
                from repro.core import synthesis

                response = synthesis.combine(query, pooled, self.l1.synthesis_mode, self.l1.summarizer)
                self.l1.insert(query, response, {"generative": True}, vec=vec)
                self.l1.stats.generative_hits += 1
                return CacheResult(
                    True, response, pooled[0][0], combined, True, pooled,
                    self.l1.effective_threshold(query, context),
                    time.perf_counter() - t0, "multi-level:generative",
                )
        res = CacheResult(False)
        res.latency_s = time.perf_counter() - t0
        return res

    def insert(
        self,
        query: str,
        response: str,
        meta: Optional[dict] = None,
        cache_l1: bool = True,
        cache_l2: bool = True,
        vec: Optional[np.ndarray] = None,
    ) -> None:
        """Privacy hints (§4): callers may exclude either level."""
        if vec is None:
            vec = self.l1.embed(query)
        if cache_l1:
            self.l1.insert(query, response, meta, vec=vec)
        if cache_l2 and self.l2 is not None:
            self.l2.insert(query, response, meta, vec=vec)
        elif self.inclusive and cache_l1 and self.l2 is not None:
            self.l2.insert(query, response, meta, vec=vec)
