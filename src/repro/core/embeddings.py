"""Pluggable embedding models (the paper's "embeddings manager").

The paper's measured default is facebook/contriever-msmarco run locally; we
implement that architecture as a JAX encoder (random-init offline — the
similarity *math* and performance profile are what the cache exercises).

For functional end-to-end tests we also ship ``NgramHashEmbedder``: a
deterministic character-n-gram feature-hashing embedder whose cosine
similarity genuinely tracks text overlap, so semantic-cache behavior
(hit/miss/generative-combination) is observable without pretrained weights.

New models plug in by subclassing EmbeddingModel and registering.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.contriever import CONTRIEVER_MSMARCO, E5_LARGE_V2, EncoderConfig
from repro.configs.contriever import smoke as contriever_smoke
from repro.core.tokenizer import HashTokenizer


def _identity_forward(vecs):
    """Device leg of the default fused forward: the embedding was computed
    host-side, so the program just consumes the uploaded [B, D] block.
    Module-level so every host embedder of one dim shares a jit cache key."""
    return vecs


class EmbeddingModel:
    """Interface: embed a batch of texts into L2-normalized vectors."""

    name: str = "base"
    dim: int = 0

    def embed(self, texts: List[str]) -> np.ndarray:  # [n, dim], unit-norm
        raise NotImplementedError

    def embed_one(self, text: str) -> np.ndarray:
        return self.embed([text])[0]

    def embed_batch(self, texts: List[str]) -> np.ndarray:
        """Batched entry point for the cache pipeline.

        Semantically identical to ``embed``; models whose forward is jitted
        override/benefit from shape bucketing so one device dispatch covers
        the whole request batch instead of one per query.
        """
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        return self.embed(texts)

    # -- zero-host-hop read path (repro.core.read_path) -------------------------

    def fused_forward(self):
        """A jit-composable split of ``embed_batch`` for the fused read
        program: ``(prepare, forward)`` where ``prepare(texts) -> (args, n,
        B)`` runs host-side (tokenize / featurize, B power-of-two bucketed
        >= n) and ``forward(*args) -> [B, dim]`` is traced INTO the read
        program, so embed -> search -> decide -> touch is one device
        dispatch. The default runs the whole embedding host-side in
        ``prepare`` (models without a device forward) and uploads the [B, D]
        block once — still zero hops between embed and decide. The pair is
        cached per instance: a stable ``forward`` identity keys the
        program's compile cache."""
        if getattr(self, "_fused_fwd", None) is None:

            def prepare(texts: List[str]):
                from repro.core.store_bank import pad_to_bucket

                vecs, n = pad_to_bucket(
                    np.asarray(self.embed_batch(list(texts)), np.float32)
                )
                return (vecs,), n, vecs.shape[0]

            self._fused_fwd = (prepare, _identity_forward)
        return self._fused_fwd


# ---------------------------------------------------------------------------
# N-gram feature-hash embedder (deterministic, overlap-sensitive)
# ---------------------------------------------------------------------------


class NgramHashEmbedder(EmbeddingModel):
    name = "ngram-hash"

    def __init__(self, dim: int = 256):
        self.dim = dim
        self.tok = HashTokenizer()

    def embed(self, texts: List[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            for h, w in self.tok.ngrams(t):
                idx = h % self.dim
                sign = 1.0 if (h >> 17) & 1 else -1.0
                out[i, idx] += sign * w
        norms = np.linalg.norm(out, axis=1, keepdims=True)
        return out / np.maximum(norms, 1e-9)


# ---------------------------------------------------------------------------
# Contriever-style JAX encoder
# ---------------------------------------------------------------------------


def _init_encoder(cfg: EncoderConfig, key) -> dict:
    k = iter(jax.random.split(key, 4 + 8 * cfg.num_layers))
    d, H, F = cfg.d_model, cfg.num_heads, cfg.d_ff
    std = d ** -0.5

    def dense(shape, fan_in=None):
        fan_in = fan_in or shape[0]
        return jax.random.normal(next(k), shape, jnp.float32) * (fan_in ** -0.5)

    params = {
        "tok_embed": jax.random.normal(next(k), (cfg.vocab_size, d), jnp.float32) * std,
        "pos_embed": jax.random.normal(next(k), (cfg.max_seq_len, d), jnp.float32) * std,
        "ln_embed": {"w": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "layers": [],
    }
    for _ in range(cfg.num_layers):
        params["layers"].append(
            {
                "wq": dense((d, d)),
                "wk": dense((d, d)),
                "wv": dense((d, d)),
                "wo": dense((d, d)),
                "ln1": {"w": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wi": dense((d, F)),
                "bi": jnp.zeros((F,)),
                "wo2": dense((F, d), F),
                "bo2": jnp.zeros((d,)),
                "ln2": {"w": jnp.ones((d,)), "b": jnp.zeros((d,))},
            }
        )
    return params


def _layer_norm(x, p, eps):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]


def _encoder_forward(params, cfg: EncoderConfig, ids, mask):
    """BERT-style post-LN encoder with mean pooling. ids [n,L], mask [n,L]."""
    n, L = ids.shape
    H = cfg.num_heads
    dh = cfg.d_model // H
    x = params["tok_embed"][ids] + params["pos_embed"][:L][None]
    x = _layer_norm(x, params["ln_embed"], cfg.norm_eps)
    attn_bias = (1.0 - mask)[:, None, None, :] * -1e9  # [n,1,1,L]
    for lp in params["layers"]:
        q = (x @ lp["wq"]).reshape(n, L, H, dh)
        k_ = (x @ lp["wk"]).reshape(n, L, H, dh)
        v = (x @ lp["wv"]).reshape(n, L, H, dh)
        s = jnp.einsum("nqhd,nkhd->nhqk", q, k_) / (dh ** 0.5) + attn_bias
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("nhqk,nkhd->nqhd", w, v).reshape(n, L, cfg.d_model)
        x = _layer_norm(x + o @ lp["wo"], lp["ln1"], cfg.norm_eps)
        h = jax.nn.gelu(x @ lp["wi"] + lp["bi"])
        x = _layer_norm(x + h @ lp["wo2"] + lp["bo2"], lp["ln2"], cfg.norm_eps)
    # mean pooling over valid tokens (contriever)
    pooled = jnp.sum(x * mask[..., None], axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0
    )
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)


class ContrieverEncoder(EmbeddingModel):
    """Mean-pooled transformer bi-encoder in JAX (contriever architecture)."""

    def __init__(self, cfg: EncoderConfig = CONTRIEVER_MSMARCO, seed: int = 0):
        self.cfg = cfg
        self.name = cfg.name
        self.dim = cfg.d_model
        self.tok = HashTokenizer(vocab_size=cfg.vocab_size, max_len=cfg.max_seq_len)
        self.params = _init_encoder(cfg, jax.random.PRNGKey(seed))
        self._fwd = jax.jit(lambda p, ids, mask: _encoder_forward(p, cfg, ids, mask))

    @staticmethod
    def _bucket(n: int, start: int) -> int:
        b = start
        while b < n:
            b *= 2
        return b

    def embed(self, texts: List[str]) -> np.ndarray:
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        ids, mask = self.tok.encode_batch(texts)
        # pad both L and B to power-of-two buckets to bound recompilation:
        # the [B, L] forward then compiles O(log B * log L) variants total and
        # a request batch of any size rides one jitted dispatch.
        n, L = ids.shape
        Lb = self._bucket(L, 8)
        Bb = self._bucket(n, 1)
        if (Bb - n) or (Lb - L):
            ids = np.pad(ids, ((0, Bb - n), (0, Lb - L)))
            mask = np.pad(mask, ((0, Bb - n), (0, Lb - L)))
        return np.asarray(self._fwd(self.params, ids, mask))[:n]

    def fused_forward(self):
        """Real in-program forward: ``prepare`` only tokenizes (host), the
        encoder itself is traced into the fused read program — token ids in,
        decisions out, with the [B, D] embedding never leaving the device.
        Params ride as a jit argument (not a baked constant), so the program
        compiles once per shape bucket, not per weight update."""
        if getattr(self, "_fused_fwd", None) is None:
            cfg = self.cfg

            def forward(params, ids, mask):
                return _encoder_forward(params, cfg, ids, mask)

            def prepare(texts: List[str]):
                ids, mask = self.tok.encode_batch(texts)
                n, L = ids.shape
                Lb = self._bucket(L, 8)
                Bb = self._bucket(n, 1)
                if (Bb - n) or (Lb - L):
                    ids = np.pad(ids, ((0, Bb - n), (0, Lb - L)))
                    mask = np.pad(mask, ((0, Bb - n), (0, Lb - L)))
                return (self.params, ids, mask), n, Bb

            self._fused_fwd = (prepare, forward)
        return self._fused_fwd


# ---------------------------------------------------------------------------
# Simulated remote models (the paper's OpenAI embedding endpoints)
# ---------------------------------------------------------------------------


class SimulatedRemoteEmbedder(EmbeddingModel):
    """Wraps a local embedder with the paper's remote-call profile:
    network latency + per-token monetary cost (Fig 7 / §2 discussion)."""

    def __init__(self, base: EmbeddingModel, name: str, latency_s: float, usd_per_mtok: float):
        self.base = base
        self.name = name
        self.dim = base.dim
        self.latency_s = latency_s
        self.usd_per_mtok = usd_per_mtok
        self.total_cost = 0.0

    def embed(self, texts: List[str]) -> np.ndarray:
        time.sleep(self.latency_s)  # simulated RTT
        self.total_cost += sum(len(t.split()) for t in texts) * self.usd_per_mtok / 1e6
        return self.base.embed(texts)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], EmbeddingModel]] = {}


def register(name: str, factory: Callable[[], EmbeddingModel]) -> None:
    _REGISTRY[name] = factory


def get_embedder(name: str) -> EmbeddingModel:
    if name not in _REGISTRY:
        raise KeyError(f"unknown embedder {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


register("ngram-hash", lambda: NgramHashEmbedder())
register("contriever-msmarco", lambda: ContrieverEncoder(CONTRIEVER_MSMARCO))
register("e5-large-v2", lambda: ContrieverEncoder(E5_LARGE_V2))
register("contriever-smoke", lambda: ContrieverEncoder(contriever_smoke()))
# the paper's three OpenAI endpoints, simulated with their latency ordering
register(
    "text-embedding-ada-002",
    lambda: SimulatedRemoteEmbedder(NgramHashEmbedder(1536), "text-embedding-ada-002", 0.05, 100.0),
)
register(
    "text-embedding-3-small",
    lambda: SimulatedRemoteEmbedder(NgramHashEmbedder(1536), "text-embedding-3-small", 0.06, 20.0),
)
register(
    "text-embedding-3-large",
    lambda: SimulatedRemoteEmbedder(NgramHashEmbedder(3072), "text-embedding-3-large", 0.08, 130.0),
)
