"""Deterministic offline tokenizer for the embedding encoder.

No external vocabularies are available offline, so this is a stable
feature-hashing word/byte tokenizer: words map to hashed ids in
[256, vocab), rare/unknown byte content falls back to byte ids [0, 256).
Deterministic across processes (uses blake2, not python hash()).
"""
from __future__ import annotations

import hashlib
import re
from typing import List

import numpy as np

_WORD_RE = re.compile(r"\w+|[^\w\s]", re.UNICODE)

_STOPWORDS = frozenset(
    "a an the is are was were be been being what which who whom how why when where "
    "do does did can could would should shall will may might must i you he she it we "
    "they me my your his her its our their of to in on at by for with about against "
    "and or not no nor so if then else as that this these those there here am please "
    "tell give describe explain me".split()
)


def _hash_word(word: str, vocab_size: int) -> int:
    h = hashlib.blake2b(word.encode("utf-8"), digest_size=8).digest()
    return 256 + int.from_bytes(h, "little") % (vocab_size - 256)


class HashTokenizer:
    """Stable word-level feature-hash tokenizer."""

    def __init__(self, vocab_size: int = 30522, max_len: int = 512, lowercase: bool = True):
        self.vocab_size = vocab_size
        self.max_len = max_len
        self.lowercase = lowercase
        self.pad_id = 0
        self.cls_id = 1

    def encode(self, text: str) -> List[int]:
        if self.lowercase:
            text = text.lower()
        ids = [self.cls_id]
        for w in _WORD_RE.findall(text)[: self.max_len - 1]:
            ids.append(_hash_word(w, self.vocab_size))
        return ids

    def encode_batch(self, texts: List[str]) -> tuple:
        """Returns (ids [n, L] int32, mask [n, L] f32) padded to the longest."""
        encoded = [self.encode(t) for t in texts]
        L = max(8, max(len(e) for e in encoded))
        ids = np.zeros((len(texts), L), np.int32)
        mask = np.zeros((len(texts), L), np.float32)
        for i, e in enumerate(encoded):
            ids[i, : len(e)] = e
            mask[i, : len(e)] = 1.0
        return ids, mask

    def ngrams(self, text: str, n_lo: int = 3, n_hi: int = 5) -> List[tuple]:
        """(hash, weight) features for the projection embedder: character
        n-grams (longer = heavier), content-word unigrams, and content-word
        bigrams. Function words are skipped at the word level so short
        template queries ("what is X?") don't dominate the content words."""
        if self.lowercase:
            text = text.lower()
        text = re.sub(r"\s+", " ", text.strip())
        out = []
        for n in range(n_lo, n_hi + 1):
            w = 0.15 * n  # char-grams give typo robustness; content words dominate
            for i in range(max(0, len(text) - n + 1)):
                g = text[i : i + n]
                h = hashlib.blake2b(g.encode("utf-8"), digest_size=8).digest()
                out.append((int.from_bytes(h, "little"), w))
        content = [w for w in _WORD_RE.findall(text) if w not in _STOPWORDS]
        for w_ in content:
            h = hashlib.blake2b(("w:" + w_).encode("utf-8"), digest_size=8).digest()
            out.append((int.from_bytes(h, "little"), 10.0))
        for a, b in zip(content, content[1:]):
            h = hashlib.blake2b(f"b:{a} {b}".encode("utf-8"), digest_size=8).digest()
            out.append((int.from_bytes(h, "little"), 12.0))
        return out
