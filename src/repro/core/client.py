"""Enhanced client for GenerativeCache (§5).

Coordinates multiple LLM backends behind one interface with the cache
integrated: embed -> cache lookup -> hit: return / miss: dispatch to a
backend, charge its cost, insert the answer.

The request path itself lives in ``repro.serving.service.CacheService``
(async-first: ``submit(CacheRequest) -> Future[CacheResponse]`` with
priority/deadline scheduling and admission control). This client is the
thin synchronous facade kept for compatibility: ``query`` /
``complete_batch`` build ``CacheRequest`` envelopes and run them inline
through ``CacheService.complete``; ``query_many`` / ``broadcast`` ride the
service's scheduler so concurrent dispatch shares one embed forward and
one backend fan-out.

Cost optimization knobs from §3.1/§5.3: model selection (serve from cheaper
models while the user is satisfied, escalate on dissatisfaction), max_tokens
limits, and the feedback/cost controllers servoing t_s.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.adaptive import (
    DEFAULT_PRICE_TABLE,
    CostController,
    ModelCostInfo,
    QualityRateController,
    ThresholdPolicy,
)
from repro.core.generative_cache import GenerativeCache
from repro.core.hierarchy import HierarchicalCache
from repro.core.request import CacheRequest, CacheResponse
from repro.core.semantic_cache import CacheResult
from repro.resilience.breaker import CircuitBreaker
from repro.resilience.errors import AllBackendsFailed, BackendFailure
from repro.resilience.retry import RetryBudget, RetryPolicy


def accepts_kwarg(cls, method_name: str, kwarg: str) -> bool:
    """Does ``cls.<method_name>`` declare ``kwarg``? Cached in the class's
    OWN dict, so a subclass overriding the method is re-probed on its own
    signature instead of inheriting its parent's cached answer. Used to
    call newer keyword arguments (``deadlines``, ``return_vecs``)
    compatibly past subclasses written against an older signature."""
    cache_attr = f"_accepts_{kwarg}_cached"
    cached = cls.__dict__.get(cache_attr)
    if cached is None:
        import inspect

        try:
            cached = kwarg in inspect.signature(getattr(cls, method_name)).parameters
        except (TypeError, ValueError):
            cached = False
        setattr(cls, cache_attr, cached)
    return cached


@dataclass
class LLMResponse:
    text: str
    model: str
    tokens_in: int = 0
    tokens_out: int = 0
    latency_s: float = 0.0
    cost_usd: float = 0.0
    # the backend canceled this generation because its deadline passed
    # mid-flight (text holds whatever partial decode existed); the service
    # maps it to a typed DEADLINE_EXCEEDED response and never caches it
    expired: bool = False


class LLMBackend:
    """Interface for a model endpoint."""

    name: str = "llm"
    # tri-state deadline capability: None = auto-detect from the
    # generate_batch signature; True/False = explicit declaration (set True
    # on wrappers that forward **kwargs to a deadline-aware backend)
    supports_deadlines: Optional[bool] = None

    def generate(self, prompt: str, max_tokens: int = 256, temperature: float = 0.0) -> LLMResponse:
        raise NotImplementedError

    def generate_batch(
        self, prompts: Sequence[str], max_tokens: int = 256, temperature: float = 0.0
    ) -> List[LLMResponse]:
        """Serve a batch of prompts. Backends that batch natively (e.g. the
        continuous-batching engine) override this; the default loops.
        Deadline-aware backends accept an extra ``deadlines`` kwarg
        (absolute perf_counter stamps per prompt) and mark responses whose
        deadline passed mid-generation ``expired=True`` — the dispatcher
        only passes it to backends whose signature declares it."""
        return [self.generate(p, max_tokens, temperature) for p in prompts]


class MockLLM(LLMBackend):
    """Deterministic offline backend with a configurable latency/price profile."""

    def __init__(
        self,
        name: str = "mock-llm",
        latency_s: float = 0.0,
        responder: Optional[Callable[[str], str]] = None,
        fail: bool = False,
    ):
        self.name = name
        self.latency_s = latency_s
        self.responder = responder or (lambda p: f"[{name}] answer to: {p}")
        self.fail = fail
        self.calls = 0

    def generate(self, prompt: str, max_tokens: int = 256, temperature: float = 0.0) -> LLMResponse:
        if self.fail:
            raise ConnectionError(f"{self.name} unresponsive")
        t0 = time.perf_counter()
        if self.latency_s:
            time.sleep(self.latency_s)
        self.calls += 1
        text = self.responder(prompt)
        words = text.split()
        if len(words) > max_tokens:
            text = " ".join(words[:max_tokens])
        return LLMResponse(
            text, self.name, tokens_in=len(prompt.split()), tokens_out=min(len(words), max_tokens),
            latency_s=time.perf_counter() - t0,
        )

    def generate_batch(
        self, prompts: Sequence[str], max_tokens: int = 256, temperature: float = 0.0,
        deadlines: Optional[Sequence[Optional[float]]] = None,
    ) -> List[LLMResponse]:
        # batched endpoint semantics: the batch travels together, so the
        # simulated RTT is paid once, not once per prompt
        if self.fail:
            raise ConnectionError(f"{self.name} unresponsive")
        t0 = time.perf_counter()
        if self.latency_s:
            time.sleep(self.latency_s)
        deadlines = deadlines if deadlines is not None else [None] * len(prompts)
        now = time.perf_counter()
        out = []
        for prompt, deadline_t in zip(prompts, deadlines):
            if deadline_t is not None and now > deadline_t:
                # deadline passed while the batch was in flight: canceled
                out.append(LLMResponse("", self.name, latency_s=now - t0, expired=True))
                continue
            self.calls += 1
            text = self.responder(prompt)
            words = text.split()
            if len(words) > max_tokens:
                text = " ".join(words[:max_tokens])
            out.append(LLMResponse(
                text, self.name, tokens_in=len(prompt.split()),
                tokens_out=min(len(words), max_tokens),
                latency_s=time.perf_counter() - t0,
            ))
        return out


@dataclass
class ClientResult:
    text: str
    from_cache: bool
    cache_result: Optional[CacheResult]
    llm_response: Optional[LLMResponse]
    model: str
    cost_usd: float
    latency_s: float
    request_id: int


@dataclass
class ClientStats:
    requests: int = 0
    cache_hits: int = 0
    llm_calls: int = 0
    llm_errors: int = 0
    retries: int = 0  # backend calls repeated after a failure (same backend)
    breaker_trips: int = 0  # closed/half-open -> open transitions
    breaker_open_skips: int = 0  # backends skipped without a call (fast-fail)
    all_backends_failed: int = 0  # failover walks that exhausted every backend
    total_cost_usd: float = 0.0
    total_latency_s: float = 0.0

    @property
    def avg_cost(self) -> float:
        return self.total_cost_usd / self.requests if self.requests else 0.0


class EnhancedClient:
    def __init__(
        self,
        cache: Optional[GenerativeCache] = None,
        hierarchy: Optional[HierarchicalCache] = None,
        policy: Optional[ThresholdPolicy] = None,
        price_table: Optional[Dict[str, ModelCostInfo]] = None,
        quality_target: float = 0.8,
        target_cost_per_request: Optional[float] = None,
        max_workers: int = 8,  # kept for signature compat; the service's schedulers replaced the pool
        retry_policy: Optional[RetryPolicy] = None,
        retry_budget: Optional[RetryBudget] = None,
        breaker_factory: Optional[Callable[[str], CircuitBreaker]] = None,
    ):
        if policy is not None:
            self.policy = policy
        elif cache is not None and cache.policy is not None:
            self.policy = cache.policy
        else:
            # inherit the cache's static threshold as the servo's starting base
            self.policy = ThresholdPolicy(base=cache.threshold if cache is not None else 0.8)
        if cache is not None and cache.policy is None:
            cache.policy = self.policy
        self.cache = cache
        self.hierarchy = hierarchy
        self.price_table = dict(price_table or DEFAULT_PRICE_TABLE)
        self.backends: Dict[str, LLMBackend] = {}
        self._order: List[str] = []  # registration order == escalation order (cheap -> pricey)
        self.quality_ctl = QualityRateController(self.policy, target=quality_target)
        self.cost_ctl = (
            CostController(self.policy, target_cost_per_request)
            if target_cost_per_request is not None
            else None
        )
        self.stats = ClientStats()
        self.max_workers = max_workers
        # lazily-built CacheService (repro.serving.service)
        self._service = None  # guarded-by: _state_lock
        self._results: Dict[int, ClientResult] = {}  # guarded-by: _state_lock
        self._next_id = 0  # guarded-by: _state_lock
        # client-owned locks, so several CacheService instances sharing this
        # client cannot tear them: _state_lock guards stats/_next_id/_results,
        # _cache_lock serializes store lookups against backfill scatters
        self._state_lock = threading.Lock()
        self._cache_lock = threading.RLock()
        self._preferred_level = 0  # guarded-by: _state_lock
        # -- resilience (repro.resilience): per-backend breakers + retry --
        # breakers/_breaker_factory mutate only at registration time (setup,
        # single-threaded by convention); each CircuitBreaker is internally
        # locked, so the dispatch path reads them lock-free
        self.retry_policy = retry_policy or RetryPolicy()
        self.retry_budget = retry_budget or RetryBudget()
        self._breaker_factory = breaker_factory or (lambda name: CircuitBreaker(name))
        self.breakers: Dict[str, CircuitBreaker] = {}
        self._retry_rng = random.Random(0)  # guarded-by: _state_lock

    # -- service delegation ----------------------------------------------------

    @property
    def service(self):
        """The CacheService every request path delegates to. Built lazily
        (runtime import: core and serving reference each other)."""
        if self._service is None:  # repro: noqa[RA301] — double-checked fast path; GIL-atomic read, confirmed under the lock below
            from repro.serving.service import CacheService

            with self._state_lock:  # concurrent first use must not build two
                if self._service is None:
                    self._service = CacheService(self)
        return self._service  # repro: noqa[RA301] — monotonic once-set publish; rebuilt never, torn never (single reference assignment)

    def close(self) -> None:
        with self._state_lock:
            service = self._service
        if service is not None:
            service.close()

    @staticmethod
    def _to_client_result(resp: CacheResponse) -> ClientResult:
        return ClientResult(
            resp.text, resp.from_cache, resp.cache_result, resp.llm_response,
            resp.model, resp.cost_usd, resp.latency_s, resp.request_id,
        )

    # -- backend management --------------------------------------------------

    def register_backend(self, backend: LLMBackend, price: Optional[ModelCostInfo] = None):
        self.backends[backend.name] = backend
        if backend.name not in self._order:
            self._order.append(backend.name)
        self.breakers[backend.name] = self._breaker_factory(backend.name)
        if price is not None:
            self.price_table[backend.name] = price

    def breaker_snapshot(self) -> Dict[str, dict]:
        """Per-backend breaker state for /healthz and /v1/cache/stats."""
        return {name: br.snapshot() for name, br in self.breakers.items()}

    def _price(self, model: str) -> ModelCostInfo:
        return self.price_table.get(model, ModelCostInfo())

    def _cost_of(self, model: str, resp: LLMResponse) -> float:
        p = self._price(model)
        return (resp.tokens_in * p.usd_per_mtok_in + resp.tokens_out * p.usd_per_mtok_out) / 1e6

    def _select_model(self, model: Optional[str]) -> str:
        if model is not None:
            return model
        if not self._order:
            raise RuntimeError("no backends registered")
        with self._state_lock:
            level = self._preferred_level
        return self._order[min(level, len(self._order) - 1)]

    def _context_for(self, request: CacheRequest, chosen: str) -> dict:
        """ThresholdPolicy context (§2) for one request."""
        return {
            "model_info": self._price(chosen),
            "max_tokens": request.max_tokens,
            "connectivity": request.connectivity,
        }

    # -- main request path (thin sync wrappers over CacheService) ----------------

    def query(
        self,
        prompt: str,
        model: Optional[str] = None,
        max_tokens: int = 256,
        temperature: float = 0.0,
        use_cache: bool = True,
        force_fresh: bool = False,  # user explicitly wants a new LLM response
        cache_l1: bool = True,  # privacy hints (§4)
        cache_l2: bool = True,
        connectivity: float = 1.0,
    ) -> ClientResult:
        return self.complete_requests([
            CacheRequest(
                prompt, model=model, max_tokens=max_tokens, temperature=temperature,
                use_cache=use_cache, force_fresh=force_fresh, cache_l1=cache_l1,
                cache_l2=cache_l2, connectivity=connectivity,
            )
        ])[0]

    def complete_requests(self, requests: Sequence[CacheRequest]) -> List[ClientResult]:
        """Serve CacheRequests inline (one embed forward + one search +
        one batched miss dispatch) and return in request order."""
        return [self._to_client_result(r) for r in self.service.complete(requests)]

    def complete_batch(
        self,
        prompts: Sequence[str],
        model: Optional[str] = None,
        max_tokens: int = 256,
        temperature: float = 0.0,
        use_cache: bool = True,
        force_fresh: bool = False,
        cache_l1: bool = True,
        cache_l2: bool = True,  # privacy hints (§4); only meaningful with a hierarchy
        connectivity: float = 1.0,
    ) -> List[ClientResult]:
        """Serve B prompts through the batched cache pipeline (uniform knobs;
        build ``CacheRequest``s directly for per-request hints/priorities)."""
        return self.complete_requests([
            CacheRequest(
                p, model=model, max_tokens=max_tokens, temperature=temperature,
                use_cache=use_cache, force_fresh=force_fresh, cache_l1=cache_l1,
                cache_l2=cache_l2, connectivity=connectivity,
            )
            for p in prompts
        ])

    # -- async API (futures) -----------------------------------------------------

    def submit(self, request: CacheRequest):
        """Async entry: ``Future[CacheResponse]`` that resolves at hit speed
        for hits even when slow misses share the stream."""
        return self.service.submit(request)

    def asubmit(self, request: CacheRequest):
        return self.service.asubmit(request)

    # -- failover (used by the service's dispatch phase) -------------------------

    def _generate_with_failover(self, model, prompt, max_tokens, temperature) -> LLMResponse:
        """If an LLM is unresponsive, fall through to the other backends (§2)."""
        return self._generate_batch_with_failover(model, [prompt], max_tokens, temperature)[0]

    @staticmethod
    def _accepts_deadlines(backend: LLMBackend) -> bool:
        # explicit declaration wins: backends that delegate via *args/**kwargs
        # (no literal 'deadlines' parameter) can set supports_deadlines=True
        declared = getattr(backend, "supports_deadlines", None)
        if declared is not None:
            return bool(declared)
        return accepts_kwarg(type(backend), "generate_batch", "deadlines")

    def _jitter_draw(self) -> float:
        with self._state_lock:
            return self._retry_rng.random()

    def _generate_batch_with_failover(
        self, model, prompts, max_tokens, temperature, deadlines=None
    ) -> List[LLMResponse]:
        """Batched failover with per-backend retry + circuit breaking.

        Rows whose deadline has ALREADY passed resolve in place as typed
        ``expired`` responses — an expiry is the caller's clock running out,
        not a backend failure, so it burns no call, no retry, no failover
        hop, and no ``llm_errors`` bump. The live rows then walk the
        escalation order: backends whose breaker is open are skipped without
        a call; each admitted backend gets up to ``retry_policy.max_attempts``
        tries with exponential backoff + jitter, gated by the global retry
        budget and by deadline headroom (never sleep past the soonest live
        deadline). Exhausting every backend raises a typed
        ``AllBackendsFailed`` carrying structured per-backend causes.
        """
        n = len(prompts)
        out: List[Optional[LLMResponse]] = [None] * n
        stamps = list(deadlines) if deadlines is not None else [None] * n

        def _expire_passed(now: float) -> None:
            for i in range(n):
                if out[i] is None and stamps[i] is not None and now > stamps[i]:
                    out[i] = LLMResponse("", model or "", expired=True)

        def _live() -> List[int]:
            return [i for i in range(n) if out[i] is None]

        _expire_passed(time.perf_counter())
        if not _live():
            return [r for r in out if r is not None]

        self.retry_budget.deposit(len(_live()))
        causes: List[BackendFailure] = []
        names = [model] + [n_ for n_ in self._order if n_ != model]
        for name in names:
            backend = self.backends.get(name)
            if backend is None:
                continue
            _expire_passed(time.perf_counter())
            live = _live()
            if not live:
                break
            breaker = self.breakers.get(name)
            if breaker is not None and not breaker.allow():
                causes.append(BackendFailure(name, skipped=True))
                with self._state_lock:
                    self.stats.breaker_open_skips += 1
                continue
            failure = self._call_backend_with_retry(
                backend, breaker, out, stamps, live, prompts, max_tokens, temperature
            )
            if failure is None:
                return [r for r in out if r is not None]
            causes.append(failure)
        _expire_passed(time.perf_counter())
        if not _live():
            # every remaining row expired while we failed over: a typed
            # per-row expiry beats an exception that would also poison the
            # rows a backend DID answer earlier
            return [r for r in out if r is not None]
        with self._state_lock:
            self.stats.all_backends_failed += 1
        raise AllBackendsFailed(causes)

    def _call_backend_with_retry(
        self, backend, breaker, out, stamps, live, prompts, max_tokens, temperature
    ) -> Optional[BackendFailure]:
        """Try ONE backend for the ``live`` rows, retrying per policy.
        Returns None on success (results written into ``out``), else the
        structured failure record for the AllBackendsFailed envelope."""
        name = backend.name
        sub_prompts = [prompts[i] for i in live]
        sub_stamps = [stamps[i] for i in live]
        pass_deadlines = any(s is not None for s in sub_stamps) and self._accepts_deadlines(backend)
        soonest = min((s for s in sub_stamps if s is not None), default=None)
        failure = BackendFailure(name)
        for attempt in range(1, self.retry_policy.max_attempts + 1):
            failure.attempts = attempt
            try:
                if pass_deadlines:
                    rows = backend.generate_batch(
                        sub_prompts, max_tokens, temperature, deadlines=sub_stamps
                    )
                else:
                    rows = backend.generate_batch(sub_prompts, max_tokens, temperature)
            except Exception as e:  # noqa: BLE001 — failover on any backend error
                failure.errors.append(repr(e))
                failure.kinds.append(type(e).__name__)
                tripped = breaker.record_failure() if breaker is not None else False
                with self._state_lock:
                    self.stats.llm_errors += 1
                    if tripped:
                        self.stats.breaker_trips += 1
                if attempt >= self.retry_policy.max_attempts:
                    return failure
                backoff = self.retry_policy.backoff_s(attempt, self._jitter_draw())
                if soonest is not None and time.perf_counter() + backoff >= soonest:
                    return failure  # no headroom: retrying would land past the deadline
                if not self.retry_budget.try_spend():
                    return failure  # global retry budget dry: move on immediately
                with self._state_lock:
                    self.stats.retries += 1
                if backoff > 0:
                    time.sleep(backoff)
                continue
            if breaker is not None:
                breaker.record_success()
            for i, row in zip(live, rows):
                out[i] = row
            return None
        return failure  # unreachable, but keeps the type checker honest

    # -- parallel multi-LLM dispatch (§5.2) ---------------------------------------

    def query_many(
        self,
        prompts: Sequence[str],
        models: Optional[Sequence[Optional[str]]] = None,
        parallel: bool = True,
        **kwargs,
    ) -> List[ClientResult]:
        models = models or [None] * len(prompts)
        if not parallel:
            return [self.query(p, m, **kwargs) for p, m in zip(prompts, models)]
        # concurrent requests ride the service scheduler: one admitted batch
        # shares one embed forward and one backend fan-out per model group
        # (submit_many blocks for capacity instead of shedding, so a bulk
        # sync call never abandons already-submitted work)
        futures = self.service.submit_many(
            [CacheRequest(p, model=m, **kwargs) for p, m in zip(prompts, models)]
        )
        return [self._to_client_result(f.result()) for f in futures]

    def broadcast(self, prompt: str, models: Optional[Sequence[str]] = None, **kwargs) -> Dict[str, ClientResult]:
        """Ask several LLMs the same question concurrently (§5.2)."""
        models = list(models or self._order)
        futures = self.service.submit_many(
            [CacheRequest(prompt, model=m, use_cache=False, **kwargs) for m in models]
        )
        return {m: self._to_client_result(f.result()) for m, f in zip(models, futures)}

    # -- feedback (§3.1) ------------------------------------------------------------

    def feedback(self, result: ClientResult, satisfied: bool) -> None:
        """User feedback on a served result.

        Cache hits feed the quality-rate controller. Dissatisfaction with an
        *LLM* answer escalates model selection; satisfaction de-escalates
        toward the cheaper models.
        """
        if result.from_cache:
            self.quality_ctl.record(satisfied)
        else:
            with self._state_lock:
                if satisfied:
                    self._preferred_level = max(0, self._preferred_level - 1)
                else:
                    self._preferred_level = min(
                        len(self._order) - 1, self._preferred_level + 1
                    )
