"""Enhanced client for GenerativeCache (§5).

Coordinates multiple LLM backends behind one interface with the cache
integrated: embed -> cache lookup -> hit: return / miss: dispatch to a
backend, charge its cost, insert the answer. Parallel multi-backend fan-out
uses a thread pool (the paper's asyncio/multiprocessing parallel dispatch —
backends here release the GIL inside jitted generation or simulate IO).

Cost optimization knobs from §3.1/§5.3: model selection (serve from cheaper
models while the user is satisfied, escalate on dissatisfaction), max_tokens
limits, and the feedback/cost controllers servoing t_s.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import (
    DEFAULT_PRICE_TABLE,
    CostController,
    ModelCostInfo,
    QualityRateController,
    ThresholdPolicy,
)
from repro.core.generative_cache import GenerativeCache
from repro.core.hierarchy import HierarchicalCache
from repro.core.semantic_cache import CacheResult


@dataclass
class LLMResponse:
    text: str
    model: str
    tokens_in: int = 0
    tokens_out: int = 0
    latency_s: float = 0.0
    cost_usd: float = 0.0


class LLMBackend:
    """Interface for a model endpoint."""

    name: str = "llm"

    def generate(self, prompt: str, max_tokens: int = 256, temperature: float = 0.0) -> LLMResponse:
        raise NotImplementedError

    def generate_batch(
        self, prompts: Sequence[str], max_tokens: int = 256, temperature: float = 0.0
    ) -> List[LLMResponse]:
        """Serve a batch of prompts. Backends that batch natively (e.g. the
        continuous-batching engine) override this; the default loops."""
        return [self.generate(p, max_tokens, temperature) for p in prompts]


class MockLLM(LLMBackend):
    """Deterministic offline backend with a configurable latency/price profile."""

    def __init__(
        self,
        name: str = "mock-llm",
        latency_s: float = 0.0,
        responder: Optional[Callable[[str], str]] = None,
        fail: bool = False,
    ):
        self.name = name
        self.latency_s = latency_s
        self.responder = responder or (lambda p: f"[{name}] answer to: {p}")
        self.fail = fail
        self.calls = 0

    def generate(self, prompt: str, max_tokens: int = 256, temperature: float = 0.0) -> LLMResponse:
        if self.fail:
            raise ConnectionError(f"{self.name} unresponsive")
        t0 = time.perf_counter()
        if self.latency_s:
            time.sleep(self.latency_s)
        self.calls += 1
        text = self.responder(prompt)
        words = text.split()
        if len(words) > max_tokens:
            text = " ".join(words[:max_tokens])
        return LLMResponse(
            text, self.name, tokens_in=len(prompt.split()), tokens_out=min(len(words), max_tokens),
            latency_s=time.perf_counter() - t0,
        )

    def generate_batch(
        self, prompts: Sequence[str], max_tokens: int = 256, temperature: float = 0.0
    ) -> List[LLMResponse]:
        # batched endpoint semantics: the batch travels together, so the
        # simulated RTT is paid once, not once per prompt
        if self.fail:
            raise ConnectionError(f"{self.name} unresponsive")
        t0 = time.perf_counter()
        if self.latency_s:
            time.sleep(self.latency_s)
        out = []
        for prompt in prompts:
            self.calls += 1
            text = self.responder(prompt)
            words = text.split()
            if len(words) > max_tokens:
                text = " ".join(words[:max_tokens])
            out.append(LLMResponse(
                text, self.name, tokens_in=len(prompt.split()),
                tokens_out=min(len(words), max_tokens),
                latency_s=time.perf_counter() - t0,
            ))
        return out


@dataclass
class ClientResult:
    text: str
    from_cache: bool
    cache_result: Optional[CacheResult]
    llm_response: Optional[LLMResponse]
    model: str
    cost_usd: float
    latency_s: float
    request_id: int


@dataclass
class ClientStats:
    requests: int = 0
    cache_hits: int = 0
    llm_calls: int = 0
    llm_errors: int = 0
    total_cost_usd: float = 0.0
    total_latency_s: float = 0.0

    @property
    def avg_cost(self) -> float:
        return self.total_cost_usd / self.requests if self.requests else 0.0


class EnhancedClient:
    def __init__(
        self,
        cache: Optional[GenerativeCache] = None,
        hierarchy: Optional[HierarchicalCache] = None,
        policy: Optional[ThresholdPolicy] = None,
        price_table: Optional[Dict[str, ModelCostInfo]] = None,
        quality_target: float = 0.8,
        target_cost_per_request: Optional[float] = None,
        max_workers: int = 8,
    ):
        if policy is not None:
            self.policy = policy
        elif cache is not None and cache.policy is not None:
            self.policy = cache.policy
        else:
            # inherit the cache's static threshold as the servo's starting base
            self.policy = ThresholdPolicy(base=cache.threshold if cache is not None else 0.8)
        if cache is not None and cache.policy is None:
            cache.policy = self.policy
        self.cache = cache
        self.hierarchy = hierarchy
        self.price_table = dict(price_table or DEFAULT_PRICE_TABLE)
        self.backends: Dict[str, LLMBackend] = {}
        self._order: List[str] = []  # registration order == escalation order (cheap -> pricey)
        self.quality_ctl = QualityRateController(self.policy, target=quality_target)
        self.cost_ctl = (
            CostController(self.policy, target_cost_per_request)
            if target_cost_per_request is not None
            else None
        )
        self.stats = ClientStats()
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._results: Dict[int, ClientResult] = {}
        self._next_id = 0
        self._preferred_level = 0  # model-selection escalation state

    # -- backend management --------------------------------------------------

    def register_backend(self, backend: LLMBackend, price: Optional[ModelCostInfo] = None):
        self.backends[backend.name] = backend
        self._order.append(backend.name)
        if price is not None:
            self.price_table[backend.name] = price

    def _price(self, model: str) -> ModelCostInfo:
        return self.price_table.get(model, ModelCostInfo())

    def _cost_of(self, model: str, resp: LLMResponse) -> float:
        p = self._price(model)
        return (resp.tokens_in * p.usd_per_mtok_in + resp.tokens_out * p.usd_per_mtok_out) / 1e6

    def _select_model(self, model: Optional[str]) -> str:
        if model is not None:
            return model
        if not self._order:
            raise RuntimeError("no backends registered")
        return self._order[min(self._preferred_level, len(self._order) - 1)]

    # -- main request path ------------------------------------------------------

    def query(
        self,
        prompt: str,
        model: Optional[str] = None,
        max_tokens: int = 256,
        temperature: float = 0.0,
        use_cache: bool = True,
        force_fresh: bool = False,  # user explicitly wants a new LLM response
        cache_l1: bool = True,  # privacy hints (§4)
        cache_l2: bool = True,
        connectivity: float = 1.0,
    ) -> ClientResult:
        t0 = time.perf_counter()
        self.stats.requests += 1
        rid = self._next_id
        self._next_id += 1
        chosen = self._select_model(model)
        ctx = {
            "model_info": self._price(chosen),
            "max_tokens": max_tokens,
            "connectivity": connectivity,
        }

        cache_res: Optional[CacheResult] = None
        vec = None
        if use_cache and (self.cache is not None or self.hierarchy is not None):
            embedder_owner = self.hierarchy.l1 if self.hierarchy is not None else self.cache
            vec = embedder_owner.embed(prompt)  # embed once; reused for insert
        if use_cache and not force_fresh and (self.cache or self.hierarchy):
            target = self.hierarchy or self.cache
            cache_res = target.lookup(prompt, ctx, vec=vec)
            if cache_res.hit:
                self.stats.cache_hits += 1
                if self.cost_ctl:
                    self.cost_ctl.record(0.0, True)
                out = ClientResult(
                    cache_res.response, True, cache_res, None, "cache", 0.0,
                    time.perf_counter() - t0, rid,
                )
                self._results[rid] = out
                return out

        resp = self._generate_with_failover(chosen, prompt, max_tokens, temperature)
        cost = self._cost_of(resp.model, resp)
        resp.cost_usd = cost
        self.stats.llm_calls += 1
        self.stats.total_cost_usd += cost
        if self.cost_ctl:
            self.cost_ctl.record(cost, False)
        if use_cache and (self.cache or self.hierarchy):
            if self.hierarchy is not None:
                self.hierarchy.insert(prompt, resp.text, cache_l1=cache_l1,
                                      cache_l2=cache_l2, vec=vec)
            else:
                if cache_l1:
                    self.cache.insert(prompt, resp.text, {"model": resp.model}, vec=vec)
        out = ClientResult(
            resp.text, False, cache_res, resp, resp.model, cost, time.perf_counter() - t0, rid
        )
        self.stats.total_latency_s += out.latency_s
        self._results[rid] = out
        return out

    def _generate_with_failover(self, model, prompt, max_tokens, temperature) -> LLMResponse:
        """If an LLM is unresponsive, fall through to the other backends (§2)."""
        tried = []
        names = [model] + [n for n in self._order if n != model]
        for name in names:
            backend = self.backends.get(name)
            if backend is None:
                continue
            try:
                return backend.generate(prompt, max_tokens, temperature)
            except Exception as e:  # noqa: BLE001 — failover on any backend error
                tried.append((name, repr(e)))
                self.stats.llm_errors += 1
        raise ConnectionError(f"all backends failed: {tried}")

    def _generate_batch_with_failover(
        self, model, prompts, max_tokens, temperature
    ) -> List[LLMResponse]:
        """Batched failover: the whole miss batch moves to the next backend."""
        tried = []
        names = [model] + [n for n in self._order if n != model]
        for name in names:
            backend = self.backends.get(name)
            if backend is None:
                continue
            try:
                return backend.generate_batch(prompts, max_tokens, temperature)
            except Exception as e:  # noqa: BLE001 — failover on any backend error
                tried.append((name, repr(e)))
                self.stats.llm_errors += 1
        raise ConnectionError(f"all backends failed: {tried}")

    # -- batched request path (embed -> search -> synthesize, then one dispatch) --

    def complete_batch(
        self,
        prompts: Sequence[str],
        model: Optional[str] = None,
        max_tokens: int = 256,
        temperature: float = 0.0,
        use_cache: bool = True,
        force_fresh: bool = False,
        cache_l1: bool = True,
        cache_l2: bool = True,  # privacy hints (§4); only meaningful with a hierarchy
        connectivity: float = 1.0,
    ) -> List[ClientResult]:
        """Serve B prompts through the batched cache pipeline.

        One embed forward + one store search (per hierarchy level, when one is
        configured) covers the whole batch; hits and generative hits are
        answered immediately and the remaining misses fan out to the backend
        in a single batched dispatch, then backfill the cache with one
        ``add_batch`` scatter per level. Results come back in prompt order.
        """
        t0 = time.perf_counter()
        n = len(prompts)
        if n == 0:
            return []
        self.stats.requests += n
        rids = list(range(self._next_id, self._next_id + n))
        self._next_id += n
        chosen = self._select_model(model)
        ctx = {
            "model_info": self._price(chosen),
            "max_tokens": max_tokens,
            "connectivity": connectivity,
        }

        results: List[Optional[ClientResult]] = [None] * n
        target = self.hierarchy if self.hierarchy is not None else self.cache
        vecs = None
        if use_cache and target is not None:
            embedder_owner = self.hierarchy.l1 if self.hierarchy is not None else self.cache
            vecs = embedder_owner.embed_batch(list(prompts))
            if not force_fresh:
                cache_results = target.lookup_batch(list(prompts), [ctx] * n, vecs=vecs)
                for i, cr in enumerate(cache_results):
                    if cr.hit:
                        self.stats.cache_hits += 1
                        if self.cost_ctl:
                            self.cost_ctl.record(0.0, True)
                        results[i] = ClientResult(
                            cr.response, True, cr, None, "cache", 0.0,
                            time.perf_counter() - t0, rids[i],
                        )

        miss_idx = [i for i in range(n) if results[i] is None]
        if miss_idx:
            # one batched dispatch for the whole miss set (async fan-out is a
            # ROADMAP item; submitting to the shared pool just to block here
            # would only steal a worker from query_many traffic)
            resps = self._generate_batch_with_failover(
                chosen, [prompts[i] for i in miss_idx], max_tokens, temperature
            )
            if len(resps) != len(miss_idx):  # fail fast on a short batch
                raise RuntimeError(
                    f"backend returned {len(resps)} responses for {len(miss_idx)} prompts"
                )
            for i, resp in zip(miss_idx, resps):
                cost = self._cost_of(resp.model, resp)
                resp.cost_usd = cost
                self.stats.llm_calls += 1
                self.stats.total_cost_usd += cost
                if self.cost_ctl:
                    self.cost_ctl.record(cost, False)
                results[i] = ClientResult(
                    resp.text, False, None, resp, resp.model, cost,
                    time.perf_counter() - t0, rids[i],
                )
            if use_cache and target is not None:
                miss_vecs = np.asarray(vecs)[miss_idx]
                miss_prompts = [prompts[i] for i in miss_idx]
                miss_texts = [results[i].text for i in miss_idx]
                if self.hierarchy is not None:
                    # whole miss set backfills each permitted level in one scatter
                    self.hierarchy.insert_batch(
                        miss_prompts, miss_texts, cache_l1=cache_l1,
                        cache_l2=cache_l2, vecs=miss_vecs,
                    )
                elif cache_l1:
                    self.cache.insert_batch(
                        miss_prompts, miss_texts,
                        metas=[{"model": results[i].model} for i in miss_idx],
                        vecs=miss_vecs,
                    )

        for r in results:
            if not r.from_cache:  # match query(): hits don't accrue latency
                self.stats.total_latency_s += r.latency_s
            self._results[r.request_id] = r
        return results  # type: ignore[return-value]

    # -- parallel multi-LLM dispatch (§5.2) ---------------------------------------

    def query_many(
        self,
        prompts: Sequence[str],
        models: Optional[Sequence[Optional[str]]] = None,
        parallel: bool = True,
        **kwargs,
    ) -> List[ClientResult]:
        models = models or [None] * len(prompts)
        if not parallel:
            return [self.query(p, m, **kwargs) for p, m in zip(prompts, models)]
        futures = [self._pool.submit(self.query, p, m, **kwargs) for p, m in zip(prompts, models)]
        return [f.result() for f in futures]

    def broadcast(self, prompt: str, models: Optional[Sequence[str]] = None, **kwargs) -> Dict[str, ClientResult]:
        """Ask several LLMs the same question concurrently (§5.2)."""
        models = list(models or self._order)
        futures = {
            m: self._pool.submit(self.query, prompt, m, use_cache=False, **kwargs) for m in models
        }
        return {m: f.result() for m, f in futures.items()}

    # -- feedback (§3.1) ------------------------------------------------------------

    def feedback(self, result: ClientResult, satisfied: bool) -> None:
        """User feedback on a served result.

        Cache hits feed the quality-rate controller. Dissatisfaction with an
        *LLM* answer escalates model selection; satisfaction de-escalates
        toward the cheaper models.
        """
        if result.from_cache:
            self.quality_ctl.record(satisfied)
        else:
            if satisfied:
                self._preferred_level = max(0, self._preferred_level - 1)
            else:
                self._preferred_level = min(len(self._order) - 1, self._preferred_level + 1)
