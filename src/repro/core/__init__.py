"""The paper's contribution: generative semantic caching for LLMs."""
from repro.core.adaptive import (  # noqa: F401
    DEFAULT_PRICE_TABLE,
    CostController,
    ModelCostInfo,
    QualityRateController,
    ThresholdPolicy,
    classify_content,
)
from repro.core.client import (  # noqa: F401
    ClientResult,
    EnhancedClient,
    LLMBackend,
    LLMResponse,
    MockLLM,
)
from repro.core.embeddings import (  # noqa: F401
    ContrieverEncoder,
    EmbeddingModel,
    NgramHashEmbedder,
    get_embedder,
)
from repro.core.generative_cache import GenerativeCache  # noqa: F401
from repro.core.hierarchy import HierarchicalCache  # noqa: F401
from repro.core.request import (  # noqa: F401
    DEADLINE_EXCEEDED,
    GENERATED,
    HIT,
    CacheChunk,
    CacheRequest,
    CacheResponse,
    split_stream_tokens,
)
from repro.core.semantic_cache import CacheResult, GPTCacheLike, SemanticCache  # noqa: F401
from repro.core.store_bank import StoreBank  # noqa: F401
from repro.core.tiers import HostRamTier, SnapshotTier, TierEntry  # noqa: F401
from repro.core.vector_store import Entry, InMemoryVectorStore  # noqa: F401
