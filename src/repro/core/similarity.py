"""Semantic similarity calculator (pluggable metrics, jitted batch scoring)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

METRICS = ("cosine", "dot", "euclidean")


def scores(db: jax.Array, q: jax.Array, metric: str = "cosine") -> jax.Array:
    """db [N, D], q [Q, D] -> similarity scores [Q, N] (higher = more similar)."""
    if metric == "cosine":
        dbn = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-9)
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
        return qn @ dbn.T
    if metric == "dot":
        return q @ db.T
    if metric == "euclidean":
        d2 = jnp.sum(q * q, -1)[:, None] - 2 * (q @ db.T) + jnp.sum(db * db, -1)[None, :]
        return -jnp.sqrt(jnp.maximum(d2, 0.0))
    raise ValueError(f"unknown metric {metric!r}")


def top_k_scores(
    db: jax.Array, valid: jax.Array, q: jax.Array, k: int, metric: str = "cosine"
) -> Tuple[jax.Array, jax.Array]:
    """Masked top-k search. valid [N] bool. Returns (scores [Q,k], idx [Q,k])."""
    s = scores(db, q, metric)
    s = jnp.where(valid[None, :], s, -jnp.inf)
    return jax.lax.top_k(s, k)


def pairwise_similarity(a: np.ndarray, b: np.ndarray, metric: str = "cosine") -> float:
    return float(np.asarray(scores(jnp.asarray(b[None]), jnp.asarray(a[None]), metric))[0, 0])
