"""Request/response envelope for the cache serving path (§5).

``CacheRequest`` replaces the kwargs sprawl that was duplicated across
``EnhancedClient.query`` / ``complete_batch`` / ``query_many`` /
``broadcast`` with one dataclass carrying every per-request knob — the
cache hints (``use_cache``, ``force_fresh``, the §4 privacy hints) plus the
async-serving fields the scheduler acts on (``priority``, ``deadline_s``).

``CacheResponse`` is the typed result every submitted future resolves
with. Hits and generated answers carry text; a miss whose deadline expired
in queue resolves with ``status == DEADLINE_EXCEEDED`` and ``text=None``
instead of generating — the caller gets a typed result, never a silent
stall behind a slow backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, Optional

if TYPE_CHECKING:  # typing only — avoids a runtime cycle with repro.core.client
    from repro.core.client import LLMResponse
    from repro.core.semantic_cache import CacheResult

# CacheResponse.status values
HIT = "hit"  # served from cache (semantic or generative)
GENERATED = "generated"  # miss: a backend generated the answer
DEADLINE_EXCEEDED = "deadline_exceeded"  # miss expired in queue; no backend call


@dataclass
class CacheRequest:
    prompt: str
    model: Optional[str] = None  # None -> the client's escalation ladder picks
    max_tokens: int = 256
    temperature: float = 0.0
    use_cache: bool = True
    force_fresh: bool = False  # skip lookup, still insert the fresh answer (§5.2)
    cache_l1: bool = True  # privacy hints (§4); cache_l2 only matters with a hierarchy
    cache_l2: bool = True
    connectivity: float = 1.0
    priority: int = 0  # higher is scheduled sooner
    deadline_s: Optional[float] = None  # relative to submit; expired misses don't generate
    ttl_s: Optional[float] = None  # backfilled answer's cache lifetime; None = store default
    metadata: Dict[str, Any] = field(default_factory=dict)


@dataclass
class CacheResponse:
    text: Optional[str]
    status: str  # HIT | GENERATED | DEADLINE_EXCEEDED
    from_cache: bool
    cache_result: Optional["CacheResult"]
    llm_response: Optional["LLMResponse"]
    model: str
    cost_usd: float
    latency_s: float
    request_id: int

    @property
    def expired(self) -> bool:
        return self.status == DEADLINE_EXCEEDED
