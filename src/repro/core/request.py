"""Request/response envelope for the cache serving path (§5).

``CacheRequest`` replaces the kwargs sprawl that was duplicated across
``EnhancedClient.query`` / ``complete_batch`` / ``query_many`` /
``broadcast`` with one dataclass carrying every per-request knob — the
cache hints (``use_cache``, ``force_fresh``, the §4 privacy hints) plus the
async-serving fields the scheduler acts on (``priority``, ``deadline_s``).

``CacheResponse`` is the typed result every submitted future resolves
with. Hits and generated answers carry text; a miss whose deadline expired
in queue resolves with ``status == DEADLINE_EXCEEDED`` and ``text=None``
instead of generating — the caller gets a typed result, never a silent
stall behind a slow backend.

``CacheChunk`` is the streaming unit: ``CacheService.astream`` replays a
resolved response token-by-token as chunks whose concatenated ``text`` is
byte-identical to the non-streamed ``CacheResponse.text`` — the HTTP
gateway serves hits and misses over the same SSE surface, so a client
cannot tell a millisecond cache replay from a live generation except by
reading the ``X-Cache`` header.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, List, Optional

if TYPE_CHECKING:  # typing only — avoids a runtime cycle with repro.core.client
    from repro.core.client import LLMResponse
    from repro.core.semantic_cache import CacheResult

# CacheResponse.status values
HIT = "hit"  # served from cache (semantic or generative)
GENERATED = "generated"  # miss: a backend generated the answer
DEADLINE_EXCEEDED = "deadline_exceeded"  # miss expired in queue; no backend call
STALE = "stale"  # stale-if-error: expired entry served because every backend was down


@dataclass
class CacheRequest:
    prompt: str
    model: Optional[str] = None  # None -> the client's escalation ladder picks
    max_tokens: int = 256
    temperature: float = 0.0
    use_cache: bool = True
    force_fresh: bool = False  # skip lookup, still insert the fresh answer (§5.2)
    cache_l1: bool = True  # privacy hints (§4); cache_l2 only matters with a hierarchy
    cache_l2: bool = True
    connectivity: float = 1.0
    priority: int = 0  # higher is scheduled sooner
    deadline_s: Optional[float] = None  # relative to submit; expired misses don't generate
    ttl_s: Optional[float] = None  # backfilled answer's cache lifetime; None = store default
    stream: bool = False  # caller wants chunked delivery (CacheService.astream / SSE)
    # stale-if-error (resilience): when every backend is open/down, a request
    # that opted in may be answered from an EXPIRED cache entry instead of a
    # 503 — bounded by max_stale_s past expiry (None = any age)
    allow_stale: bool = False
    max_stale_s: Optional[float] = None
    metadata: Dict[str, Any] = field(default_factory=dict)


@dataclass
class CacheResponse:
    text: Optional[str]
    status: str  # HIT | GENERATED | DEADLINE_EXCEEDED
    from_cache: bool
    cache_result: Optional["CacheResult"]
    llm_response: Optional["LLMResponse"]
    model: str
    cost_usd: float
    latency_s: float
    request_id: int

    @property
    def expired(self) -> bool:
        return self.status == DEADLINE_EXCEEDED

    @property
    def cache_status(self) -> str:
        """Where the answer came from, as the gateway's ``X-Cache`` value:
        ``hit`` (plain semantic tier-0), ``generative`` (synthesized from
        several sources, §3), ``tier1`` (promoted from the host-RAM ring),
        ``stale`` (expired entry served stale-if-error while backends were
        down), or ``miss`` (a backend generated it — including expiries,
        which the gateway maps to an error status before this header
        matters)."""
        if self.status == STALE:
            return "stale"
        if self.status == HIT and self.cache_result is not None:
            level = self.cache_result.level or ""
            if "tier1" in level:
                return "tier1"
            if self.cache_result.generative or "generative" in level:
                return "generative"
            return "hit"
        return "miss"

    @property
    def similarity(self) -> Optional[float]:
        """Winning similarity score for cache hits, None for misses."""
        if self.cache_result is None:
            return None
        return float(self.cache_result.similarity)

    @property
    def resolved_level(self) -> str:
        """The hierarchy level that answered (``semantic``, ``L2:tier1``,
        ``generative``, ...) or ``miss``/``deadline_exceeded`` for the rest."""
        if self.status == HIT and self.cache_result is not None:
            return self.cache_result.level or "semantic"
        return "miss" if self.status == GENERATED else self.status


def split_stream_tokens(text: str) -> List[str]:
    """Split ``text`` into replayable streaming tokens (a word plus its
    trailing whitespace each) such that ``"".join(...)`` reproduces the
    input byte-for-byte — the invariant the gateway's SSE parity contract
    (and its tests) rest on. Leading whitespace rides the first token."""
    if not text:
        return []
    runs = re.findall(r"\s+|\S+", text)  # alternating runs; join(runs) == text
    tokens: List[str] = []
    for run in runs:
        if tokens and run.isspace():
            tokens[-1] += run
        else:
            tokens.append(run)
    return tokens


@dataclass
class CacheChunk:
    """One streamed piece of a resolved response (``CacheService.astream``).

    ``response`` carries the full ``CacheResponse`` on EVERY chunk so a
    consumer (the gateway writes cache-status headers before the first SSE
    event) never waits for the stream's end to learn hit/miss, similarity,
    or latency. ``final`` marks the last chunk; an empty response yields a
    single final chunk with ``text == ""``."""

    text: str
    index: int
    final: bool
    response: CacheResponse
