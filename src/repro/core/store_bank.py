"""StoreBank: one device-resident [L, cap, D] buffer for many vector stores.

The cache's read path used to issue one ``search_batch`` dispatch per
hierarchy level (and the sharded DB kept a separate flat buffer). The bank
stacks every *lane* — a hierarchy level (private L1 / shared L2 / peers) or
a DB shard — into a single [L, cap, D] embedding tensor with a [L, cap]
validity mask, so a B-query lookup across the whole hierarchy is ONE fused
top-k dispatch:

    [L, cap, D] x [B, D] -> scores [B, L, k], lane-local idx [B, L, k]

``InMemoryVectorStore`` and ``ShardedVectorStore`` are thin lane views over
a bank: each keeps its public add/search/remove API and host-side entry
metadata, while the device tensors, the per-lane recency/frequency counters
(LRU/LFU over any lane, sharded included), and the search dispatch live
here. A standalone store is just a 1-lane bank; ``StoreBank.adopt`` stacks
live stores into a shared bank (repointing each store's lane view) so a
hierarchy's levels become rows of one tensor.

Eviction counters are DEVICE-RESIDENT since the zero-host-hop read path:
``last_access`` (a logical event tick — ordering-equivalent to the old
``time.monotonic()`` stamps, including the tie semantics of one shared
stamp per touch event), ``access_count`` and ``insert_seq`` are [L, cap]
int32 ``jnp`` arrays. Touches are scatter-adds fused into the read dispatch
(or one small scatter for the legacy host-join paths); insert-time counter
resets ride the same donated scatter as the row write. Host code
(``select_victim``, save/load, tests) reads them through a lazily-synced
numpy mirror — the ``last_access``/``access_count``/``insert_seq``
properties — which only pays a device->host copy after a fused read touched
counters on device.

For cosine lanes the bank keeps rows unit-normalized at insert time (dot ==
cosine on unit vectors), so searches skip the per-call [cap, D]
re-normalization entirely. Lanes may carry *mixed metrics* (per-lane metric
tags: cosine/dot/euclidean) — the fused jnp search scores each lane under
its own metric in one program, and the Pallas kernel covers cosine+dot
mixes by scoring raw dots against unit rows and rescaling cosine lanes by
1/|q| (rank-preserving). Search backends: a jitted jnp einsum+top_k path,
or the ``similarity_topk`` Pallas kernel with its batched-lanes grid
(``use_pallas=True``); the kernel backend (interpret vs compiled) is
auto-selected per JAX backend via ``repro.kernels.backend``.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_KERNEL_METRICS = ("cosine", "dot")  # metrics the Pallas kernel path covers
_INT32_MIN = np.iinfo(np.int32).min
# renumber the logical event clock well before int32 saturates (headroom for
# one batch worth of ticks past the check)
_TICK_COMPACT_AT = np.iinfo(np.int32).max - (1 << 20)
# lifecycle epoch: created/expires stamps are float seconds RELATIVE to this
# process-wide origin, so every bank in the process shares one time base
# (adoption copies stamps verbatim) and the device float32 copies keep
# sub-second precision over any realistic process lifetime. Snapshots persist
# absolute times and re-base on load.
_EPOCH = time.time()


def bucket_len(n: int) -> int:
    """THE bucketing policy: the next power-of-two length >= n (>= 1).
    Every padded host->device block (rows, scatter indices, touch lists)
    uses this so jits compile O(log N) variants, not one per size."""
    return 1 << (n - 1).bit_length() if n > 1 else 1


def pad_to_bucket(rows: np.ndarray) -> Tuple[np.ndarray, int]:
    """Zero-pad a [N, D] block to the next power-of-two row bucket.

    Serving drains variable-size micro-batches; an unbucketed jit would
    recompile per distinct N (stalling the lookup scheduler for hundreds of
    ms at each new size). Returns the padded block and the original N so the
    caller can slice the result back down. Shared by the in-memory and
    sharded search paths.
    """
    n = rows.shape[0]
    bucket = bucket_len(n)
    if bucket > n:
        rows = np.concatenate(
            [rows, np.zeros((bucket - n, *rows.shape[1:]), rows.dtype)]
        )
    return rows, n


def prepare_scatter(
    idxs: List[int], rows: np.ndarray, *extras: np.ndarray
) -> Tuple[np.ndarray, ...]:
    """Build the (rows, idxs, *extras) update for a multi-row
    ``buf.at[idxs].set``.

    Deduplicates repeated slots last-write-wins (a batch that wraps capacity
    may pick the same victim twice; XLA scatter order for conflicting updates
    is implementation-defined, the sequential loop's is not) and pads to the
    next power-of-two bucket by repeating the final update (identical
    duplicate writes are order-independent) so the scatter jit compiles per
    bucket, not per batch size. ``extras`` are per-row arrays (insert ticks,
    sequence numbers) deduped and padded in lockstep. Shared by the
    in-memory and sharded stores.
    """
    slot_to_row: Dict[int, int] = {}
    for j, idx in enumerate(idxs):
        slot_to_row[idx] = j
    out_idx = np.fromiter(slot_to_row.keys(), np.int32, len(slot_to_row))
    keep = np.fromiter(slot_to_row.values(), np.int64, len(slot_to_row))
    out_rows = rows[keep]
    out_extras = [np.asarray(e)[keep] for e in extras]
    bucket = bucket_len(len(out_idx))
    if bucket > len(out_idx):
        pad = bucket - len(out_idx)
        out_idx = np.concatenate([out_idx, np.repeat(out_idx[-1:], pad)])
        out_rows = np.concatenate([out_rows, np.repeat(out_rows[-1:], pad, axis=0)])
        out_extras = [
            np.concatenate([e, np.repeat(e[-1:], pad, axis=0)]) for e in out_extras
        ]
    return (out_rows, out_idx, *out_extras)


def select_victim(
    eviction: str,
    last_access: np.ndarray,
    access_count: np.ndarray,
    insert_seq: np.ndarray,
) -> int:
    """Pick the slot an lru/lfu/fifo policy evicts (flat index into the
    given counter views). One victim rule for every lane view — the
    in-memory store and the sharded DB evict identically."""
    if eviction == "fifo":
        return int(np.argmin(insert_seq))
    if eviction == "lfu":
        return int(np.argmin(access_count))
    return int(np.argmin(last_access))


def _normalize_rows(rows: jax.Array) -> jax.Array:
    return rows / jnp.maximum(jnp.linalg.norm(rows, axis=-1, keepdims=True), 1e-9)


# -- module-level jits: compiled once per shape and shared by every bank ------


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6),
                   static_argnames=("normalize",))
def _bank_scatter(buf, valid, last, cnt, seq, created, expires, lane, idxs, rows,
                  c_lanes, c_idxs, c_ticks, c_seqs, c_cnts, c_created, c_expires,
                  *, normalize: bool):
    """Row scatter with the insert-time counter AND lifecycle resets fused in:
    one donated device update covers rows, masks,
    last_access/access_count/insert_seq, and created/expires stamps for the
    claimed slots (slots deduped host-side; padding repeats the final update
    with identical values, so conflicting-order scatter is moot).
    ``c_cnts`` is 0 for a fresh insert and the preserved count for a tier-1
    promotion restoring a demoted entry."""
    if normalize:
        rows = _normalize_rows(rows)
    return (
        buf.at[lane, idxs].set(rows),
        valid.at[lane, idxs].set(True),
        last.at[c_lanes, c_idxs].set(c_ticks),
        cnt.at[c_lanes, c_idxs].set(c_cnts),
        seq.at[c_lanes, c_idxs].set(c_seqs),
        created.at[c_lanes, c_idxs].set(c_created),
        expires.at[c_lanes, c_idxs].set(c_expires),
    )


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
def _bank_counter_set(last, cnt, seq, created, expires,
                      c_lanes, c_idxs, c_ticks, c_seqs, c_cnts, c_created, c_expires):
    return (
        last.at[c_lanes, c_idxs].set(c_ticks),
        cnt.at[c_lanes, c_idxs].set(c_cnts),
        seq.at[c_lanes, c_idxs].set(c_seqs),
        created.at[c_lanes, c_idxs].set(c_created),
        expires.at[c_lanes, c_idxs].set(c_expires),
    )


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5))
def _bank_free(valid, last, cnt, seq, created, expires, lanes, idxs):
    """Freed-slot hygiene in ONE donated update: clearing validity alone
    would leave stale recency/frequency/TTL metadata attached to the slot
    (visible to snapshots, counter mirrors, and any future policy that scans
    invalid slots) — a remove resets the slot's whole metadata row."""
    return (
        valid.at[lanes, idxs].set(False),
        last.at[lanes, idxs].set(0),
        cnt.at[lanes, idxs].set(0),
        seq.at[lanes, idxs].set(0),
        created.at[lanes, idxs].set(0.0),
        expires.at[lanes, idxs].set(jnp.inf),
    )


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _bank_touch(last, cnt, lanes, idxs, weights, tick):
    """Batched recency/frequency bump: one scatter for N (lane, idx) touches.
    ``weights`` is 1 per real touch and 0 for bucket padding — duplicate
    (lane, idx) pairs accumulate in ``access_count`` (add commutes) and share
    one tick in ``last_access`` (max of equal values), exactly matching the
    sequential host loop's one-stamp-per-event semantics."""
    stamp = jnp.where(weights > 0, tick, jnp.int32(_INT32_MIN))
    return last.at[lanes, idxs].max(stamp), cnt.at[lanes, idxs].add(weights)


def _lane_scores(db, q, metric: str, prenormalized: bool):
    """db [.., N, D] x q [Q, D] -> scores [.., Q, N] (higher = more similar)."""
    q = q.astype(jnp.float32)
    db = db.astype(jnp.float32)
    if metric == "cosine":
        if not prenormalized:
            db = _normalize_rows(db)
        q = _normalize_rows(q)
        return jnp.einsum("qd,...nd->...qn", q, db)
    if metric == "dot":
        return jnp.einsum("qd,...nd->...qn", q, db)
    if metric == "euclidean":
        d2 = (
            jnp.sum(q * q, -1)[:, None]
            - 2 * jnp.einsum("qd,...nd->...qn", q, db)
            + jnp.sum(db * db, -1)[..., None, :]
        )
        return -jnp.sqrt(jnp.maximum(d2, 0.0))
    raise ValueError(f"unknown metric {metric!r}")


def fused_search_body(buf, valid, q, k: int, metrics: tuple, prenorm: tuple):
    """Traced body of the fused all-lanes search, shared by the standalone
    jit below and the zero-host-hop read program (repro.core.read_path):
    buf [L, cap, D], valid [L, cap], q [Q, D] -> ([Q, L, k], [Q, L, k]).
    Uniform-metric banks score all lanes in one einsum; mixed-metric banks
    score each lane under its own per-lane metric tag — still one program,
    one dispatch."""
    if len(set(metrics)) == 1:
        s = _lane_scores(buf, q, metrics[0], all(prenorm))  # [L, Q, cap]
    else:
        s = jnp.stack([
            _lane_scores(buf[li], q, metrics[li], prenorm[li])
            for li in range(len(metrics))
        ])
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    ts, ti = jax.lax.top_k(s, k)  # [L, Q, k]
    return ts.transpose(1, 0, 2), ti.transpose(1, 0, 2)


@functools.lru_cache(maxsize=None)
def _fused_search_jnp(k: int, metrics: tuple, prenorm: tuple):
    return jax.jit(functools.partial(fused_search_body, k=k, metrics=metrics,
                                     prenorm=prenorm))


@functools.lru_cache(maxsize=None)
def _lane_search_jnp(k: int, metric: str, prenormalized: bool):
    def fn(buf, valid, lane, q):  # one lane, sliced inside the jit (no copy hop)
        s = _lane_scores(buf[lane], q, metric, prenormalized)  # [Q, cap]
        s = jnp.where(valid[lane][None, :], s, -jnp.inf)
        return jax.lax.top_k(s, k)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _lane_search_pallas(k: int, metric: str, interpret: bool, prenormalized: bool):
    from repro.kernels.similarity_topk.ops import _similarity_topk_lanes

    def fn(buf, valid, lane, q):
        s, i = _similarity_topk_lanes(
            buf[lane][None], valid[lane][None], q, k=k, metric=(metric,),
            block_n=None, interpret=interpret, prenormalized=prenormalized,
        )
        return s[:, 0], i[:, 0]

    return jax.jit(fn)


class StoreBank:
    """Device-resident multi-lane store: stacked [L, cap, D] rows + masks +
    per-lane device eviction counters + the fused search dispatch."""

    def __init__(
        self,
        dim: int,
        capacities: Sequence[int],
        *,
        metric="cosine",  # one metric for every lane, or a per-lane sequence
        use_pallas: bool = False,
        interpret: Optional[bool] = None,
        buf: Optional[jax.Array] = None,
        valid: Optional[jax.Array] = None,
    ):
        self.dim = dim
        self.use_pallas = use_pallas
        self.interpret = interpret  # None = auto (repro.kernels.backend)
        self.capacities = list(capacities)
        self.L = len(self.capacities)
        self.cap = max(self.capacities)
        if isinstance(metric, str):
            self.metrics: Tuple[str, ...] = (metric,) * self.L
        else:
            self.metrics = tuple(metric)
            assert len(self.metrics) == self.L
        # cosine lanes hold unit rows: normalize once at insert, never at search
        self.prenorm: Tuple[bool, ...] = tuple(m == "cosine" for m in self.metrics)
        self.buf = (
            buf if buf is not None else jnp.zeros((self.L, self.cap, dim), jnp.float32)
        )
        self.valid = (
            valid if valid is not None else jnp.zeros((self.L, self.cap), bool)
        )
        # per-lane recency/frequency/insertion counters: DEVICE arrays, shared
        # by every lane view's eviction policy (LRU/LFU over sharded lanes
        # too). last_access holds logical event ticks — order-equivalent to
        # wall-clock stamps, and exactly one tick per touch event so argmin
        # tie-breaking matches the old host loop.
        self.d_last_access = jnp.zeros((self.L, self.cap), jnp.int32)
        self.d_access_count = jnp.zeros((self.L, self.cap), jnp.int32)
        self.d_insert_seq = jnp.zeros((self.L, self.cap), jnp.int32)
        self._mirror: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = (
            np.zeros((self.L, self.cap), np.int32),
            np.zeros((self.L, self.cap), np.int32),
            np.zeros((self.L, self.cap), np.int32),
        )
        # entry lifecycle: created/expires stamps (seconds relative to the
        # process _EPOCH). The device float32 copies feed the fused read
        # program's expiry mask + staleness penalty; the float64 host arrays
        # are the source of truth — lifecycle only changes on host-initiated
        # paths (insert/remove/clear), so unlike the eviction counters they
        # never go stale and need no mirror-sync machinery.
        self.d_created = jnp.zeros((self.L, self.cap), jnp.float32)
        self.d_expires = jnp.full((self.L, self.cap), jnp.inf, jnp.float32)
        self.h_created = np.zeros((self.L, self.cap), np.float64)
        self.h_expires = np.full((self.L, self.cap), np.inf, np.float64)
        # per-lane staleness weight: an aging entry's effective score drops by
        # w * age_fraction (0 at insert -> w at expiry), so it must beat a
        # correspondingly higher threshold. 0 = scoring unchanged.
        self.staleness_w = np.zeros(self.L, np.float32)
        self._d_stale: Optional[jax.Array] = None  # device cache of staleness_w
        self._ttl_live = False  # any finite expiry ever installed
        self._tick = 1  # 0 = never touched/inserted
        # insert-time counter updates awaiting the next row scatter (claims
        # run host-side first; the device catches up in the same donated
        # update that writes the rows)
        self._pending: List[Tuple[int, int, int, int, int, float, float]] = []
        self._free_jit = _bank_free  # sharded lane views swap in a sharded jit
        self.dispatches = 0  # fused/device search dispatches issued by this bank
        self.counter_scatters = 0  # standalone counter scatters (non-fused paths)
        self.free_scatters = 0  # slot-free updates (remove/clear; off the read path)
        self.host_hops = 0  # host<->device data hops on the search path

    # -- metric helpers --------------------------------------------------------

    @property
    def metric(self) -> str:
        """Uniform metric name, or "mixed" for per-lane-tagged banks."""
        return self.metrics[0] if len(set(self.metrics)) == 1 else "mixed"

    @property
    def prenormalized(self) -> bool:
        return all(self.prenorm)

    def _kernel_ok(self) -> bool:
        return all(m in _KERNEL_METRICS for m in self.metrics)

    # -- entry lifecycle (TTL/expiry + staleness) ------------------------------

    @staticmethod
    def rel_now() -> float:
        """Current time on the bank's relative clock (seconds since _EPOCH)."""
        return time.time() - _EPOCH

    @staticmethod
    def to_rel(abs_time: float) -> float:
        return abs_time - _EPOCH if np.isfinite(abs_time) else float("inf")

    @staticmethod
    def to_abs(rel_time: float) -> float:
        return rel_time + _EPOCH if np.isfinite(rel_time) else float("inf")

    def lifecycle_active(self) -> bool:
        """True once any entry carries a finite TTL or any lane scores with a
        staleness penalty — the read paths skip all lifecycle math until then,
        so TTL-free deployments pay nothing."""
        return self._ttl_live or bool((self.staleness_w != 0).any())

    def set_staleness(self, lane: int, weight: float) -> None:
        self.staleness_w[lane] = np.float32(weight)
        self._d_stale = None

    def d_staleness(self) -> jax.Array:
        if self._d_stale is None:
            self._d_stale = jnp.asarray(self.staleness_w)
        return self._d_stale

    def set_lifecycle(self, created_rel: np.ndarray, expires_rel: np.ndarray) -> None:
        """Install full lifecycle arrays (adoption / snapshot load), in the
        relative-seconds representation."""
        self.h_created = np.asarray(created_rel, np.float64).copy()
        self.h_expires = np.asarray(expires_rel, np.float64).copy()
        self.d_created = jnp.asarray(self.h_created.astype(np.float32))
        self.d_expires = jnp.asarray(self.h_expires.astype(np.float32))
        if np.isfinite(self.h_expires).any():
            self._ttl_live = True

    def lifecycle_rescore(
        self, scores: np.ndarray, lanes, idx: np.ndarray, now: Optional[float] = None
    ) -> Optional[np.ndarray]:
        """Host-side expiry mask + staleness penalty for the legacy search
        paths (the fused read program applies the same rule in-program):
        expired candidates drop to -inf (never served; ``join_candidates``'
        finite filter discards them), live TTL'd candidates lose
        ``w[lane] * clip(age / ttl, 0, 1)``. Returns the effective scores
        (same shape as ``scores``; the caller re-sorts), or None when no
        lifecycle state is active — pure numpy, zero extra dispatches."""
        if not self.lifecycle_active():
            return None
        now = self.rel_now() if now is None else now
        lanes = np.broadcast_to(np.asarray(lanes, np.int64), idx.shape)
        c = self.h_created[lanes, idx]
        e = self.h_expires[lanes, idx]
        s = np.asarray(scores, np.float32).copy()
        finite = np.isfinite(s)
        expired = finite & (e <= now)
        aging = finite & ~expired & np.isfinite(e)
        if aging.any():
            frac = np.clip(
                (now - c[aging]) / np.maximum(e[aging] - c[aging], 1e-6), 0.0, 1.0
            )
            s[aging] -= (self.staleness_w[lanes[aging]] * frac).astype(np.float32)
        s[expired] = -np.inf
        return s

    @staticmethod
    def resort_desc(s: np.ndarray, idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Re-establish descending score order after lifecycle rescoring
        (decide rules assume candidates arrive best-first); stable, so
        untouched rows keep their original top-k order exactly."""
        order = np.argsort(-s, axis=-1, kind="stable")
        return np.take_along_axis(s, order, -1), np.take_along_axis(idx, order, -1)

    # -- counters: device truth + lazily-synced host mirror --------------------

    def next_tick(self) -> int:
        if self._tick >= _TICK_COMPACT_AT:
            self._compact_ticks()
        t = self._tick
        self._tick += 1
        return t

    def _compact_ticks(self) -> None:
        """Renumber last_access ticks densely (order- and tie-preserving
        rank transform) before the int32 event clock saturates: at most
        L*cap distinct stamps survive, so the clock restarts near zero.
        Runs once every ~2B touch events — one host sync + one upload."""
        self.flush_pending()  # pre-compaction ticks must not resurface later
        last, cnt, seq = self.counters_host()
        ranks = np.unique(last, return_inverse=True)[1]
        self.set_counters(ranks.reshape(last.shape).astype(np.int32), cnt, seq)
        self._tick = int(ranks.max(initial=0)) + 1

    def counters_host(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host view of the device counters (synced on demand; only a fused
        read invalidates it, so eviction-time syncs cost one copy per dirty
        epoch, not one per insert). A clean mirror already reflects pending
        insert claims (note_insert writes it in place), so no flush happens
        here — victim selection between claims in one add_batch stays free;
        only a dirty mirror forces the pending flush + device copy."""
        if self._mirror is None:
            self.flush_pending()
            # np.array (not asarray): jax arrays view as read-only, and the
            # mirror takes in-place updates from note_insert/touch_slots
            self._mirror = (
                np.array(self.d_last_access),
                np.array(self.d_access_count),
                np.array(self.d_insert_seq),
            )
        return self._mirror

    @property
    def last_access(self) -> np.ndarray:
        return self.counters_host()[0]

    @property
    def access_count(self) -> np.ndarray:
        return self.counters_host()[1]

    @property
    def insert_seq(self) -> np.ndarray:
        return self.counters_host()[2]

    def adopt_fused_counters(self, new_last: jax.Array, new_cnt: jax.Array) -> None:
        """Install counters returned by a fused read program (the donated
        scatter-add already applied on device); the host mirror goes stale."""
        self.d_last_access = new_last
        self.d_access_count = new_cnt
        self._mirror = None

    def set_counters(self, last: np.ndarray, cnt: np.ndarray, seq: np.ndarray) -> None:
        """Install full counter arrays (adoption / snapshot load)."""
        last = np.asarray(last, np.int32)
        cnt = np.asarray(cnt, np.int32)
        seq = np.asarray(seq, np.int32)
        self.d_last_access = jnp.asarray(last)
        self.d_access_count = jnp.asarray(cnt)
        self.d_insert_seq = jnp.asarray(seq)
        self._mirror = (last.copy(), cnt.copy(), seq.copy())
        self._tick = max(self._tick, int(last.max(initial=0)) + 1)

    def note_insert(
        self,
        lane: int,
        idx: int,
        seq: int,
        *,
        created: Optional[float] = None,
        expires: Optional[float] = None,
        count: int = 0,
    ) -> None:
        """Counter + lifecycle bookkeeping for one claimed slot. The device
        update is deferred into the next row scatter; the host mirror (when
        clean) and the host lifecycle arrays are updated immediately so
        victim selection inside the same add_batch sees earlier claims.
        ``created``/``expires`` are relative-clock stamps (defaults: now /
        never); ``count`` restores a promoted entry's access_count."""
        tick = self.next_tick()
        created = self.rel_now() if created is None else float(created)
        expires = float("inf") if expires is None else float(expires)
        if np.isfinite(expires):
            self._ttl_live = True
        if self._mirror is not None:
            ml, mc, ms = self._mirror
            ml[lane, idx] = tick
            mc[lane, idx] = count
            ms[lane, idx] = seq
        self.h_created[lane, idx] = created
        self.h_expires[lane, idx] = expires
        self._pending.append((lane, idx, tick, seq, count, created, expires))

    def _drain_pending(self):
        """Pending insert-counter updates as bucketed scatter arrays
        (last-wins dedupe per slot, padding repeats the final update)."""
        last_wins: Dict[Tuple[int, int], Tuple[int, int, int, float, float]] = {}
        for lane, idx, tick, seq, count, created, expires in self._pending:
            last_wins[(lane, idx)] = (tick, seq, count, created, expires)
        self._pending.clear()
        n = len(last_wins)
        lanes = np.fromiter((k[0] for k in last_wins), np.int32, n)
        idxs = np.fromiter((k[1] for k in last_wins), np.int32, n)
        ticks = np.fromiter((v[0] for v in last_wins.values()), np.int32, n)
        seqs = np.fromiter((v[1] for v in last_wins.values()), np.int32, n)
        cnts = np.fromiter((v[2] for v in last_wins.values()), np.int32, n)
        created = np.fromiter((v[3] for v in last_wins.values()), np.float32, n)
        expires = np.fromiter((v[4] for v in last_wins.values()), np.float32, n)
        cols = [lanes, idxs, ticks, seqs, cnts, created, expires]
        bucket = bucket_len(n)
        if bucket > n:
            pad = bucket - n
            cols = [np.concatenate([c, np.repeat(c[-1:], pad)]) for c in cols]
        return tuple(cols)

    def flush_pending(self) -> None:
        """Push deferred insert-counter updates to device (normally they ride
        the row scatter; this standalone path is a safety net for callers
        that read counters between a claim and its ``set_rows``)."""
        if not self._pending:
            return
        cl, ci, ct, cs, cc, ccr, cex = self._drain_pending()
        self.counter_scatters += 1
        (
            self.d_last_access, self.d_access_count, self.d_insert_seq,
            self.d_created, self.d_expires,
        ) = _bank_counter_set(
            self.d_last_access, self.d_access_count, self.d_insert_seq,
            self.d_created, self.d_expires,
            jnp.asarray(cl), jnp.asarray(ci), jnp.asarray(ct), jnp.asarray(cs),
            jnp.asarray(cc), jnp.asarray(ccr), jnp.asarray(cex),
        )

    def touch_slots(self, lanes, idxs) -> None:
        """Bump recency/frequency for N (lane, idx) pairs in ONE device
        scatter (one shared tick per call — the old one-``now``-per-event
        semantics). Duplicate pairs accumulate one count each. Keeps the
        host mirror in sync when it is clean."""
        lanes = np.asarray(lanes, np.int32).reshape(-1)
        idxs = np.asarray(idxs, np.int32).reshape(-1)
        if lanes.size == 0:
            return
        tick = self.next_tick()
        if self._mirror is not None:
            ml, mc, _ = self._mirror
            ml[lanes, idxs] = tick
            np.add.at(mc, (lanes, idxs), 1)
        n = lanes.size
        bucket = bucket_len(n)
        w = np.ones(n, np.int32)
        if bucket > n:
            pad = bucket - n
            lanes = np.concatenate([lanes, np.repeat(lanes[-1:], pad)])
            idxs = np.concatenate([idxs, np.repeat(idxs[-1:], pad)])
            w = np.concatenate([w, np.zeros(pad, np.int32)])
        self.counter_scatters += 1
        self.d_last_access, self.d_access_count = _bank_touch(
            self.d_last_access, self.d_access_count,
            jnp.asarray(lanes), jnp.asarray(idxs), jnp.asarray(w), np.int32(tick),
        )

    # -- device updates --------------------------------------------------------

    def set_rows(self, lane: int, idxs: List[int], rows: np.ndarray,
                 *, pinned: bool = False) -> None:
        """Scatter N raw rows into one lane (ONE donated device update that
        also applies the pending insert-counter/lifecycle resets; rows are
        unit-normalized in-jit for cosine lanes). ``pinned=True`` stages the
        row block through pinned host memory when the backend has it (tier-1
        promotions overlap their H2D copy with the read dispatch they ride
        alongside); pageable numpy fallback on CPU."""
        sel, scatter_idx = prepare_scatter(idxs, np.asarray(rows, np.float32))
        if pinned:
            from repro.kernels.backend import stage_pinned

            sel = stage_pinned(sel)
        cl, ci, ct, cs, cc, ccr, cex = self._drain_pending()
        (
            self.buf, self.valid,
            self.d_last_access, self.d_access_count, self.d_insert_seq,
            self.d_created, self.d_expires,
        ) = _bank_scatter(
            self.buf, self.valid,
            self.d_last_access, self.d_access_count, self.d_insert_seq,
            self.d_created, self.d_expires,
            lane, jnp.asarray(scatter_idx), jnp.asarray(sel),
            jnp.asarray(cl), jnp.asarray(ci), jnp.asarray(ct), jnp.asarray(cs),
            jnp.asarray(cc), jnp.asarray(ccr), jnp.asarray(cex),
            normalize=self.prenorm[lane],
        )

    def invalidate(self, lane: int, idx: int) -> None:
        self.free_slots([lane], [idx])

    def free_slots(self, lanes, idxs) -> None:
        """Free N (lane, idx) slots in ONE donated update, resetting the
        whole metadata row (validity, recency/frequency/insertion counters,
        created/expires) — a recycled slot must be indistinguishable from a
        never-used one. Shared by remove() and clear(older_than) on both
        lane-view stores (the sharded view swaps in a jit with its output
        shardings via ``_free_jit``)."""
        lanes = np.asarray(lanes, np.int32).reshape(-1)
        idxs = np.asarray(idxs, np.int32).reshape(-1)
        if lanes.size == 0:
            return
        # drop any pending insert for a slot freed before its row scatter
        if self._pending:
            freed = set(zip(lanes.tolist(), idxs.tolist()))
            self._pending = [p for p in self._pending if (p[0], p[1]) not in freed]
        if self._mirror is not None:
            ml, mc, ms = self._mirror
            ml[lanes, idxs] = 0
            mc[lanes, idxs] = 0
            ms[lanes, idxs] = 0
        self.h_created[lanes, idxs] = 0.0
        self.h_expires[lanes, idxs] = np.inf
        n = lanes.size
        bucket = bucket_len(n)
        if bucket > n:  # pad repeats the final pair — the free is idempotent
            pad = bucket - n
            lanes = np.concatenate([lanes, np.repeat(lanes[-1:], pad)])
            idxs = np.concatenate([idxs, np.repeat(idxs[-1:], pad)])
        self.free_scatters += 1
        (
            self.valid,
            self.d_last_access, self.d_access_count, self.d_insert_seq,
            self.d_created, self.d_expires,
        ) = self._free_jit(
            self.valid,
            self.d_last_access, self.d_access_count, self.d_insert_seq,
            self.d_created, self.d_expires,
            jnp.asarray(lanes), jnp.asarray(idxs),
        )

    def compact_seqs(self) -> int:
        """Rank-rebase the insert_seq counters before the int32 insertion
        clock saturates — the insert-side twin of ``_compact_ticks`` (same
        order- and tie-preserving rank transform as the legacy-snapshot
        loader). At most L*cap distinct sequence numbers survive, so the
        clock restarts near zero; per-lane fifo victim ordering is unchanged
        (a rank transform is monotone, and it is applied bank-wide so every
        lane view's future inserts stay above every surviving rank). Returns
        the next free sequence number for the calling store."""
        self.flush_pending()
        last, cnt, seq = self.counters_host()
        ranks = np.unique(seq, return_inverse=True)[1].reshape(seq.shape)
        self.set_counters(last, cnt, ranks.astype(np.int32))
        return int(ranks.max(initial=0)) + 1

    # -- search ----------------------------------------------------------------

    def _resolved_interpret(self) -> bool:
        from repro.kernels.backend import resolve_interpret

        return resolve_interpret(self.interpret)

    def search_lane(
        self, lane: int, q_vecs: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k of ONE lane for Q queries in one device dispatch ->
        (scores [Q, k], lane-local idx [Q, k])."""
        self.flush_pending()
        q, n_q = pad_to_bucket(np.atleast_2d(np.asarray(q_vecs, np.float32)))
        self.dispatches += 1
        self.host_hops += 2  # query upload + score download around the dispatch
        metric = self.metrics[lane]
        if self.use_pallas and metric in _KERNEL_METRICS:
            from repro.kernels.similarity_topk import ops as st_ops

            st_ops.record_dispatch()
            fn = _lane_search_pallas(
                k, metric, self._resolved_interpret(), self.prenorm[lane]
            )
        else:
            fn = _lane_search_jnp(k, metric, self.prenorm[lane])
        s, i = fn(self.buf, self.valid, lane, jnp.asarray(q))
        s, i = np.asarray(s)[:n_q], np.asarray(i)[:n_q]
        s_eff = self.lifecycle_rescore(s, lane, i)
        if s_eff is not None:
            s, i = self.resort_desc(s_eff, i)
        return s, i

    def search_lanes(
        self, q_vecs: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fused all-lanes top-k for Q queries in ONE device dispatch ->
        (scores [Q, L, k], lane-local idx [Q, L, k]). Candidates are never
        merged across lanes — cross-lane policy (hierarchy walk order,
        shard merge) stays with the caller, host-side, on these scores."""
        self.flush_pending()
        q, n_q = pad_to_bucket(np.atleast_2d(np.asarray(q_vecs, np.float32)))
        self.dispatches += 1
        self.host_hops += 2
        if self.use_pallas and self._kernel_ok():
            from repro.kernels.similarity_topk.ops import similarity_topk_lanes

            # mixed cosine/dot banks satisfy the kernel's unit-cosine-rows
            # requirement by construction (insert normalizes cosine lanes)
            mixed = len(set(self.metrics)) > 1
            s, i = similarity_topk_lanes(
                self.buf, self.valid, jnp.asarray(q), k=k, metric=self.metrics,
                interpret=self.interpret,
                prenormalized=True if mixed else self.prenormalized,
            )
        else:
            fn = _fused_search_jnp(k, self.metrics, self.prenorm)
            s, i = fn(self.buf, self.valid, jnp.asarray(q))
        s, i = np.asarray(s)[:n_q], np.asarray(i)[:n_q]
        s_eff = self.lifecycle_rescore(s, np.arange(self.L)[None, :, None], i)
        if s_eff is not None:
            s, i = self.resort_desc(s_eff, i)
        return s, i

    # -- lane views ------------------------------------------------------------

    def lane_buf(self, lane: int, capacity: Optional[int] = None) -> jax.Array:
        cap = self.capacities[lane] if capacity is None else capacity
        return self.buf[lane, :cap]

    def lane_valid(self, lane: int, capacity: Optional[int] = None) -> jax.Array:
        cap = self.capacities[lane] if capacity is None else capacity
        return self.valid[lane, :cap]

    # -- composition -----------------------------------------------------------

    @classmethod
    def adopt(cls, stores: Sequence) -> "StoreBank":
        """Stack live lane-view stores into ONE shared bank and repoint each
        store at its row. Contents (rows, masks, counters) are copied from
        each store's current bank lane, so adoption is transparent to the
        stores' own add/search/remove paths — they just start resolving
        against the shared tensor. Per-lane metric tags let mixed-metric
        stores share a bank; mixed dims cannot."""
        dims = {s.dim for s in stores}
        if len(dims) != 1:
            raise ValueError(f"cannot stack stores with mixed dim: {dims}")
        for s in stores:
            s._bank.flush_pending()
        interps = {s._bank.interpret for s in stores}
        bank = cls(
            dims.pop(),
            [s.capacity for s in stores],
            metric=[s.metric for s in stores],
            # conservative: the compiled-kernel path only when every lane opted in
            use_pallas=all(getattr(s, "use_pallas", False) for s in stores),
            # an explicit interpret override shared by every source lane
            # survives adoption (like use_pallas); disagreement falls back
            # to auto-selection
            interpret=interps.pop() if len(interps) == 1 else None,
        )
        buf = np.zeros((bank.L, bank.cap, bank.dim), np.float32)
        valid = np.zeros((bank.L, bank.cap), bool)
        last = np.zeros((bank.L, bank.cap), np.int32)
        cnt = np.zeros((bank.L, bank.cap), np.int32)
        seq = np.zeros((bank.L, bank.cap), np.int32)
        created = np.zeros((bank.L, bank.cap), np.float64)
        expires = np.full((bank.L, bank.cap), np.inf, np.float64)
        for li, s in enumerate(stores):
            ob, ol, cap = s._bank, s._lane, s.capacity
            src_last, src_cnt, src_seq = ob.counters_host()
            buf[li, :cap] = np.asarray(ob.buf[ol, :cap])
            valid[li, :cap] = np.asarray(ob.valid[ol, :cap])
            last[li, :cap] = src_last[ol, :cap]
            cnt[li, :cap] = src_cnt[ol, :cap]
            seq[li, :cap] = src_seq[ol, :cap]
            # lifecycle stamps share the process-wide epoch, so they copy
            # verbatim across banks; per-lane staleness follows the store
            created[li, :cap] = ob.h_created[ol, :cap]
            expires[li, :cap] = ob.h_expires[ol, :cap]
            bank.staleness_w[li] = ob.staleness_w[ol]
        bank.buf = jnp.asarray(buf)
        bank.valid = jnp.asarray(valid)
        bank.set_counters(last, cnt, seq)
        bank.set_lifecycle(created, expires)
        bank._d_stale = None
        bank._tick = max(bank._tick, *(s._bank._tick for s in stores))
        for li, s in enumerate(stores):
            s._bank = bank
            s._lane = li
        return bank
