"""StoreBank: one device-resident [L, cap, D] buffer for many vector stores.

The cache's read path used to issue one ``search_batch`` dispatch per
hierarchy level (and the sharded DB kept a separate flat buffer). The bank
stacks every *lane* — a hierarchy level (private L1 / shared L2 / peers) or
a DB shard — into a single [L, cap, D] embedding tensor with a [L, cap]
validity mask, so a B-query lookup across the whole hierarchy is ONE fused
top-k dispatch:

    [L, cap, D] x [B, D] -> scores [B, L, k], lane-local idx [B, L, k]

``InMemoryVectorStore`` and ``ShardedVectorStore`` are thin lane views over
a bank: each keeps its public add/search/remove API and host-side entry
metadata, while the device tensors, the per-lane recency/frequency counters
(LRU/LFU over any lane, sharded included), and the search dispatch live
here. A standalone store is just a 1-lane bank; ``StoreBank.adopt`` stacks
live stores into a shared bank (repointing each store's lane view) so a
hierarchy's levels become rows of one tensor.

For cosine lanes the bank keeps rows unit-normalized at insert time (dot ==
cosine on unit vectors), so searches skip the per-call [cap, D]
re-normalization entirely. Search backends: a jitted jnp einsum+top_k path,
or the ``similarity_topk`` Pallas kernel with its batched-lanes grid
(``use_pallas=True``); the kernel backend (interpret vs compiled) is
auto-selected per JAX backend via ``repro.kernels.backend``.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pad_to_bucket(rows: np.ndarray) -> Tuple[np.ndarray, int]:
    """Zero-pad a [N, D] block to the next power-of-two row bucket.

    Serving drains variable-size micro-batches; an unbucketed jit would
    recompile per distinct N (stalling the lookup scheduler for hundreds of
    ms at each new size). Returns the padded block and the original N so the
    caller can slice the result back down. Shared by the in-memory and
    sharded search paths.
    """
    n = rows.shape[0]
    bucket = 1 << (n - 1).bit_length() if n > 1 else 1
    if bucket > n:
        rows = np.concatenate(
            [rows, np.zeros((bucket - n, *rows.shape[1:]), rows.dtype)]
        )
    return rows, n


def prepare_scatter(idxs: List[int], rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Build the (rows, idxs) update for a multi-row ``buf.at[idxs].set``.

    Deduplicates repeated slots last-write-wins (a batch that wraps capacity
    may pick the same victim twice; XLA scatter order for conflicting updates
    is implementation-defined, the sequential loop's is not) and pads to the
    next power-of-two bucket by repeating the final update (identical
    duplicate writes are order-independent) so the scatter jit compiles per
    bucket, not per batch size. Shared by the in-memory and sharded stores.
    """
    slot_to_row: Dict[int, int] = {}
    for j, idx in enumerate(idxs):
        slot_to_row[idx] = j
    out_idx = np.fromiter(slot_to_row.keys(), np.int32, len(slot_to_row))
    out_rows = rows[np.fromiter(slot_to_row.values(), np.int64, len(slot_to_row))]
    bucket = 1 << (len(out_idx) - 1).bit_length() if len(out_idx) > 1 else 1
    if bucket > len(out_idx):
        pad = bucket - len(out_idx)
        out_idx = np.concatenate([out_idx, np.repeat(out_idx[-1:], pad)])
        out_rows = np.concatenate([out_rows, np.repeat(out_rows[-1:], pad, axis=0)])
    return out_rows, out_idx


def select_victim(
    eviction: str,
    last_access: np.ndarray,
    access_count: np.ndarray,
    insert_seq: np.ndarray,
) -> int:
    """Pick the slot an lru/lfu/fifo policy evicts (flat index into the
    given counter views). One victim rule for every lane view — the
    in-memory store and the sharded DB evict identically."""
    if eviction == "fifo":
        return int(np.argmin(insert_seq))
    if eviction == "lfu":
        return int(np.argmin(access_count))
    return int(np.argmin(last_access))


def _normalize_rows(rows: jax.Array) -> jax.Array:
    return rows / jnp.maximum(jnp.linalg.norm(rows, axis=-1, keepdims=True), 1e-9)


# -- module-level jits: compiled once per shape and shared by every bank ------


@functools.partial(jax.jit, donate_argnums=(0, 1), static_argnames=("normalize",))
def _bank_scatter(buf, valid, lane, idxs, rows, *, normalize: bool):
    if normalize:
        rows = _normalize_rows(rows)
    return buf.at[lane, idxs].set(rows), valid.at[lane, idxs].set(True)


@functools.partial(jax.jit, donate_argnums=(0,))
def _bank_invalidate(valid, lane, idx):
    return valid.at[lane, idx].set(False)


def _lane_scores(db, q, metric: str, prenormalized: bool):
    """db [.., N, D] x q [Q, D] -> scores [.., Q, N] (higher = more similar)."""
    q = q.astype(jnp.float32)
    db = db.astype(jnp.float32)
    if metric == "cosine":
        if not prenormalized:
            db = _normalize_rows(db)
        q = _normalize_rows(q)
        return jnp.einsum("qd,...nd->...qn", q, db)
    if metric == "dot":
        return jnp.einsum("qd,...nd->...qn", q, db)
    if metric == "euclidean":
        d2 = (
            jnp.sum(q * q, -1)[:, None]
            - 2 * jnp.einsum("qd,...nd->...qn", q, db)
            + jnp.sum(db * db, -1)[..., None, :]
        )
        return -jnp.sqrt(jnp.maximum(d2, 0.0))
    raise ValueError(f"unknown metric {metric!r}")


@functools.lru_cache(maxsize=None)
def _fused_search_jnp(k: int, metric: str, prenormalized: bool):
    def fn(buf, valid, q):  # buf [L, cap, D], valid [L, cap], q [Q, D]
        s = _lane_scores(buf, q, metric, prenormalized)  # [L, Q, cap]
        s = jnp.where(valid[:, None, :], s, -jnp.inf)
        ts, ti = jax.lax.top_k(s, k)  # [L, Q, k]
        return ts.transpose(1, 0, 2), ti.transpose(1, 0, 2)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _lane_search_jnp(k: int, metric: str, prenormalized: bool):
    def fn(buf, valid, lane, q):  # one lane, sliced inside the jit (no copy hop)
        s = _lane_scores(buf[lane], q, metric, prenormalized)  # [Q, cap]
        s = jnp.where(valid[lane][None, :], s, -jnp.inf)
        return jax.lax.top_k(s, k)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _lane_search_pallas(k: int, metric: str, interpret: bool, prenormalized: bool):
    from repro.kernels.similarity_topk.ops import _similarity_topk_lanes

    def fn(buf, valid, lane, q):
        s, i = _similarity_topk_lanes(
            buf[lane][None], valid[lane][None], q, k=k, metric=metric,
            block_n=512, interpret=interpret, prenormalized=prenormalized,
        )
        return s[:, 0], i[:, 0]

    return jax.jit(fn)


class StoreBank:
    """Device-resident multi-lane store: stacked [L, cap, D] rows + masks +
    per-lane eviction counters + the fused search dispatch."""

    def __init__(
        self,
        dim: int,
        capacities: Sequence[int],
        *,
        metric: str = "cosine",
        use_pallas: bool = False,
        interpret: Optional[bool] = None,
        buf: Optional[jax.Array] = None,
        valid: Optional[jax.Array] = None,
    ):
        self.dim = dim
        self.metric = metric
        self.use_pallas = use_pallas
        self.interpret = interpret  # None = auto (repro.kernels.backend)
        self.capacities = list(capacities)
        self.L = len(self.capacities)
        self.cap = max(self.capacities)
        # cosine lanes hold unit rows: normalize once at insert, never at search
        self.prenormalized = metric == "cosine"
        self.buf = (
            buf if buf is not None else jnp.zeros((self.L, self.cap, dim), jnp.float32)
        )
        self.valid = (
            valid if valid is not None else jnp.zeros((self.L, self.cap), bool)
        )
        # per-lane recency/frequency/insertion counters (host-side, shared by
        # every lane view's eviction policy — LRU/LFU over sharded lanes too)
        self.last_access = np.zeros((self.L, self.cap), np.float64)
        self.access_count = np.zeros((self.L, self.cap), np.int64)
        self.insert_seq = np.zeros((self.L, self.cap), np.int64)
        self.dispatches = 0  # fused/device search dispatches issued by this bank

    # -- device updates --------------------------------------------------------

    def set_rows(self, lane: int, idxs: List[int], rows: np.ndarray) -> None:
        """Scatter N raw rows into one lane (ONE donated device update;
        rows are unit-normalized in-jit for cosine banks)."""
        sel, scatter_idx = prepare_scatter(idxs, np.asarray(rows, np.float32))
        self.buf, self.valid = _bank_scatter(
            self.buf, self.valid, lane, jnp.asarray(scatter_idx), jnp.asarray(sel),
            normalize=self.prenormalized,
        )

    def invalidate(self, lane: int, idx: int) -> None:
        self.valid = _bank_invalidate(self.valid, lane, idx)

    # -- search ----------------------------------------------------------------

    def _resolved_interpret(self) -> bool:
        from repro.kernels.backend import resolve_interpret

        return resolve_interpret(self.interpret)

    def search_lane(
        self, lane: int, q_vecs: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k of ONE lane for Q queries in one device dispatch ->
        (scores [Q, k], lane-local idx [Q, k])."""
        q, n_q = pad_to_bucket(np.atleast_2d(np.asarray(q_vecs, np.float32)))
        self.dispatches += 1
        if self.use_pallas:
            from repro.kernels.similarity_topk import ops as st_ops

            st_ops.record_dispatch()
            fn = _lane_search_pallas(
                k, self.metric, self._resolved_interpret(), self.prenormalized
            )
        else:
            fn = _lane_search_jnp(k, self.metric, self.prenormalized)
        s, i = fn(self.buf, self.valid, lane, jnp.asarray(q))
        return np.asarray(s)[:n_q], np.asarray(i)[:n_q]

    def search_lanes(
        self, q_vecs: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fused all-lanes top-k for Q queries in ONE device dispatch ->
        (scores [Q, L, k], lane-local idx [Q, L, k]). Candidates are never
        merged across lanes — cross-lane policy (hierarchy walk order,
        shard merge) stays with the caller, host-side, on these scores."""
        q, n_q = pad_to_bucket(np.atleast_2d(np.asarray(q_vecs, np.float32)))
        self.dispatches += 1
        if self.use_pallas:
            from repro.kernels.similarity_topk.ops import similarity_topk_lanes

            s, i = similarity_topk_lanes(
                self.buf, self.valid, jnp.asarray(q), k=k, metric=self.metric,
                interpret=self.interpret, prenormalized=self.prenormalized,
            )
        else:
            fn = _fused_search_jnp(k, self.metric, self.prenormalized)
            s, i = fn(self.buf, self.valid, jnp.asarray(q))
        return np.asarray(s)[:n_q], np.asarray(i)[:n_q]

    # -- lane views ------------------------------------------------------------

    def lane_buf(self, lane: int, capacity: Optional[int] = None) -> jax.Array:
        cap = self.capacities[lane] if capacity is None else capacity
        return self.buf[lane, :cap]

    def lane_valid(self, lane: int, capacity: Optional[int] = None) -> jax.Array:
        cap = self.capacities[lane] if capacity is None else capacity
        return self.valid[lane, :cap]

    def note_insert(self, lane: int, idx: int, seq: int) -> None:
        self.last_access[lane, idx] = time.monotonic()
        self.access_count[lane, idx] = 0
        self.insert_seq[lane, idx] = seq

    # -- composition -----------------------------------------------------------

    @classmethod
    def adopt(cls, stores: Sequence) -> "StoreBank":
        """Stack live lane-view stores into ONE shared bank and repoint each
        store at its row. Contents (rows, masks, counters) are copied from
        each store's current bank lane, so adoption is transparent to the
        stores' own add/search/remove paths — they just start resolving
        against the shared tensor."""
        dims = {s.dim for s in stores}
        metrics = {s.metric for s in stores}
        if len(dims) != 1 or len(metrics) != 1:
            raise ValueError(
                f"cannot stack stores with mixed dim/metric: {dims}/{metrics}"
            )
        bank = cls(
            dims.pop(),
            [s.capacity for s in stores],
            metric=metrics.pop(),
            # conservative: the compiled-kernel path only when every lane opted in
            use_pallas=all(getattr(s, "use_pallas", False) for s in stores),
        )
        buf = np.zeros((bank.L, bank.cap, bank.dim), np.float32)
        valid = np.zeros((bank.L, bank.cap), bool)
        for li, s in enumerate(stores):
            ob, ol, cap = s._bank, s._lane, s.capacity
            buf[li, :cap] = np.asarray(ob.buf[ol, :cap])
            valid[li, :cap] = np.asarray(ob.valid[ol, :cap])
            bank.last_access[li, :cap] = ob.last_access[ol, :cap]
            bank.access_count[li, :cap] = ob.access_count[ol, :cap]
            bank.insert_seq[li, :cap] = ob.insert_seq[ol, :cap]
        bank.buf = jnp.asarray(buf)
        bank.valid = jnp.asarray(valid)
        for li, s in enumerate(stores):
            s._bank = bank
            s._lane = li
        return bank
