"""Capacity tiers behind the device bank.

The device ``StoreBank`` is tier 0: fast, searched by the fused read
program, but capped at HBM. This module adds the two layers behind it:

- ``HostRamTier`` (tier 1): a host-RAM ring per lane (numpy, optionally
  mmap-backed). Eviction victims demote here instead of vanishing; the
  read path consults it host-side only after a tier-0 miss, so the fused
  hot path stays one dispatch / zero host hops. Tier-1 hits promote back
  into the device lane through the same batched row-scatter inserts use.
- ``SnapshotTier`` (tier 2): a persistent export/import of a store's full
  contents (tier 0 + tier 1) for warm-starts and cross-deployment cache
  sharing (§4 "bring a cache to a warm state").

Entries keep their identity across tiers: ``TierEntry`` carries the key,
texts, lifecycle stamps, and access count, so a demote -> promote
roundtrip is byte-identical to never having left the device.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class TierEntry:
    """A cache entry in transit between tiers — everything needed to
    reconstruct it exactly where it lands."""

    key: int
    query: str
    response: str
    meta: Dict[str, Any] = field(default_factory=dict)
    created_at: float = 0.0  # absolute unix seconds
    expires_at: float = float("inf")
    access_count: int = 0

    def expired(self, now: Optional[float] = None) -> bool:
        return self.expires_at <= (time.time() if now is None else now)


def _normalize(rows: np.ndarray) -> np.ndarray:
    norms = np.maximum(np.linalg.norm(rows, axis=-1, keepdims=True), 1e-9)
    return rows / norms


def _host_scores(db: np.ndarray, q: np.ndarray, metric: str) -> np.ndarray:
    """Numpy twin of store_bank._lane_scores: [N, D] x [Q, D] -> [Q, N],
    higher = more similar. Cosine rows are stored unit-norm (mirroring the
    prenormalized device bank), so only the query needs normalizing."""
    q = np.asarray(q, np.float32)
    if metric == "cosine":
        return _normalize(q) @ db.T
    if metric == "dot":
        return q @ db.T
    if metric == "euclidean":
        d2 = (
            np.sum(q * q, -1)[:, None]
            - 2.0 * (q @ db.T)
            + np.sum(db * db, -1)[None, :]
        )
        return -np.sqrt(np.maximum(d2, 0.0))
    raise ValueError(f"unknown metric {metric!r}")


class HostRamTier:
    """Fixed-capacity host-RAM ring holding demoted entries.

    Off the hot path by construction: ``put`` is a numpy row copy at
    eviction time, ``search`` is a host matmul run only for queries that
    already missed tier 0. ``mmap_path`` backs the vector block with a
    file-mapped array so a large tier 1 doesn't compete with the host
    working set for RAM.
    """

    def __init__(
        self,
        dim: int,
        capacity: int = 65536,
        metric: str = "cosine",
        staleness_weight: float = 0.0,
        mmap_path: Optional[str] = None,
    ):
        assert capacity > 0
        self.dim = dim
        self.capacity = capacity
        self.metric = metric
        self.staleness_weight = float(staleness_weight)
        if mmap_path is not None:
            os.makedirs(os.path.dirname(mmap_path) or ".", exist_ok=True)
            self._vecs = np.lib.format.open_memmap(
                mmap_path, mode="w+", dtype=np.float32, shape=(capacity, dim)
            )
        else:
            self._vecs = np.zeros((capacity, dim), np.float32)
        self._entries: List[Optional[TierEntry]] = [None] * capacity
        self._key_to_slot: Dict[int, int] = {}
        self._ptr = 0  # ring head: oldest demotion is overwritten first
        self.size = 0
        self.demotions = 0
        self.promotions = 0

    def __len__(self) -> int:
        return self.size

    # -- demote ------------------------------------------------------------

    def put(self, entry: TierEntry, vec: np.ndarray) -> int:
        """Accept a demoted entry (ring-overwrite of the oldest demotion
        once full; a re-demoted key overwrites its stale tier copy)."""
        row = np.asarray(vec, np.float32).reshape(self.dim)
        if self.metric == "cosine":
            row = _normalize(row[None])[0]
        slot = self._key_to_slot.get(entry.key)
        if slot is None:
            slot = self._ptr
            self._ptr = (self._ptr + 1) % self.capacity
            old = self._entries[slot]
            if old is not None:
                self._key_to_slot.pop(old.key, None)
                self.size -= 1
            self.size += 1
        self._entries[slot] = entry
        self._key_to_slot[entry.key] = slot
        self._vecs[slot] = row
        self.demotions += 1
        return slot

    # -- consult (tier-0 miss only) -----------------------------------------

    def search(
        self, q_vecs: np.ndarray, k: int = 1, now: Optional[float] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over the ring with the SAME lifecycle rules as tier 0:
        expired entries score -inf (never served), TTL'd live entries lose
        ``staleness_weight * clip(age/ttl, 0, 1)``. Returns (effective
        scores [Q, k], slots [Q, k])."""
        q = np.atleast_2d(np.asarray(q_vecs, np.float32))
        nq = q.shape[0]
        k = max(1, min(k, self.capacity))
        if self.size == 0:
            return (
                np.full((nq, k), -np.inf, np.float32),
                np.zeros((nq, k), np.int64),
            )
        now = time.time() if now is None else now
        s = _host_scores(self._vecs, q, self.metric).astype(np.float32)
        dead = np.array(
            [e is None or e.expires_at <= now for e in self._entries], bool
        )
        s[:, dead] = -np.inf
        if self.staleness_weight != 0.0:
            pen = np.zeros(self.capacity, np.float32)
            for i, e in enumerate(self._entries):
                if e is None or dead[i] or not np.isfinite(e.expires_at):
                    continue
                ttl = max(e.expires_at - e.created_at, 1e-6)
                pen[i] = self.staleness_weight * min(max((now - e.created_at) / ttl, 0.0), 1.0)
            s = s - pen[None, :]
        order = np.argsort(-s, axis=-1, kind="stable")[:, :k]
        return np.take_along_axis(s, order, -1), order.astype(np.int64)

    def get(self, slot: int) -> Optional[TierEntry]:
        return self._entries[slot]

    # -- promote -------------------------------------------------------------

    def pop(self, slot: int) -> Tuple[TierEntry, np.ndarray]:
        """Remove and return (entry, vector) for promotion back to tier 0."""
        e = self._entries[slot]
        assert e is not None, "pop() of an empty tier-1 slot"
        self._entries[slot] = None
        self._key_to_slot.pop(e.key, None)
        self.size -= 1
        self.promotions += 1
        return e, np.array(self._vecs[slot], np.float32)

    # -- maintenance ---------------------------------------------------------

    def clear(self, older_than: Optional[float] = None) -> int:
        """Drop everything, or with ``older_than`` (seconds) only entries
        created more than that long ago plus anything expired."""
        now = time.time()
        cutoff = None if older_than is None else now - float(older_than)
        dropped = 0
        for i, e in enumerate(self._entries):
            if e is None:
                continue
            if cutoff is None or e.created_at <= cutoff or e.expires_at <= now:
                self._entries[i] = None
                self._key_to_slot.pop(e.key, None)
                self.size -= 1
                dropped += 1
        return dropped

    def snapshot_entries(self) -> List[Tuple[TierEntry, np.ndarray]]:
        """Live (entry, vector) pairs, oldest demotion first (export order)."""
        out = []
        for off in range(self.capacity):
            slot = (self._ptr + off) % self.capacity
            e = self._entries[slot]
            if e is not None:
                out.append((e, np.array(self._vecs[slot], np.float32)))
        return out


class SnapshotTier:
    """Tier 2: persistent snapshot export/import for warm-starts and
    cross-deployment cache sharing.

    ``export_from`` captures a store's full live contents — device lane
    (tier 0) plus any attached host ring (tier 1) — as one npz + manifest
    under ``path``. ``import_into`` replays a snapshot into any compatible
    store: entries are re-keyed into the target's key space but keep their
    lifecycle stamps and access counts, already-expired entries are skipped,
    and rows arrive oldest-created first so when the snapshot exceeds the
    device capacity the newest entries stay in tier 0 and the overflow
    demotes naturally into the target's tier 1.
    """

    def __init__(self, path: str):
        self.path = path

    def _vec_path(self) -> str:
        return os.path.join(self.path, "snapshot.npz")

    def _manifest_path(self) -> str:
        return os.path.join(self.path, "snapshot.json")

    def export_from(self, store) -> int:
        """Snapshot every live, unexpired entry of ``store`` (tier 0 then
        tier 1). One device pull of the lane rows — fine off the hot path."""
        now = time.time()
        rows: List[np.ndarray] = []
        items: List[dict] = []
        lane_rows = np.asarray(store._buf)  # [cap, D] device pull
        counts = np.asarray(store._access_count)
        for idx, e in enumerate(store._entries):
            if e is None or e.expires_at <= now:
                continue
            rows.append(np.asarray(lane_rows[idx], np.float32))
            items.append(
                {
                    "query": e.query,
                    "response": e.response,
                    "meta": e.meta,
                    "created_at": e.created_at,
                    "expires_at": None if not np.isfinite(e.expires_at) else e.expires_at,
                    "access_count": int(counts[idx]),
                }
            )
        if getattr(store, "tier1", None) is not None:
            for e, vec in store.tier1.snapshot_entries():
                if e.expires_at <= now:
                    continue
                rows.append(vec)
                items.append(
                    {
                        "query": e.query,
                        "response": e.response,
                        "meta": e.meta,
                        "created_at": e.created_at,
                        "expires_at": None if not np.isfinite(e.expires_at) else e.expires_at,
                        "access_count": int(e.access_count),
                    }
                )
        os.makedirs(self.path, exist_ok=True)
        vecs = (
            np.stack(rows) if rows else np.zeros((0, store.dim), np.float32)
        )
        np.savez(self._vec_path(), vecs=vecs)
        manifest = {"dim": store.dim, "metric": store.metric, "entries": items}
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, self._manifest_path())  # atomic commit
        return len(items)

    def count(self) -> int:
        if not os.path.exists(self._manifest_path()):
            return 0
        with open(self._manifest_path()) as f:
            return len(json.load(f)["entries"])

    def import_into(self, store) -> int:
        """Warm-start ``store`` from the snapshot. Returns entries imported
        (expired rows in the snapshot are dropped on the way in)."""
        with open(self._manifest_path()) as f:
            m = json.load(f)
        assert m["dim"] == store.dim, "snapshot dim mismatch"
        vecs = np.load(self._vec_path())["vecs"]
        now = time.time()
        live = []
        for i, it in enumerate(m["entries"]):
            expires = float("inf") if it["expires_at"] is None else it["expires_at"]
            if expires <= now:
                continue
            live.append((it["created_at"], i, it, expires))
        # oldest first: the newest entries land last and therefore survive
        # in tier 0 when the snapshot overflows the device capacity
        live.sort(key=lambda t: (t[0], t[1]))
        if not live:
            return 0
        entries = []
        for created, i, it, expires in live:
            key = store._next_key
            store._next_key += 1  # re-key into the target's key space
            entries.append(
                TierEntry(
                    key=key,
                    query=it["query"],
                    response=it["response"],
                    meta=dict(it.get("meta") or {}),
                    created_at=created,
                    expires_at=expires,
                    access_count=int(it.get("access_count", 0)),
                )
            )
        rows = vecs[[i for _, i, _, _ in live]]
        store._restore_batch(rows, entries)
        return len(entries)

    def clear(self) -> int:
        dropped = self.count()
        for p in (self._vec_path(), self._manifest_path()):
            if os.path.exists(p):
                os.remove(p)
        return dropped
