"""Response synthesis for generative caching (§3).

The paper offers two options for a generative hit: "provide a combination of
all answers obtained from the cache or perform a summarization of the answers".
``combine`` implements both — template combination (deterministic, no model)
and summarization via a pluggable summarizer callable (one of the zoo models
behind the serving engine, or any callable str -> str).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from repro.core.vector_store import Entry


def combine(
    query: str,
    sources: List[Tuple[float, Entry]],
    mode: str = "template",
    summarizer: Optional[Callable[[str], str]] = None,
) -> str:
    ordered = sorted(sources, key=lambda se: -se[0])
    if mode == "concat":
        return "\n\n".join(e.response for _, e in ordered)
    if mode == "template":
        parts = [f"[combined from {len(ordered)} cached answers]"]
        for s, e in ordered:
            parts.append(f"- (sim={s:.3f}) Re: {e.query}\n{e.response}")
        return "\n".join(parts)
    if mode == "summarize":
        if summarizer is None:
            raise ValueError("summarize mode requires a summarizer callable")
        joined = "\n\n".join(e.response for _, e in ordered)
        return summarizer(
            f"Summarize the following cached answers into one response to: {query}\n\n{joined}"
        )
    raise ValueError(f"unknown synthesis mode {mode!r}")
