"""Generative caching (§3) — the paper's headline contribution.

Algorithm (verbatim from the paper, with t_single < t_s < t_combined):

    X <- {cached queries x_i : S(x_i, Q5) > t_single}
    if sum_{x_i in X} S(x_i, Q5) > t_combined:  cache hit (synthesize from X)
    else:                                        cache miss

Invocation modes:
  * primary   — generative matching IS the default lookup algorithm
  * secondary — generative matching only runs after a regular semantic miss

A single-entry exact-style hit (best similarity > t_s) is still served
directly (it trivially satisfies the generative rule and needs no synthesis).
Synthesized answers are inserted back into the cache so future queries
semantically similar to Q5 hit directly.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import numpy as np

from repro.core import synthesis
from repro.core.semantic_cache import CacheResult, SemanticCache


class GenerativeCache(SemanticCache):
    def __init__(
        self,
        embedder,
        threshold: float = 0.8,
        t_single: float = 0.6,
        t_combined: float = 1.4,
        mode: str = "secondary",  # "primary" | "secondary"
        max_sources: int = 4,
        synthesis_mode: str = "template",
        summarizer: Optional[Callable[[str], str]] = None,
        cache_synthesized: bool = True,
        **kwargs,
    ):
        super().__init__(embedder, threshold, **kwargs)
        assert mode in ("primary", "secondary")
        self.t_single = t_single
        self.t_combined = t_combined
        self.mode = mode
        self.max_sources = max_sources
        self.synthesis_mode = synthesis_mode
        self.summarizer = summarizer
        self.cache_synthesized = cache_synthesized

    # -- generative matching -----------------------------------------------------

    def _generative_lookup(
        self, query: str, vec: np.ndarray, t_s: float, t_start: float
    ) -> CacheResult:
        t0 = time.perf_counter()
        matches = self.store.search(vec, k=self.max_sources)
        self.stats.search_time_s += time.perf_counter() - t0
        X = [(s, e) for s, e in matches if s > self.t_single]
        combined = float(sum(s for s, _ in X))
        best = matches[0][0] if matches else -1.0

        if X and combined > self.t_combined:
            # single overwhelming match -> direct hit, no synthesis needed
            if X[0][0] > t_s:
                s, e = X[0]
                self.stats.hits += 1
                return CacheResult(True, e.response, s, combined, False, X[:1], t_s,
                                   time.perf_counter() - t_start, "semantic")
            response = synthesis.combine(query, X, self.synthesis_mode, self.summarizer)
            self.stats.hits += 1
            self.stats.generative_hits += 1
            if self.cache_synthesized:
                self.insert(query, response, {"generative": True}, vec=vec)
            return CacheResult(True, response, best, combined, True, X, t_s,
                               time.perf_counter() - t_start, "generative")
        promoted = self.consult_tier1([query], np.asarray(vec)[None], [t_s], [0])
        if 0 in promoted:
            r = promoted[0]
            r.latency_s = time.perf_counter() - t_start
            return r
        return CacheResult(False, None, best, combined, False, X, t_s,
                           time.perf_counter() - t_start)

    # -- lookup ---------------------------------------------------------------

    def lookup(self, query: str, context: Optional[dict] = None, vec: Optional[np.ndarray] = None) -> CacheResult:
        t_start = time.perf_counter()
        self.stats.lookups += 1
        t_s = self.effective_threshold(query, context)
        if vec is None:
            vec = self.embed(query)

        if self.mode == "primary":
            return self._generative_lookup(query, vec, t_s, t_start)

        # secondary: regular semantic lookup first
        t0 = time.perf_counter()
        matches = self.store.search(vec, k=1)
        self.stats.search_time_s += time.perf_counter() - t0
        if matches and matches[0][0] > t_s:
            s, e = matches[0]
            self.stats.hits += 1
            return CacheResult(True, e.response, s, s, False, [(s, e)], t_s,
                               time.perf_counter() - t_start, "semantic")
        return self._generative_lookup(query, vec, t_s, t_start)

    def _solo_k(self) -> int:
        """A batched generative lookup searches top-max_sources once; the
        top-1 of that shared candidate set equals the sequential secondary
        probe, so decisions match B sequential ``lookup`` calls. (The base
        class ``lookup_batch`` drives both the fused device-decide program
        and the host fallback through this k; synthesized answers are
        inserted after all decisions, so in-batch queries never hit each
        other's synthesized entries.)"""
        return max(self.max_sources, 1)

    def _decide_batch(self, queries, thresholds, matches, lazy_synth=False):
        """Generative-rule decisions over pre-searched candidates (§3).

        ``matches`` rows may hold more than ``max_sources`` candidates (the
        hierarchy searches each level once with a shared k); the rule only
        ever sees the top ``max_sources``, like the sequential path. Deferred
        synthesized inserts come back as ``(query_index, response)`` so the
        caller controls when (and whether) they land. With ``lazy_synth``,
        generative hits carry ``response=None`` and no deferred inserts — the
        hierarchy synthesizes only for levels that actually win a query (the
        summarizer may be an LLM call; losers must not pay for it)."""
        results: List[CacheResult] = []
        to_insert: List[tuple] = []  # synthesized answers, applied post-batch
        for i, m in enumerate(matches):
            t_s = float(thresholds[i])
            best = m[0][0] if m else -1.0
            if self.mode == "secondary" and m and best > t_s:
                s, e = m[0]
                self.stats.hits += 1
                results.append(CacheResult(True, e.response, s, s, False, [(s, e)],
                                           t_s, 0.0, "semantic"))
                continue
            X = [(s, e) for s, e in m[: self.max_sources] if s > self.t_single]
            combined = float(sum(s for s, _ in X))
            if X and combined > self.t_combined:
                if X[0][0] > t_s:
                    s, e = X[0]
                    self.stats.hits += 1
                    results.append(CacheResult(True, e.response, s, combined, False,
                                               X[:1], t_s, 0.0, "semantic"))
                    continue
                if lazy_synth:
                    response = None
                else:
                    response = synthesis.combine(queries[i], X, self.synthesis_mode, self.summarizer)
                    if self.cache_synthesized:
                        to_insert.append((i, response))
                self.stats.hits += 1
                self.stats.generative_hits += 1
                results.append(CacheResult(True, response, best, combined, True, X,
                                           t_s, 0.0, "generative"))
            else:
                results.append(CacheResult(False, None, best, combined, False, X,
                                           t_s, 0.0))
        return results, to_insert

    def _materialize_one(self, query, t_s, m, hit, gen, lazy_synth=False):
        """Host half of the generative ``_decide_batch`` for the fused read
        path: the hit/generative classification arrives as device-computed
        masks; this rebuilds the X set, scores, and (unless ``lazy_synth``)
        the synthesized response for exactly the rows that need them. The
        sub-classification of a non-generative hit (direct secondary match
        vs the rule's single-overwhelming-match branch) re-runs the same
        float comparisons on the same device scores, so it cannot disagree
        with the masks."""
        best = m[0][0] if m else -1.0
        X = [(s, e) for s, e in m[: self.max_sources] if s > self.t_single]
        combined = float(sum(s for s, _ in X))
        if hit and not gen:
            if self.mode == "secondary" and m and best > t_s:
                s, e = m[0]
                self.stats.hits += 1
                return (
                    CacheResult(True, e.response, s, s, False, [(s, e)], t_s,
                                0.0, "semantic"),
                    None,
                )
            s, e = X[0]  # gen_ok hit with best > t_s: X[0] == m[0]
            self.stats.hits += 1
            return (
                CacheResult(True, e.response, s, combined, False, X[:1], t_s,
                            0.0, "semantic"),
                None,
            )
        if hit:
            self.stats.hits += 1
            self.stats.generative_hits += 1
            if lazy_synth:
                response, ins = None, None
            else:
                response = synthesis.combine(query, X, self.synthesis_mode, self.summarizer)
                ins = response if self.cache_synthesized else None
            return (
                CacheResult(True, response, best, combined, True, X, t_s, 0.0,
                            "generative"),
                ins,
            )
        return CacheResult(False, None, best, combined, False, X, t_s, 0.0), None
