"""Semantic cache (and the GPTCache-like baseline for the §6.1 comparison).

A lookup embeds the query, searches the vector store, and declares a hit when
the best similarity exceeds the *effective* threshold t_s — which is not a
constant: it is computed per query by the ThresholdPolicy (content type,
model cost/latency, connectivity, user preference; §2) and servoed over time
by the feedback controllers (§3.1).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.embeddings import EmbeddingModel
from repro.core.vector_store import Entry, InMemoryVectorStore


@dataclass
class CacheResult:
    hit: bool
    response: Optional[str] = None
    similarity: float = -1.0
    combined_similarity: float = 0.0
    generative: bool = False
    sources: List[Tuple[float, Entry]] = field(default_factory=list)
    threshold_used: float = 0.0
    latency_s: float = 0.0
    level: str = "miss"


@dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0
    generative_hits: int = 0
    tier1_hits: int = 0  # tier-0 misses served from the host-RAM tier
    stale_hits: int = 0  # expired entries served stale-if-error (backends down)
    adds: int = 0
    embed_time_s: float = 0.0
    search_time_s: float = 0.0
    add_time_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class SemanticCache:
    def __init__(
        self,
        embedder: EmbeddingModel,
        threshold: float = 0.8,
        capacity: int = 4096,
        metric: str = "cosine",
        eviction: str = "lru",
        policy=None,  # ThresholdPolicy (repro.core.adaptive)
        store: Optional[InMemoryVectorStore] = None,
        use_pallas: bool = False,
    ):
        self.embedder = embedder
        self.threshold = threshold
        self.policy = policy
        # note: `store or ...` would discard an *empty* store (len == 0 is falsy)
        self.store = (
            store
            if store is not None
            else InMemoryVectorStore(embedder.dim, capacity, metric, eviction, use_pallas=use_pallas)
        )
        self.stats = CacheStats()

    # -- thresholds -----------------------------------------------------------

    def effective_threshold(self, query: str, context: Optional[dict] = None) -> float:
        if self.policy is not None:
            return self.policy.compute(query, context or {})
        return self.threshold

    # -- embedding ------------------------------------------------------------

    def embed(self, query: str) -> np.ndarray:
        t0 = time.perf_counter()
        v = self.embedder.embed_one(query)
        self.stats.embed_time_s += time.perf_counter() - t0
        return v

    def embed_batch(self, queries: List[str]) -> np.ndarray:
        """Embed a request batch in one model forward ([B, L] tokens)."""
        t0 = time.perf_counter()
        v = self.embedder.embed_batch(list(queries))
        self.stats.embed_time_s += time.perf_counter() - t0
        return v

    # -- candidate search (shared with the hierarchy) ----------------------------

    def search_candidates(
        self, vecs: np.ndarray, k: int, touch: bool = True
    ) -> List[List[Tuple[float, Entry]]]:
        """One timed store search for the whole batch. ``touch=False`` defers
        LRU/LFU bookkeeping to the caller — the hierarchy probes every level
        speculatively and bumps only levels a sequential walk would reach."""
        t0 = time.perf_counter()
        try:
            matches = self.store.search_batch(np.asarray(vecs), k=k, touch=touch)
        except TypeError:  # store without deferred-bookkeeping support
            matches = self.store.search_batch(np.asarray(vecs), k=k)
        self.stats.search_time_s += time.perf_counter() - t0
        return matches

    def touch(self, keys) -> None:
        """Apply deferred recency/frequency bookkeeping (no-op for stores
        without eviction counters, e.g. the sharded store)."""
        touch_keys = getattr(self.store, "touch_keys", None)
        if touch_keys is not None and keys:
            touch_keys(keys)

    # -- tier-1 consult (tier-0 miss only; host-side, off the fused path) -------

    def consult_tier1(
        self, queries: List[str], vecs: np.ndarray, thresholds, rows: List[int]
    ) -> Dict[int, CacheResult]:
        """Consult the store's host-RAM demotion tier for the listed miss
        rows. Hits promote back into the device lane via the same batched
        row scatter inserts ride (one scatter for all winners), then resolve
        as hits at level "tier1". Runs only after a tier-0 miss, so the
        fused read program stays one dispatch / zero host hops."""
        tier = getattr(self.store, "tier1", None)
        if tier is None or len(tier) == 0 or not rows:
            return {}
        vecs = np.asarray(vecs, np.float32)
        sc, slots = tier.search(vecs[rows], k=1)
        winners = []  # (batch row, effective score, tier slot)
        for j, i in enumerate(rows):
            s = float(sc[j, 0])
            if np.isfinite(s) and s > float(thresholds[i]):
                winners.append((i, s, int(slots[j, 0])))
        if not winners:
            return {}
        popped: Dict[int, tuple] = {}  # slot -> (TierEntry, vec); pop once
        for _, _, slot in winners:
            if slot not in popped:
                popped[slot] = tier.pop(slot)
        self.store._restore_batch(
            np.stack([v for _, v in popped.values()]),
            [e for e, _ in popped.values()],
        )
        out: Dict[int, CacheResult] = {}
        # the sharded store keeps (query, response) payloads, not Entry rows —
        # reconstruct from the TierEntry there
        entry_table = getattr(self.store, "_entries", None)
        for i, s, slot in winners:
            te = popped[slot][0]
            idx = self.store._key_to_slot.get(te.key)
            entry = (
                entry_table[idx]
                if idx is not None and entry_table is not None
                else Entry(te.key, te.query, te.response, dict(te.meta),
                           te.created_at, te.expires_at)
            )
            self.stats.hits += 1
            self.stats.tier1_hits += 1
            out[i] = CacheResult(
                True, entry.response, s, s, False, [(s, entry)],
                float(thresholds[i]), 0.0, "tier1",
            )
        return out

    # -- stale-if-error lookup (degraded path; resilience subsystem) ------------

    def lookup_stale(
        self,
        queries: List[str],
        vecs: np.ndarray,
        thresholds,
        now: Optional[float] = None,
        max_stale_s=None,
    ) -> Dict[int, CacheResult]:
        """Serve EXPIRED entries when every backend is down (stale-if-error).

        Host-side scan over tier 0's entry table plus the tier-1 ring —
        deliberately off the fused path: this runs only after the failover
        walk exhausted every backend, where a host matmul is noise next to
        the outage. An entry qualifies when it expires (or expired) after
        ``now - max_stale_s`` (``max_stale_s=None`` accepts any age; live
        entries qualify trivially). The winner must still clear the row's
        threshold. Nothing is promoted and no recency/frequency counters
        move — a dead backend must not reshape the eviction order.
        ``max_stale_s`` may be a scalar or a per-row sequence; returns
        row -> CacheResult at level ``stale:tier0`` / ``stale:tier1``.
        """
        from repro.core.tiers import _host_scores, _normalize

        q = np.atleast_2d(np.asarray(vecs, np.float32))
        nq = q.shape[0]
        now = time.time() if now is None else now
        if max_stale_s is None or np.isscalar(max_stale_s):
            stales = [max_stale_s] * nq
        else:
            stales = list(max_stale_s)
        floors = np.array(
            [-np.inf if s is None else now - float(s) for s in stales], np.float64
        )

        def _best(db, expires):  # [N, D] rows + [N] expiry stamps -> per-row best
            if db.shape[0] == 0:
                return np.full(nq, -np.inf, np.float32), np.full(nq, -1, np.int64)
            rows = _normalize(db) if self.store.metric == "cosine" else db
            s = _host_scores(rows, q, self.store.metric).astype(np.float32)
            ok = expires[None, :] > floors[:, None]
            s = np.where(ok, s, -np.inf)
            j = np.argmax(s, axis=-1)
            return s[np.arange(nq), j], j

        out: Dict[int, CacheResult] = {}
        # tier 0: the entry table keeps expired rows until eviction reclaims
        # them — exactly the stale inventory this path serves
        entries = getattr(self.store, "_entries", None)
        if entries is not None:
            t0_idx = [i for i, e in enumerate(entries) if e is not None]
            if t0_idx:
                host = self.store._host_rows
                allrows = (
                    host if host is not None else np.asarray(self.store._buf, np.float32)
                )
                db = np.asarray(allrows, np.float32)[t0_idx]
                exp = np.array([entries[i].expires_at for i in t0_idx], np.float64)
                best, j = _best(db, exp)
                for r in range(nq):
                    if np.isfinite(best[r]) and best[r] > float(thresholds[r]):
                        e = entries[t0_idx[int(j[r])]]
                        out[r] = CacheResult(
                            True, e.response, float(best[r]), float(best[r]), False,
                            [(float(best[r]), e)], float(thresholds[r]), 0.0,
                            "stale:tier0",
                        )
        tier = getattr(self.store, "tier1", None)
        if tier is not None and len(tier) > 0:
            t1_idx = [i for i, e in enumerate(tier._entries) if e is not None]
            if t1_idx:
                db = np.asarray(tier._vecs, np.float32)[t1_idx]
                exp = np.array([tier._entries[i].expires_at for i in t1_idx], np.float64)
                best, j = _best(db, exp)
                for r in range(nq):
                    if r in out:
                        continue  # tier 0 already answered this row
                    if np.isfinite(best[r]) and best[r] > float(thresholds[r]):
                        te = tier._entries[t1_idx[int(j[r])]]
                        from repro.core.vector_store import Entry as _Entry

                        e = _Entry(te.key, te.query, te.response, dict(te.meta),
                                   te.created_at, te.expires_at)
                        out[r] = CacheResult(
                            True, e.response, float(best[r]), float(best[r]), False,
                            [(float(best[r]), e)], float(thresholds[r]), 0.0,
                            "stale:tier1",
                        )
        if out:
            self.stats.stale_hits += len(out)
        return out

    # -- lookup / insert --------------------------------------------------------

    def lookup(
        self, query: str, context: Optional[dict] = None, vec: Optional[np.ndarray] = None
    ) -> CacheResult:
        t_start = time.perf_counter()
        self.stats.lookups += 1
        t_s = self.effective_threshold(query, context)
        if vec is None:
            vec = self.embed(query)
        t0 = time.perf_counter()
        matches = self.store.search(vec, k=1)
        self.stats.search_time_s += time.perf_counter() - t0
        if matches and matches[0][0] > t_s:
            score, entry = matches[0]
            self.stats.hits += 1
            return CacheResult(
                True, entry.response, score, score, False, [(score, entry)], t_s,
                time.perf_counter() - t_start, "semantic",
            )
        promoted = self.consult_tier1([query], np.asarray(vec)[None], [t_s], [0])
        if 0 in promoted:
            r = promoted[0]
            r.latency_s = time.perf_counter() - t_start
            return r
        best = matches[0][0] if matches else -1.0
        return CacheResult(
            False, None, best, best, False, matches[:1], t_s, time.perf_counter() - t_start
        )

    def _solo_k(self) -> int:
        """Candidates a standalone batched lookup searches (and touches)."""
        return 1

    def _fused_read_decision(self, queries, thresholds, vecs):
        """Try the zero-host-hop read program for a standalone lookup: one
        device dispatch covering embed -> search -> decide -> touch. Returns
        (ReadDecision, k) or (None, 0) when ineligible — customized decide
        logic, a non-bankable store, a store adopted into a multi-lane bank
        (a solo search must stay lane-scoped), or an empty store."""
        from repro.core import read_path

        store = self.store
        if (
            not read_path.store_bankable(store)
            or store._bank.L != 1
            or len(store) == 0
        ):
            return None, 0
        k = min(max(self._solo_k(), 1), store.capacity)
        spec = read_path.level_spec(self, k)
        if spec is None:
            return None, 0
        t0 = time.perf_counter()
        dec = read_path.fused_read(
            store._bank, self.embedder, queries,
            np.asarray(thresholds, np.float32).reshape(-1, 1), (spec,), vecs=vecs,
        )
        self.stats.search_time_s += time.perf_counter() - t0
        return dec, k

    def lookup_batch(
        self,
        queries: List[str],
        contexts: Optional[List[Optional[dict]]] = None,
        vecs: Optional[np.ndarray] = None,
        return_vecs: bool = False,
    ):
        """Batched lookup: one fused device program (embed + search + decide
        masks + counter touches — see repro.core.read_path) for B queries,
        or one embed forward + one store search when the store/decide logic
        is customized. ``return_vecs=True`` additionally returns the [B, D]
        embeddings (the serving path reuses them for dedup/backfill).

        Decision-identical to B sequential ``lookup`` calls against the same
        store snapshot (per-query effective thresholds applied vectorized);
        store contents are not mutated by the decisions themselves, so
        results do not depend on the order of queries within the batch.
        """
        t_start = time.perf_counter()
        n = len(queries)
        if n == 0:
            empty = np.zeros((0, self.embedder.dim), np.float32)
            return ([], empty) if return_vecs else []
        contexts = list(contexts) if contexts is not None else [None] * n
        self.stats.lookups += n
        thresholds = np.asarray(
            [self.effective_threshold(q, c) for q, c in zip(queries, contexts)]
        )
        dec, k = self._fused_read_decision(queries, thresholds, vecs)
        if dec is not None:
            matches = [
                m[:k]
                for m in self.store.join_candidates(
                    dec.scores[:, 0], dec.idx[:, 0], touch=False
                )
            ]
            results, to_insert = self._materialize_batch(
                queries, thresholds, matches, dec.hit[:, 0], dec.generative[:, 0]
            )
            vecs = dec.vecs
        else:
            if vecs is None:
                vecs = self.embed_batch(list(queries))
            t0 = time.perf_counter()
            matches = self.store.search_batch(np.asarray(vecs), k=self._solo_k())
            self.stats.search_time_s += time.perf_counter() - t0
            results, to_insert = self._decide_batch(queries, thresholds, matches)
        misses = [i for i, r in enumerate(results) if not r.hit]
        if misses:
            promoted = self.consult_tier1(queries, vecs, thresholds, misses)
            for i, r in promoted.items():
                results[i] = r
        per_query_s = (time.perf_counter() - t_start) / n
        for r in results:
            r.latency_s = per_query_s
        if to_insert:
            # whole synthesized set lands in one add_batch scatter
            self.insert_batch(
                [queries[i] for i, _ in to_insert],
                [r for _, r in to_insert],
                metas=[{"generative": True}] * len(to_insert),
                vecs=np.stack([np.asarray(vecs[i]) for i, _ in to_insert]),
            )
        return (results, np.asarray(vecs)) if return_vecs else results

    def _decide_batch(
        self,
        queries: List[str],
        thresholds: np.ndarray,
        matches: List[List[Tuple[float, Entry]]],
        lazy_synth: bool = False,
    ) -> Tuple[List[CacheResult], List[tuple]]:
        """Per-query hit decisions over pre-searched candidates.

        Shared by ``lookup_batch`` and ``HierarchicalCache.lookup_batch`` (the
        hierarchy runs one search per level and feeds each level's candidates
        through that level's own decision rule). Returns the results (latency
        left at 0 for the caller to fill) plus deferred ``(query_index,
        response)`` inserts — empty here, used by the generative subclass.
        """
        results: List[CacheResult] = []
        for i, m in enumerate(matches):
            t_s = float(thresholds[i])
            best = m[0][0] if m else -1.0
            if m and best > t_s:
                score, entry = m[0]
                self.stats.hits += 1
                results.append(
                    CacheResult(True, entry.response, score, score, False,
                                [(score, entry)], t_s, 0.0, "semantic")
                )
            else:
                results.append(
                    CacheResult(False, None, best, best, False, m[:1], t_s, 0.0)
                )
        return results, []

    # -- host materialization for the fused (device-decide) read path -----------

    def _materialize_one(
        self,
        query: str,
        t_s: float,
        m: List[Tuple[float, Entry]],
        hit: bool,
        gen: bool,
        lazy_synth: bool = False,
    ) -> Tuple[CacheResult, Optional[str]]:
        """Build one CacheResult from the device decide masks plus the joined
        candidates — the host half of ``_decide_batch`` after the comparisons
        moved in-program. Returns (result, deferred synthesized response or
        None). The generative subclass overrides this; here a hit is always
        a plain semantic hit."""
        if hit:
            score, entry = m[0]
            self.stats.hits += 1
            return (
                CacheResult(True, entry.response, score, score, False,
                            [(score, entry)], t_s, 0.0, "semantic"),
                None,
            )
        best = m[0][0] if m else -1.0
        return CacheResult(False, None, best, best, False, m[:1], t_s, 0.0), None

    def _materialize_batch(
        self,
        queries: List[str],
        thresholds: np.ndarray,
        matches: List[List[Tuple[float, Entry]]],
        hit: np.ndarray,
        gen: np.ndarray,
        lazy_synth: bool = False,
    ) -> Tuple[List[CacheResult], List[tuple]]:
        """Vector form of ``_materialize_one`` (same (results, deferred
        inserts) contract as ``_decide_batch``)."""
        results: List[CacheResult] = []
        to_insert: List[tuple] = []
        for i, m in enumerate(matches):
            r, ins = self._materialize_one(
                queries[i], float(thresholds[i]), m, bool(hit[i]), bool(gen[i]),
                lazy_synth,
            )
            results.append(r)
            if ins is not None:
                to_insert.append((i, ins))
        return results, to_insert

    def insert(
        self,
        query: str,
        response: str,
        meta: Optional[Dict[str, Any]] = None,
        vec: Optional[np.ndarray] = None,
        ttl_s: Optional[float] = None,
    ) -> int:
        if vec is None:
            vec = self.embed(query)
        t0 = time.perf_counter()
        if ttl_s is not None:
            key = self.store.add(vec, query, response, meta, ttl_s=ttl_s)
        else:  # stores without TTL support keep working unchanged
            key = self.store.add(vec, query, response, meta)
        self.stats.add_time_s += time.perf_counter() - t0
        self.stats.adds += 1
        return key

    def insert_batch(
        self,
        queries: List[str],
        responses: List[str],
        metas: Optional[List[Optional[Dict[str, Any]]]] = None,
        vecs: Optional[np.ndarray] = None,
        ttls: Optional[List[Optional[float]]] = None,
    ) -> List[int]:
        """Insert N pairs with one embed forward + one ``add_batch`` scatter."""
        n = len(queries)
        if n == 0:
            return []
        if vecs is None:
            vecs = self.embed_batch(list(queries))
        t0 = time.perf_counter()
        if ttls is not None and any(t is not None for t in ttls):
            keys = self.store.add_batch(
                np.asarray(vecs), list(queries), list(responses), metas, ttls=ttls
            )
        else:
            keys = self.store.add_batch(np.asarray(vecs), list(queries), list(responses), metas)
        self.stats.add_time_s += time.perf_counter() - t0
        self.stats.adds += n
        return keys

    def clear(self, older_than: Optional[float] = None) -> int:
        """Prune: everything, or entries older than ``older_than`` seconds
        (expired entries always qualify). Cascades through the store into
        any attached tier-1 ring."""
        clear = getattr(self.store, "clear", None)
        return int(clear(older_than=older_than)) if clear is not None else 0

    def warm_start(self, pairs: List[Tuple[str, str]]) -> None:
        """Load query-answer pairs from past sessions (paper §4)."""
        if not pairs:
            return
        vecs = self.embedder.embed([q for q, _ in pairs])
        self.insert_batch([q for q, _ in pairs], [a for _, a in pairs], vecs=vecs)

    # -- persistence ------------------------------------------------------------

    def save(self, path: str) -> None:
        self.store.save(path)

    def load_store(self, path: str) -> None:
        # reload through the live store's class with its flags, so a
        # use_pallas store (or a custom subclass) survives the round-trip
        self.store = type(self.store).load(path, use_pallas=self.store.use_pallas)


class GPTCacheLike:
    """Architecture-shaped GPTCache baseline: per-entry python-loop scalar
    similarity over a row store (the SQLite-backed eval path the paper
    criticizes in §6.1). Same embedder as SemanticCache so the comparison
    isolates the cache data path."""

    def __init__(self, embedder: EmbeddingModel, threshold: float = 0.8):
        self.embedder = embedder
        self.threshold = threshold
        self.rows: List[Tuple[np.ndarray, Entry]] = []
        self._key = 0
        self.stats = CacheStats()

    def insert(self, query: str, response: str, vec: Optional[np.ndarray] = None) -> int:
        if vec is None:
            vec = self.embedder.embed_one(query)
        t0 = time.perf_counter()
        # row-store semantics: append a row, rebuild the "index" lazily
        self.rows.append((np.asarray(vec, np.float64), Entry(self._key, query, response)))
        self.stats.add_time_s += time.perf_counter() - t0
        self.stats.adds += 1
        self._key += 1
        return self._key - 1

    def lookup(self, query: str, vec: Optional[np.ndarray] = None) -> CacheResult:
        t_start = time.perf_counter()
        self.stats.lookups += 1
        if vec is None:
            vec = self.embedder.embed_one(query)
        v = np.asarray(vec, np.float64)
        t0 = time.perf_counter()
        best_s, best_e = -1.0, None
        for row_vec, entry in self.rows:  # per-row scalar evaluation
            num = 0.0
            na = 0.0
            nb = 0.0
            for a, b in zip(v, row_vec):
                num += a * b
                na += a * a
                nb += b * b
            s = num / max(np.sqrt(na) * np.sqrt(nb), 1e-9)
            if s > best_s:
                best_s, best_e = s, entry
        self.stats.search_time_s += time.perf_counter() - t0
        if best_e is not None and best_s > self.threshold:
            self.stats.hits += 1
            return CacheResult(True, best_e.response, best_s, best_s, False,
                               [(best_s, best_e)], self.threshold,
                               time.perf_counter() - t_start, "semantic")
        return CacheResult(False, None, best_s, best_s, False, [], self.threshold,
                           time.perf_counter() - t_start)
