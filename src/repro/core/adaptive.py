"""Adaptive similarity-threshold machinery (§2, §3.1).

The paper's position: t_s should vary with (a) content type — code demands
higher thresholds than prose; (b) the monetary cost and expected latency of
the request's target model — expensive/slow => lower t_s to favor hits;
(c) connectivity — poor connectivity => serve more from cache; (d) explicit
user preference; and it should be *servoed* by feedback:

  * QualityRateController — users mark cache hits high/low quality; drive
    quality_rate toward target t4 by raising t_s when quality is low and
    lowering it when quality is above target (the paper's §3.1 pseudo-code;
    note its published listing says "increase" in both branches — an obvious
    typo; we implement the stated intent of the surrounding text).
  * CostController — drive the hit rate toward (c2 - c1) / c2 where c1 is
    the user's preferred average cost/request and c2 the observed cost of
    actual LLM calls.
"""
from __future__ import annotations

import re
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class ModelCostInfo:
    """Per-model pricing/latency, used to scale t_s (§2).

    Defaults table mirrors the paper's May-13-2024 OpenAI numbers.
    """

    usd_per_mtok_in: float = 0.5
    usd_per_mtok_out: float = 1.5
    expected_latency_s: float = 3.0


# The paper's reference price points (§2): gpt-4-32k output is 80x
# gpt-3.5-turbo-0125 output; input 120x; gpt-4 latencies are higher.
DEFAULT_PRICE_TABLE: Dict[str, ModelCostInfo] = {
    "gpt-3.5-turbo-0125": ModelCostInfo(0.5, 1.5, 3.0),
    "gpt-4-32k": ModelCostInfo(60.0, 120.0, 20.0),
    "gpt-4": ModelCostInfo(30.0, 60.0, 12.0),
    "free-local": ModelCostInfo(0.0, 0.0, 1.0),
}


_CODE_RE = re.compile(
    r"```|\bdef \w+\(|\bclass \w+|\breturn\b|#include|;\s*$|"
    r"\b(write|generate|implement|fix|debug|refactor)\b.{0,40}\b(code|function|script|program|class|method|sql|regex)\b",
    re.IGNORECASE | re.MULTILINE,
)


def classify_content(query: str) -> str:
    """'code' queries need near-exact matches; 'text' tolerates lower t_s."""
    return "code" if _CODE_RE.search(query) else "text"


@dataclass
class ThresholdPolicy:
    """Computes the effective t_s per query from base + runtime terms."""

    base: float = 0.8
    t_min: float = 0.5
    t_max: float = 0.98
    content_offsets: Dict[str, float] = field(
        default_factory=lambda: {"text": 0.0, "code": 0.12}
    )
    # scaling for cost/latency: a model at `cost_ref` USD/mtok-out or
    # `latency_ref` seconds pulls t_s down by up to `cost_pull`/`latency_pull`.
    cost_ref: float = 120.0
    cost_pull: float = 0.10
    latency_ref: float = 30.0
    latency_pull: float = 0.05

    def compute(self, query: str, context: Optional[dict] = None) -> float:
        ctx = context or {}
        t = self.base
        t += self.content_offsets.get(classify_content(query), 0.0)
        info: Optional[ModelCostInfo] = ctx.get("model_info")
        if info is not None:
            cost_frac = min(info.usd_per_mtok_out / self.cost_ref, 1.0)
            # expected response size scales cost: honor a max_tokens hint
            size_frac = min(ctx.get("max_tokens", 1024) / 4096.0, 1.0)
            t -= self.cost_pull * cost_frac * (0.5 + 0.5 * size_frac)
            t -= self.latency_pull * min(info.expected_latency_s / self.latency_ref, 1.0)
        connectivity = ctx.get("connectivity", 1.0)  # 0 = offline, 1 = healthy
        t -= 0.15 * (1.0 - connectivity)
        t += ctx.get("user_threshold_offset", 0.0)
        return float(min(max(t, self.t_min), self.t_max))


class QualityRateController:
    """§3.1 feedback servo on the base threshold."""

    def __init__(
        self,
        policy: ThresholdPolicy,
        target: float = 0.8,
        band: float = 0.05,
        step: float = 0.02,
        window: int = 50,
        min_samples: int = 5,
    ):
        self.policy = policy
        self.target = target
        self.band = band
        self.step = step
        self.min_samples = min_samples
        self._feedback = deque(maxlen=window)

    @property
    def quality_rate(self) -> float:
        if not self._feedback:
            return 1.0
        return sum(self._feedback) / len(self._feedback)

    def record(self, high_quality: bool) -> None:
        self._feedback.append(1.0 if high_quality else 0.0)
        self.maybe_adjust()

    def maybe_adjust(self) -> float:
        if len(self._feedback) >= self.min_samples:
            qr = self.quality_rate
            if qr < self.target - self.band:
                self.policy.base = min(self.policy.base + self.step, self.policy.t_max)
            elif qr > self.target + self.band:
                self.policy.base = max(self.policy.base - self.step, self.policy.t_min)
        return self.policy.base


class CostController:
    """§3.1 cost servo: steer hit rate toward (c2 - c1) / c2."""

    def __init__(
        self,
        policy: ThresholdPolicy,
        target_cost_per_request: float,
        step: float = 0.02,
        window: int = 100,
        min_samples: int = 5,
    ):
        self.policy = policy
        self.c1 = target_cost_per_request
        self.step = step
        self.min_samples = min_samples
        self._requests = deque(maxlen=window)  # (cost_usd, was_hit)

    def record(self, cost_usd: float, was_hit: bool) -> None:
        self._requests.append((cost_usd, was_hit))
        self.maybe_adjust()

    @property
    def measured_hit_rate(self) -> float:
        if not self._requests:
            return 0.0
        return sum(1 for _, h in self._requests if h) / len(self._requests)

    @property
    def llm_cost_per_call(self) -> float:
        costs = [c for c, h in self._requests if not h]
        return sum(costs) / len(costs) if costs else 0.0

    @property
    def target_hit_rate(self) -> float:
        c2 = self.llm_cost_per_call
        if c2 <= self.c1 or c2 == 0.0:
            return 0.0
        return (c2 - self.c1) / c2

    def maybe_adjust(self) -> float:
        if len(self._requests) >= self.min_samples:
            if self.measured_hit_rate < self.target_hit_rate:
                self.policy.base = max(self.policy.base - self.step, self.policy.t_min)
            elif self.measured_hit_rate > self.target_hit_rate + 0.05:
                self.policy.base = min(self.policy.base + self.step, self.policy.t_max)
        return self.policy.base
