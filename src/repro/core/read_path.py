"""Zero-host-hop read path: ONE device program for embed -> search -> decide
-> touch.

Before this module, a batched cache lookup made three host<->device round
trips: the embedding forward materialized [B, D] on host, ``search_lanes``
re-uploaded it and pulled [B, L, k] scores back, and the per-level
threshold/winner walk plus every LRU/LFU bump ran in host Python. The fused
read program moves the whole hot path into a single jitted dispatch
(bucketed per batch size):

    token ids / raw vectors
        -> embedding forward                      (in-program)
        -> banked [L, cap, D] lane top-k          (jnp einsum or Pallas kernel)
        -> per-query/per-level threshold + generative-rule decide masks
        -> L1 > L2 > peers winner walk            (masked argmax over [B, L])
        -> recency/frequency scatter-add into the bank's device counters,
           gated to the levels a sequential walk would have probed
        -> compact decision tensors back to host

Only the decision tensors (winner lane, hit/generative class, top-k
scores/slots, and the embeddings for backfill) cross back to host — there
are ZERO host hops between embed and decide, and the touch updates that
used to be a host loop are a donated scatter inside the same program.

Decision semantics are those of ``SemanticCache._decide_batch`` /
``GenerativeCache._decide_batch`` (hit iff best > t_s; generative hit iff
the §3 rule fires), expressed as masks; the host *materialization* stage
(``_materialize_batch`` on the caches) turns masks + joined candidates into
``CacheResult``s for exactly the rows that need them. The only permissible
divergence from the host loop is the generative rule's combined-similarity
sum, accumulated in device float32 instead of host float64 — meaningful
only for scores within float32 epsilon of ``t_combined``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store_bank import StoreBank, fused_search_body, pad_to_bucket

_INT32_MIN = np.iinfo(np.int32).min
_NEG_FINITE = -3.0e38  # anything below is an invalid-slot sentinel (-inf / NEG)


@dataclass(frozen=True)
class LevelSpec:
    """Static per-level decision parameters baked into the read program
    (hashable: part of the program's compile-cache key)."""

    generative: bool  # GenerativeCache level (the §3 rule applies)
    secondary: bool  # direct best>t_s check first (semantic levels: always)
    t_single: float
    t_combined: float
    max_sources: int  # X-set cap for the generative rule
    k: int  # candidates searched & touched for this level


def level_spec(cache, k: int) -> Optional[LevelSpec]:
    """Build the device decide spec for one cache level, or None when the
    cache customizes ``_decide_batch`` (its semantics cannot be assumed —
    the caller must stay on the host decide path)."""
    from repro.core.generative_cache import GenerativeCache
    from repro.core.semantic_cache import SemanticCache

    cls = type(cache)
    if isinstance(cache, GenerativeCache):
        if cls._decide_batch is not GenerativeCache._decide_batch:
            return None
        return LevelSpec(
            True, cache.mode == "secondary", float(cache.t_single),
            float(cache.t_combined), int(cache.max_sources), int(k),
        )
    if isinstance(cache, SemanticCache):
        if cls._decide_batch is not SemanticCache._decide_batch:
            return None
        return LevelSpec(False, True, 0.0, float("inf"), 0, int(k))
    return None


def store_bankable(store) -> bool:
    """The store's device rows/counters live in a StoreBank lane and its
    search/join semantics are the stock ones (a subclass overriding either
    must keep running its own code)."""
    from repro.core.vector_store import InMemoryVectorStore

    return (
        isinstance(store, InMemoryVectorStore)
        and type(store).search_batch is InMemoryVectorStore.search_batch
        and type(store).join_candidates is InMemoryVectorStore.join_candidates
    )


@dataclass
class ReadDecision:
    """Host-side view of one fused read: everything the materialization
    stage needs, already sliced back to the real batch size."""

    vecs: np.ndarray  # [n, D] embeddings (reused for promotions/backfill)
    scores: np.ndarray  # [n, L, K]
    idx: np.ndarray  # [n, L, K] lane-local slots
    winner: np.ndarray  # [n] winning level index; L = miss everywhere
    hit: np.ndarray  # [n, L] per-level hit mask (semantic or generative)
    generative: np.ndarray  # [n, L] generative-hit mask (subset of hit)


def make_decide(specs: Tuple[LevelSpec, ...], K: int):
    """Shared trace of the decide stage: the ``_decide_batch`` semantics as
    [B, L] masks, the L1 > L2 > peers winner walk, and the probed-levels
    touch mask. ONE body with two callers — the single-host fused program
    below and the sharded shard_map program
    (``repro.distributed.sharded_read``) — so their decisions cannot drift.

    Returns ``decide(s, thresholds, qmask) -> (winner, hit, generative,
    tmask)`` where ``s`` is [B, L, K] score-desc candidates and ``tmask``
    is the [B, L, K] bump mask (levels a sequential walk would have probed,
    finite candidates only, capped at each level's own k)."""
    L = len(specs)
    t_single = np.asarray([s.t_single for s in specs], np.float32)
    t_comb = np.asarray(
        [s.t_combined if s.generative else np.inf for s in specs], np.float32
    )
    msl = np.asarray([min(s.max_sources, s.k) for s in specs], np.int32)
    ks = np.asarray([s.k for s in specs], np.int32)
    gen_l = np.asarray([s.generative for s in specs])
    sec_l = np.asarray([(not s.generative) or s.secondary for s in specs])

    def decide(s, thresholds, qmask):
        # -- decide: the _decide_batch semantics as [B, L] masks -------------
        colK = jnp.arange(K)
        finite = s > jnp.float32(_NEG_FINITE)
        best = s[:, :, 0]  # scores sorted desc, so [.., 0] is each lane's best
        sem_direct = jnp.asarray(sec_l)[None, :] & (best > thresholds)
        in_x = (
            finite
            & (s > jnp.asarray(t_single)[None, :, None])
            & (colK[None, None, :] < jnp.asarray(msl)[None, :, None])
            & jnp.asarray(gen_l)[None, :, None]
        )
        combined = jnp.sum(jnp.where(in_x, s, 0.0), axis=-1)
        gen_ok = in_x.any(-1) & (combined > jnp.asarray(t_comb)[None, :])
        # X[0] == best whenever X is nonempty (desc order), so the rule's
        # "single overwhelming match" branch is best > t_s under gen_ok
        semantic = sem_direct | (gen_ok & (best > thresholds))
        hit = (semantic | gen_ok) & qmask[:, None]
        generative = gen_ok & ~semantic & qmask[:, None]
        # -- winner walk: first hitting level in L1 > L2 > peers order --------
        winner = jnp.where(hit.any(1), jnp.argmax(hit, axis=1), L).astype(jnp.int32)
        # -- touch: bump exactly what the sequential walk would have probed --
        probed = (jnp.arange(L)[None, :] <= winner[:, None]) & qmask[:, None]
        tmask = (
            probed[:, :, None]
            & finite
            & (colK[None, None, :] < jnp.asarray(ks)[None, :, None])
        )
        return winner, hit, generative, tmask

    return decide


@functools.lru_cache(maxsize=64)
def _build_program(forward, specs: Tuple[LevelSpec, ...], K: int,
                   metrics: Tuple[str, ...], prenorm: Tuple[bool, ...],
                   use_pallas: bool, interpret: bool, block_n: int,
                   grid_order: str, lifecycle: bool = False):
    """Compile-cached fused read program. Keyed on the forward fn identity
    (stable per embedder instance — host embedders share one module-level
    identity forward), the level specs, and the bank layout; jax.jit adds
    the shape bucketing on top. Bounded: the key pins the forward closure
    (and through it the embedder), so an unbounded cache would leak
    programs in processes that churn through cache/embedder instances."""
    L = len(specs)
    mixed = len(set(metrics)) > 1
    decide = make_decide(specs, K)

    def search(q, buf, valid):
        if use_pallas:
            from repro.kernels.similarity_topk.ops import _similarity_topk_lanes

            return _similarity_topk_lanes(
                buf, valid, q, k=K, metric=metrics, block_n=block_n,
                interpret=interpret,
                prenormalized=True if mixed else all(prenorm),
                grid_order=grid_order,
            )
        return fused_search_body(buf, valid, q, K, metrics, prenorm)

    def decide_and_touch(s, idx, thresholds, qmask, last, cnt, tick):
        winner, hit, generative, tmask = decide(s, thresholds, qmask)
        lanes3 = jnp.broadcast_to(jnp.arange(L)[None, :, None], s.shape)
        cnt = cnt.at[lanes3, idx].add(tmask.astype(jnp.int32))
        stamp = jnp.where(tmask, tick, jnp.int32(_INT32_MIN))
        last = last.at[lanes3, idx].max(stamp)
        return s, idx, winner, hit, generative, last, cnt

    if not lifecycle:
        # TTL-free deployments compile the exact PR-5 program: same signature,
        # same donation, byte-identical trace
        def program(embed_args, thresholds, qmask, buf, valid, last, cnt, tick):
            q = forward(*embed_args)  # [B, D] — embeds never leave the device
            s, idx = search(q, buf, valid)
            s, idx, winner, hit, generative, last, cnt = decide_and_touch(
                s, idx, thresholds, qmask, last, cnt, tick
            )
            return q, s, idx, winner, hit, generative, last, cnt

        return jax.jit(program, donate_argnums=(5, 6))

    def program_lc(embed_args, thresholds, qmask, buf, valid, created,
                   expires, w, now, last, cnt, tick):
        q = forward(*embed_args)
        # expiry mask INSIDE the decide stage: a dead row is invalid for this
        # dispatch, so it can never surface as a candidate, let alone win
        s, idx = search(q, buf, valid & (expires > now))
        finite = s > jnp.float32(_NEG_FINITE)
        lanes3 = jnp.broadcast_to(jnp.arange(L)[None, :, None], s.shape)
        c = created[lanes3, idx]
        e = expires[lanes3, idx]
        # staleness-aware scoring: an aging entry must beat a higher bar —
        # w[lane] * clip(age/ttl, 0, 1) comes off its similarity
        frac = jnp.clip((now - c) / jnp.maximum(e - c, 1e-6), 0.0, 1.0)
        pen = jnp.where(
            finite & jnp.isfinite(e), w[None, :, None] * frac, 0.0
        )
        s = s - pen
        # re-establish descending order (decide assumes best-first candidates)
        s, order = jax.lax.top_k(s, K)
        idx = jnp.take_along_axis(idx, order, axis=-1)
        s, idx, winner, hit, generative, last, cnt = decide_and_touch(
            s, idx, thresholds, qmask, last, cnt, tick
        )
        return q, s, idx, winner, hit, generative, last, cnt

    return jax.jit(program_lc, donate_argnums=(9, 10))


def fused_read(
    bank: StoreBank,
    embedder,
    texts: Sequence[str],
    thresholds: np.ndarray,  # [n, L] per-query/per-level effective t_s
    specs: Sequence[LevelSpec],
    vecs: Optional[np.ndarray] = None,
) -> ReadDecision:
    """Run one fused read over a bank: ONE device dispatch end-to-end,
    including the eviction-counter touches. ``vecs`` short-circuits the
    embed stage (callers that already hold embeddings upload them once)."""
    from repro.core.embeddings import _identity_forward
    from repro.kernels.similarity_topk import ops as st_ops

    n = len(texts)
    specs = tuple(specs)
    L = len(specs)
    K = max(s.k for s in specs)
    if vecs is not None:
        v, _ = pad_to_bucket(np.asarray(vecs, np.float32).reshape(n, bank.dim))
        args, B, forward = (v,), v.shape[0], _identity_forward
    else:
        prepare, forward = embedder.fused_forward()
        args, n_prep, B = prepare(list(texts))
        assert n_prep == n
    qmask = np.arange(B) < n
    thr = np.full((B, L), np.inf, np.float32)
    thr[:n] = np.asarray(thresholds, np.float32).reshape(n, L)

    bank.flush_pending()
    use_pallas = bank.use_pallas and bank._kernel_ok()
    lifecycle = bank.lifecycle_active()
    program = _build_program(
        forward, specs, K, bank.metrics, bank.prenorm, use_pallas,
        bank._resolved_interpret(), st_ops.default_block_n(),
        st_ops.default_grid_order(), lifecycle,
    )
    tick = bank.next_tick()
    bank.dispatches += 1
    if use_pallas:
        st_ops.record_dispatch()
    if lifecycle:
        q, s, idx, winner, hit, gen, last, cnt = program(
            args, thr, qmask, bank.buf, bank.valid,
            bank.d_created, bank.d_expires, bank.d_staleness(),
            np.float32(bank.rel_now()),
            bank.d_last_access, bank.d_access_count, np.int32(tick),
        )
    else:
        q, s, idx, winner, hit, gen, last, cnt = program(
            args, thr, qmask, bank.buf, bank.valid,
            bank.d_last_access, bank.d_access_count, np.int32(tick),
        )
    bank.adopt_fused_counters(last, cnt)
    # ONE host fetch for all decision tensors (the counters stay on device)
    q, s, idx, winner, hit, gen = jax.device_get((q, s, idx, winner, hit, gen))
    return ReadDecision(q[:n], s[:n], idx[:n], winner[:n], hit[:n], gen[:n])


def join_rows(
    store, scores: np.ndarray, idx: np.ndarray, rows: List[int], k: int
) -> dict:
    """Join only the listed row indices against the store's host entries
    (the fused path materializes winners and pool rows — not B x L rows)."""
    if not rows:
        return {}
    joined = store.join_candidates(scores[rows], idx[rows], touch=False)
    return {i: m[:k] for i, m in zip(rows, joined)}
