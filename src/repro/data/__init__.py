from repro.data.loader import ShardedLoader  # noqa: F401
from repro.data.synthetic import markov_token_stream, squad_like_qa  # noqa: F401
