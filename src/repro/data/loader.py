"""Deterministic, checkpointable, shardable data loader.

Determinism is positional: batch `i` is a pure function of (seed, i), so a
restore at step k replays exactly the stream a fresh run would have produced
— the property that makes checkpoint/restart bitwise reproducible and lets
redundant loaders on hot-spare hosts take over without coordination
(straggler mitigation, DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class ShardedLoader:
    def __init__(
        self,
        vocab: int,
        global_batch: int,
        seq_len: int,
        *,
        seed: int = 0,
        start_step: int = 0,
        num_shards: int = 1,
        shard_index: int = 0,
    ):
        assert global_batch % num_shards == 0
        self.vocab = vocab
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = start_step
        self.num_shards = num_shards
        self.shard_index = shard_index
        from repro.data.synthetic import _bigram_logits

        self._succ = _bigram_logits(vocab, seed)

    def _batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S = self.global_batch, self.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=B)
        choices = rng.integers(0, self._succ.shape[1], size=(B, S))
        noise = rng.random((B, S)) < 0.05
        rand_toks = rng.integers(0, self.vocab, size=(B, S))
        for t in range(1, S):
            nxt = self._succ[toks[:, t - 1], choices[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_toks[:, t], nxt)
        shard = B // self.num_shards
        return toks[self.shard_index * shard : (self.shard_index + 1) * shard]

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = {"tokens": self._batch_at(self.step)}
        self.step += 1
        return batch

    # -- checkpointing ---------------------------------------------------------

    def state(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: Dict) -> None:
        assert state["seed"] == self.seed, "loader seed mismatch"
        self.step = int(state["step"])
