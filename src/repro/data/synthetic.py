"""Deterministic synthetic data.

markov_token_stream — LM training batches from a fixed random bigram chain:
unlike uniform noise it has learnable structure, so a training run shows the
loss dropping below log(V) (used by examples/train_lm.py).

squad_like_qa — paraphrase-clustered QA pairs mirroring how the paper uses
SQuAD for cache experiments: each cluster has one canonical answer and a set
of paraphrases with controllable lexical overlap, so semantic-cache hit-rate
and the generative-combination behavior can be measured deterministically.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

# -- LM token stream ----------------------------------------------------------


def _bigram_logits(vocab: int, seed: int, branch: int = 32) -> np.ndarray:
    """Sparse-ish bigram transition table: each token has `branch` likely successors."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branch))
    return succ


def markov_token_stream(vocab: int, batch: int, seq_len: int, *, seed: int = 0):
    """Infinite iterator of [batch, seq_len] int32 batches (deterministic)."""
    succ = _bigram_logits(vocab, seed)
    step = 0
    while True:
        rng = np.random.default_rng((seed << 20) ^ step)
        toks = np.empty((batch, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        choices = rng.integers(0, succ.shape[1], size=(batch, seq_len))
        noise = rng.random((batch, seq_len)) < 0.05  # 5% random restarts
        rand_toks = rng.integers(0, vocab, size=(batch, seq_len))
        for t in range(1, seq_len):
            nxt = succ[toks[:, t - 1], choices[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_toks[:, t], nxt)
        yield toks
        step += 1


# -- SQuAD-like QA clusters -----------------------------------------------------

_TOPICS = [
    "denial of service attacks", "transformer attention", "photosynthesis",
    "the french revolution", "tcp congestion control", "quantum entanglement",
    "gradient descent", "the krebs cycle", "plate tectonics", "public key cryptography",
    "virtual memory paging", "the roman senate", "mitochondrial dna", "b-tree indexes",
    "the doppler effect", "garbage collection", "monetary policy", "speciation",
    "raft consensus", "convolutional networks",
]

_Q_TEMPLATES = [
    "What is {t}?",
    "Please explain {t}.",
    "I would like to learn about {t}. Can you describe it?",
    "Give me an overview of {t}.",
    "How does {t} work?",
    "Describe the key ideas behind {t}.",
    "Could you tell me about {t} in detail?",
    "Summarize {t} for me.",
]

_ASPECTS = ["defending against", "the history of", "common examples of", "limitations of"]


def squad_like_qa(
    n_clusters: int = 20,
    paraphrases: int = 4,
    *,
    seed: int = 0,
    with_aspects: bool = False,
) -> List[Tuple[str, str, int]]:
    """Returns [(question, answer, cluster_id)]. Paraphrases within a cluster
    share the topic phrase (high lexical overlap — semantically similar);
    distinct clusters are unrelated. with_aspects adds 'aspect' clusters
    (e.g. 'defending against X') that pair with base clusters for generative
    combination experiments."""
    rng = np.random.default_rng(seed)
    out = []
    cid = 0
    for i in range(n_clusters):
        topic = _TOPICS[i % len(_TOPICS)]
        answer = f"Canonical answer about {topic} (cluster {cid})."
        order = rng.permutation(len(_Q_TEMPLATES))[:paraphrases]
        for j in order:
            out.append((_Q_TEMPLATES[j].format(t=topic), answer, cid))
        cid += 1
        if with_aspects:
            aspect = _ASPECTS[i % len(_ASPECTS)]
            answer_a = f"Canonical answer about {aspect} {topic} (cluster {cid})."
            for j in rng.permutation(len(_Q_TEMPLATES))[:paraphrases]:
                out.append((_Q_TEMPLATES[j].format(t=f"{aspect} {topic}"), answer_a, cid))
            cid += 1
    return out
