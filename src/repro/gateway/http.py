"""Minimal stdlib-asyncio HTTP/1.1 server — the gateway's socket layer.

No framework, no dependencies: ``asyncio.start_server`` plus a hand-rolled
HTTP/1.1 request parser and response writer. Deliberately small surface —
what the OpenAI-compatible front end needs and nothing more:

  * keep-alive connections, ``Content-Length`` bodies (no request-side
    chunked encoding — SDK clients don't send it; it's a 400);
  * fixed responses (``Content-Length``) and streamed responses
    (``Transfer-Encoding: chunked``, used for SSE) from one ``Response``
    type carrying an optional async chunk iterator;
  * graceful drain: ``drain()`` stops accepting (listener closed, new
    requests on live connections get 503 + ``Connection: close``), waits
    for in-flight requests to finish writing, then closes what remains.

Shared state and locking
------------------------
The server itself runs on one event loop, but ``drain()``/``aclose()`` are
routinely called from OTHER threads' coroutines in tests and from signal
handlers in ``launch/serve``, so the connection table, in-flight counter,
and drain flag keep the serving layer's ``# guarded-by:`` contract
(enforced by ``python -m repro.analysis``, checker RA301): every access
sits inside ``with self._lock`` — lock holds are tiny and never span an
``await``.
"""
from __future__ import annotations

import asyncio
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, AsyncIterator, Awaitable, Callable, Dict, List, Optional, Tuple

from repro.gateway.protocol import ProtocolError

MAX_BODY_BYTES = 8 * 1024 * 1024  # a chat transcript, not an upload endpoint

_STATUS_TEXT = {
    200: "OK", 400: "Bad Request", 404: "Not Found", 405: "Method Not Allowed",
    408: "Request Timeout", 429: "Too Many Requests", 500: "Internal Server Error",
    503: "Service Unavailable", 504: "Gateway Timeout",
}


@dataclass
class HttpRequest:
    method: str
    path: str
    headers: Dict[str, str]  # keys lower-cased
    body: bytes = b""

    def json(self) -> Dict[str, Any]:
        """Parse the body as a JSON object; malformed input is a 400."""
        try:
            obj = json.loads(self.body.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ProtocolError(400, f"request body is not valid JSON: {e}") from e
        if not isinstance(obj, dict):
            raise ProtocolError(400, "request body must be a JSON object")
        return obj


@dataclass
class Response:
    """One HTTP response. ``body`` for fixed payloads; ``chunks`` (an async
    byte iterator) switches the writer to chunked transfer — SSE streams
    ride this. ``headers`` never includes framing headers; the writer owns
    ``Content-Length``/``Transfer-Encoding``/``Connection``."""

    status: int = 200
    headers: List[Tuple[str, str]] = field(default_factory=list)
    body: bytes = b""
    content_type: str = "application/json"
    chunks: Optional[AsyncIterator[bytes]] = None

    @classmethod
    def json_response(cls, payload: Dict[str, Any], status: int = 200,
                      headers: Optional[List[Tuple[str, str]]] = None) -> "Response":
        return cls(status, list(headers or []), json.dumps(payload).encode())


Handler = Callable[[HttpRequest], Awaitable[Response]]


class GatewayHttpServer:
    """``asyncio`` HTTP/1.1 listener delegating every request to ``handler``."""

    def __init__(self, handler: Handler, host: str = "127.0.0.1", port: int = 0):
        self.handler = handler
        self.host = host
        self.port = port  # rebound to the real port after start() when 0
        self._server: Optional[asyncio.AbstractServer] = None
        self._conns: Dict[asyncio.StreamWriter, bool] = {}  # guarded-by: _lock — writer -> mid-request
        self._inflight = 0  # guarded-by: _lock — requests parsed, response not yet written
        self._draining = False  # guarded-by: _lock
        self._requests_served = 0  # guarded-by: _lock
        self._lock = threading.Lock()  # connection table + drain state

    # -- lifecycle -------------------------------------------------------------

    async def start(self) -> Tuple[str, int]:
        self._server = await asyncio.start_server(self._serve_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.host, self.port

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    @property
    def requests_served(self) -> int:
        with self._lock:
            return self._requests_served

    @property
    def draining(self) -> bool:
        with self._lock:
            return self._draining

    async def drain(self, timeout: float = 10.0) -> bool:
        """Graceful shutdown, phase one: stop accepting (listener closed,
        fresh requests answered 503), wait for every in-flight request to
        finish writing, then close idle connections. Returns True when the
        server drained clean within ``timeout``."""
        with self._lock:
            self._draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._lock:
                if self._inflight == 0:
                    break
            await asyncio.sleep(0.01)
        with self._lock:
            clean = self._inflight == 0
            writers = list(self._conns)
        for w in writers:  # drained (or timed out): drop what's left
            w.close()
        return clean

    async def aclose(self, timeout: float = 10.0) -> bool:
        return await self.drain(timeout=timeout)

    # -- connection handling ---------------------------------------------------

    async def _serve_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        with self._lock:
            if self._draining:
                writer.close()
                return
            self._conns[writer] = False
        try:
            while True:
                try:
                    request = await self._read_request(reader)
                except (asyncio.IncompleteReadError, ConnectionError):
                    break  # client went away between requests
                except asyncio.LimitOverrunError:
                    await self._write_simple(writer, 431, b"", close=True)
                    break
                except ProtocolError as e:
                    from repro.gateway.errors import map_exception

                    status, headers, body = map_exception(e)
                    await self._write_response(
                        writer, Response(status, headers, body), close=True
                    )
                    break
                if request is None:
                    break  # clean EOF at a request boundary
                with self._lock:
                    draining = self._draining
                    if not draining:
                        self._conns[writer] = True
                        self._inflight += 1
                if draining:
                    from repro.gateway.errors import draining_unavailable

                    status, headers, body = draining_unavailable()
                    await self._write_response(
                        writer, Response(status, headers, body), close=True
                    )
                    break
                try:
                    response = await self._dispatch(request)
                    close = (
                        request.headers.get("connection", "").lower() == "close"
                    )
                    await self._write_response(writer, response, close=close)
                finally:
                    with self._lock:
                        self._inflight -= 1
                        self._requests_served += 1
                        self._conns[writer] = False
                if close:
                    break
        except (ConnectionError, asyncio.CancelledError):
            pass  # mid-write disconnects are the client's prerogative
        finally:
            with self._lock:
                self._conns.pop(writer, None)
            writer.close()

    async def _dispatch(self, request: HttpRequest) -> Response:
        try:
            return await self.handler(request)
        except Exception as e:  # noqa: BLE001 — every failure gets a wire shape
            from repro.gateway.errors import map_exception

            status, headers, body = map_exception(e)
            return Response(status, headers, body)

    # -- parsing ---------------------------------------------------------------

    async def _read_request(self, reader: asyncio.StreamReader) -> Optional[HttpRequest]:
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except asyncio.IncompleteReadError as e:
            if not e.partial:
                return None  # clean close between requests
            raise
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
            raise ProtocolError(400, f"malformed request line: {lines[0]!r}")
        method, target = parts[0], parts[1]
        headers: Dict[str, str] = {}
        for line in lines[1:]:
            if not line:
                continue
            name, sep, value = line.partition(":")
            if not sep:
                raise ProtocolError(400, f"malformed header line: {line!r}")
            headers[name.strip().lower()] = value.strip()
        if "chunked" in headers.get("transfer-encoding", "").lower():
            raise ProtocolError(400, "chunked request bodies are not supported")
        body = b""
        if "content-length" in headers:
            try:
                length = int(headers["content-length"])
            except ValueError:
                raise ProtocolError(400, "invalid Content-Length") from None
            if length < 0 or length > MAX_BODY_BYTES:
                raise ProtocolError(400, f"Content-Length out of range: {length}")
            body = await reader.readexactly(length)
        path = target.split("?", 1)[0]
        return HttpRequest(method, path, headers, body)

    # -- writing ---------------------------------------------------------------

    async def _write_response(self, writer: asyncio.StreamWriter,
                              response: Response, *, close: bool = False) -> None:
        reason = _STATUS_TEXT.get(response.status, "Unknown")
        head = [f"HTTP/1.1 {response.status} {reason}"]
        names = {n.lower() for n, _ in response.headers}
        if "content-type" not in names:
            head.append(f"Content-Type: {response.content_type}")
        for name, value in response.headers:
            head.append(f"{name}: {value}")
        if response.chunks is None:
            head.append(f"Content-Length: {len(response.body)}")
            head.append(f"Connection: {'close' if close else 'keep-alive'}")
            writer.write(("\r\n".join(head) + "\r\n\r\n").encode("latin-1"))
            writer.write(response.body)
            await writer.drain()
            return
        head.append("Transfer-Encoding: chunked")
        head.append(f"Connection: {'close' if close else 'keep-alive'}")
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode("latin-1"))
        await writer.drain()
        async for chunk in response.chunks:
            if not chunk:
                continue
            writer.write(f"{len(chunk):x}\r\n".encode("latin-1") + chunk + b"\r\n")
            await writer.drain()
        writer.write(b"0\r\n\r\n")
        await writer.drain()

    async def _write_simple(self, writer: asyncio.StreamWriter, status: int,
                            body: bytes, *, close: bool) -> None:
        await self._write_response(writer, Response(status, [], body), close=close)
