"""Minimal stdlib HTTP client for the gateway: JSON calls + SSE reassembly.

``http.client`` based (synchronous — the traffic harness drives it from a
thread pool, which is also how real SDK clients behave), with just enough
SSE parsing to reassemble a streamed completion back into the exact text a
non-streamed call returns: the byte-parity contract the gateway tests pin.
"""
from __future__ import annotations

import http.client
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass
class GatewayReply:
    """One HTTP exchange, with streamed events reassembled."""

    status: int
    headers: Dict[str, str]  # lower-cased names
    body: bytes
    events: List[Dict[str, Any]] = field(default_factory=list)  # SSE data objects
    done: bool = False  # saw the `data: [DONE]` terminator

    def json(self) -> Dict[str, Any]:
        return json.loads(self.body.decode("utf-8"))

    @property
    def text(self) -> Optional[str]:
        """The completion text: from the JSON body (non-streamed) or
        reassembled from the chunk deltas (streamed). None on errors."""
        if self.status != 200:
            return None
        if self.events:
            parts: List[str] = []
            for ev in self.events:
                choice = (ev.get("choices") or [{}])[0]
                if "delta" in choice:  # chat chunk
                    parts.append(choice["delta"].get("content", ""))
                else:  # text_completion chunk
                    parts.append(choice.get("text", ""))
            return "".join(parts)
        payload = self.json()
        choice = (payload.get("choices") or [{}])[0]
        if "message" in choice:
            return choice["message"].get("content")
        return choice.get("text")


def parse_sse(raw: bytes) -> Tuple[List[Dict[str, Any]], bool]:
    """Split an SSE byte stream into its JSON data events; returns
    (events, saw_done)."""
    events: List[Dict[str, Any]] = []
    done = False
    for block in raw.split(b"\n\n"):
        for line in block.split(b"\n"):
            if not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):]
            if data.strip() == b"[DONE]":
                done = True
            else:
                events.append(json.loads(data.decode("utf-8")))
    return events, done


class GatewayClient:
    """One keep-alive connection to a gateway. Not thread-safe — give each
    harness worker its own instance (mirrors per-user SDK clients)."""

    def __init__(self, host: str, port: int, timeout: float = 30.0):
        self.host = host
        self.port = port
        self.timeout = timeout
        self._conn: Optional[http.client.HTTPConnection] = None

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout
            )
        return self._conn

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- raw request -----------------------------------------------------------

    def request(self, method: str, path: str,
                payload: Optional[Dict[str, Any]] = None) -> GatewayReply:
        conn = self._connection()
        body = None if payload is None else json.dumps(payload).encode()
        headers = {"Content-Type": "application/json"} if body else {}
        try:
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            raw = resp.read()  # drains chunked SSE bodies too
        except (http.client.HTTPException, ConnectionError, OSError):
            self.close()  # poisoned keep-alive connection; next call redials
            raise
        hdrs = {k.lower(): v for k, v in resp.getheaders()}
        if resp.will_close:
            self.close()
        if hdrs.get("content-type", "").startswith("text/event-stream"):
            events, done = parse_sse(raw)
            return GatewayReply(resp.status, hdrs, raw, events, done)
        return GatewayReply(resp.status, hdrs, raw)

    # -- the two OpenAI surfaces -----------------------------------------------

    def chat(self, content: str, *, system: Optional[str] = None,
             stream: bool = False, **fields) -> GatewayReply:
        messages = [{"role": "user", "content": content}]
        if system is not None:
            messages.insert(0, {"role": "system", "content": system})
        return self.request(
            "POST", "/v1/chat/completions",
            {"messages": messages, "stream": stream, **fields},
        )

    def completion(self, prompt: str, *, stream: bool = False,
                   **fields) -> GatewayReply:
        return self.request(
            "POST", "/v1/completions", {"prompt": prompt, "stream": stream, **fields}
        )

    def healthz(self) -> GatewayReply:
        return self.request("GET", "/healthz")

    def cache_stats(self) -> GatewayReply:
        return self.request("GET", "/v1/cache/stats")
