"""HTTP gateway + traffic-replay harness: the serving surface over
``CacheService``.

Two halves (see README "HTTP gateway"):

  * ``repro.gateway.app.Gateway`` — a stdlib-asyncio OpenAI-compatible
    front end (``/v1/chat/completions``, ``/v1/completions``, ``/healthz``,
    ``/v1/cache/stats``) with SSE streaming for hits and misses,
    cache-status headers, typed error mapping, and graceful drain;
  * ``repro.gateway.traffic`` — reproducible Zipfian/bursty workload
    generation and replay (in-process or over real HTTP), reporting
    p50/p95/p99 per cache class into ``BENCH_traffic.json`` — the
    end-to-end load gate every scale-out PR must move.
"""
from repro.gateway.app import (  # noqa: F401
    Gateway,
    GatewayStats,
    GatewayThread,
    serve_in_thread,
)
from repro.gateway.client import GatewayClient, GatewayReply, parse_sse  # noqa: F401
from repro.gateway.http import GatewayHttpServer, HttpRequest, Response  # noqa: F401
from repro.gateway.protocol import ProtocolError  # noqa: F401
