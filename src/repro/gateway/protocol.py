"""OpenAI-compatible wire protocol: JSON bodies <-> ``CacheRequest``.

The gateway is a drop-in ``base_url`` replacement (the llm-cache /
GPT-Semantic-Cache proxy pattern): an unmodified OpenAI-SDK-shaped client
POSTs ``/v1/chat/completions`` or ``/v1/completions`` and gets back the
standard ``chat.completion`` / ``text_completion`` objects — or, with
``"stream": true``, the standard ``data:``-framed SSE chunk stream ending
in ``data: [DONE]``. This module owns both directions of that translation
plus the SSE framing; it never touches a socket.

Cache-specific knobs ride as OPTIONAL top-level extension fields the
OpenAI schema ignores: ``priority`` (int), ``deadline_ms`` (float),
``ttl_s`` (float), ``use_cache`` / ``force_fresh`` / ``cache_l1`` /
``cache_l2`` / ``allow_stale`` (bools), ``max_stale_s`` (float, bounds
the stale-if-error window). Unknown fields are ignored, wrong TYPES are a 400 —
silently coercing them would serve an answer the client didn't ask for.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.request import CacheRequest, CacheResponse


class ProtocolError(Exception):
    """A malformed request, mapped by the gateway to an HTTP error.

    ``status`` is the HTTP status code; ``err_type``/``code`` land in the
    OpenAI-style JSON error body."""

    def __init__(self, status: int, message: str, err_type: str = "invalid_request_error",
                 code: Optional[str] = None):
        super().__init__(message)
        self.status = status
        self.err_type = err_type
        self.code = code


def error_body(message: str, err_type: str, code: Optional[str] = None) -> bytes:
    """OpenAI-style JSON error envelope."""
    return json.dumps(
        {"error": {"message": message, "type": err_type, "param": None, "code": code}}
    ).encode()


# -- request parsing -----------------------------------------------------------


def _field(body: Dict[str, Any], name: str, types, default):
    val = body.get(name, default)
    if val is default:
        return default
    if types is float and isinstance(val, int) and not isinstance(val, bool):
        val = float(val)  # JSON has one number type; ints are fine for floats
    if not isinstance(val, types) or isinstance(val, bool) and types is not bool:
        raise ProtocolError(400, f"'{name}' must be {getattr(types, '__name__', types)}")
    return val


def _common_knobs(body: Dict[str, Any]) -> Dict[str, Any]:
    """Shared OpenAI params + cache extension fields -> CacheRequest kwargs."""
    deadline_ms = _field(body, "deadline_ms", float, None)
    kw = dict(
        model=_field(body, "model", str, None),
        max_tokens=_field(body, "max_tokens", int, 256),
        temperature=_field(body, "temperature", float, 0.0),
        stream=_field(body, "stream", bool, False),
        priority=_field(body, "priority", int, 0),
        deadline_s=None if deadline_ms is None else deadline_ms / 1e3,
        ttl_s=_field(body, "ttl_s", float, None),
        use_cache=_field(body, "use_cache", bool, True),
        force_fresh=_field(body, "force_fresh", bool, False),
        cache_l1=_field(body, "cache_l1", bool, True),
        cache_l2=_field(body, "cache_l2", bool, True),
        # stale-if-error opt-in (resilience): serve an expired entry instead
        # of a 503 when every backend is down, bounded by max_stale_s
        allow_stale=_field(body, "allow_stale", bool, False),
        max_stale_s=_field(body, "max_stale_s", float, None),
    )
    if kw["max_tokens"] <= 0:
        raise ProtocolError(400, "'max_tokens' must be positive")
    if kw["max_stale_s"] is not None and kw["max_stale_s"] < 0:
        raise ProtocolError(400, "'max_stale_s' must be non-negative")
    return kw


def render_messages(messages: List[Dict[str, Any]]) -> str:
    """Deterministically flatten a chat transcript into the cache prompt.

    The cache keys on semantic similarity of the WHOLE conversation, so the
    rendering must be stable across requests: ``role: content`` lines in
    order. (A system prompt change therefore changes the cache key — the
    conservative choice for correctness.)"""
    lines = []
    for i, msg in enumerate(messages):
        if not isinstance(msg, dict):
            raise ProtocolError(400, f"messages[{i}] must be an object")
        role, content = msg.get("role"), msg.get("content")
        if not isinstance(role, str) or not isinstance(content, str):
            raise ProtocolError(
                400, f"messages[{i}] needs string 'role' and 'content' fields"
            )
        lines.append(f"{role}: {content}")
    return "\n".join(lines)


def parse_chat_request(body: Dict[str, Any]) -> CacheRequest:
    """``/v1/chat/completions`` body -> ``CacheRequest``."""
    messages = body.get("messages")
    if not isinstance(messages, list) or not messages:
        raise ProtocolError(400, "'messages' must be a non-empty array")
    return CacheRequest(render_messages(messages), **_common_knobs(body))


def parse_completion_request(body: Dict[str, Any]) -> CacheRequest:
    """``/v1/completions`` body -> ``CacheRequest``. A single-element array
    prompt is accepted (SDKs send it); true batch prompts are rejected —
    the service batches across HTTP requests, not within one."""
    prompt = body.get("prompt")
    if isinstance(prompt, list) and len(prompt) == 1 and isinstance(prompt[0], str):
        prompt = prompt[0]
    if not isinstance(prompt, str) or not prompt:
        raise ProtocolError(
            400, "'prompt' must be a non-empty string (or a 1-element string array)"
        )
    return CacheRequest(prompt, **_common_knobs(body))


# -- response building ---------------------------------------------------------


def _usage(prompt: str, text: str) -> Dict[str, int]:
    p, c = len(prompt.split()), len((text or "").split())
    return {"prompt_tokens": p, "completion_tokens": c, "total_tokens": p + c}


def completion_body(
    resp: CacheResponse, request: CacheRequest, *, chat: bool
) -> Dict[str, Any]:
    """Non-streamed ``chat.completion`` / ``text_completion`` object."""
    created = int(time.time())
    rid = f"{'chatcmpl' if chat else 'cmpl'}-{resp.request_id}"
    if chat:
        choice: Dict[str, Any] = {
            "index": 0,
            "message": {"role": "assistant", "content": resp.text},
            "finish_reason": "stop",
        }
        obj = "chat.completion"
    else:
        choice = {"index": 0, "text": resp.text, "finish_reason": "stop"}
        obj = "text_completion"
    return {
        "id": rid,
        "object": obj,
        "created": created,
        "model": resp.model,
        "choices": [choice],
        "usage": _usage(request.prompt, resp.text or ""),
    }


def stream_chunk_body(
    resp: CacheResponse, *, chat: bool, text: Optional[str], first: bool, final: bool
) -> Dict[str, Any]:
    """One SSE chunk object. Chat streams open with a role-only delta and
    close with an empty delta + ``finish_reason`` (the OpenAI framing);
    completion streams just carry text chunks."""
    created = int(time.time())
    rid = f"{'chatcmpl' if chat else 'cmpl'}-{resp.request_id}"
    if chat:
        delta: Dict[str, Any] = {}
        if first:
            delta["role"] = "assistant"
        if text:
            delta["content"] = text
        choice: Dict[str, Any] = {
            "index": 0,
            "delta": delta,
            "finish_reason": "stop" if final else None,
        }
        obj = "chat.completion.chunk"
    else:
        choice = {
            "index": 0,
            "text": text or "",
            "finish_reason": "stop" if final else None,
        }
        obj = "text_completion"
    return {"id": rid, "object": obj, "created": created, "model": resp.model,
            "choices": [choice]}


def sse_event(payload: Dict[str, Any]) -> bytes:
    return b"data: " + json.dumps(payload).encode() + b"\n\n"


SSE_DONE = b"data: [DONE]\n\n"


def cache_headers(resp: CacheResponse) -> List[Tuple[str, str]]:
    """The gateway's cache-status header contract (README table)."""
    headers = [
        ("X-Cache", resp.cache_status),
        ("X-Cache-Level", resp.resolved_level),
        ("X-Service-Latency-Ms", f"{resp.latency_s * 1e3:.2f}"),
        ("X-Request-Id", str(resp.request_id)),
    ]
    if resp.similarity is not None and resp.from_cache:
        headers.insert(2, ("X-Cache-Similarity", f"{resp.similarity:.4f}"))
    return headers
