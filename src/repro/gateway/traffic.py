"""Traffic-replay harness: reproducible heavy mixed load for the cache stack.

SCALM's analysis of production chat traffic says real load is *skewed* and
*bursty*: query popularity is Zipfian (a few questions dominate), arrivals
cluster per user, and requests carry mixed priorities and deadlines. This
harness generates that shape deterministically (one seed = one workload,
byte-for-byte) and replays it two ways:

  * **in-process** — ``service.submit`` per arrival, futures resolving
    asynchronously (measures the serving stack without socket overhead);
  * **http** — per-user threads drive real ``GatewayClient`` connections
    against a live ``Gateway`` (streamed and non-streamed mixed), so the
    numbers include the full wire path.

Both report p50/p95/p99 latency per cache class (``hit`` / ``generative``
/ ``tier1`` / ``miss``), throughput, per-level hit fractions, shed (429 /
``AdmissionRejected``) and expiry counts, and — the drain gate — how many
accepted requests were left unresolved after graceful shutdown (must be
zero). ``main`` writes ``BENCH_traffic.json``; CI blocks on hit-p50 being
>=5x below miss-p50 under the mixed workload and on a clean drain. This is
the end-to-end load gate every later scale-out PR must move.

A third mode replays the SAME workload through ``build_chaos_stack`` — a
seeded ``FaultInjector`` dropping/slowing ~30% of backend calls while one
backend flaps — then kills every backend and keeps asking: the breaker +
retry + stale-if-error ladder must hold availability while cached answers
(valid -> ``hit``, expired -> ``stale``) keep flowing. ``--chaos`` writes
``BENCH_chaos.json``; CI gates on availability, stale byte-parity, and
hit-path isolation (chaos hit p50 vs the clean replay's).

Run:  PYTHONPATH=src python -m repro.gateway.traffic --smoke
      PYTHONPATH=src python -m repro.gateway.traffic --mode http --requests 512
      PYTHONPATH=src python -m repro.gateway.traffic --chaos --smoke
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from concurrent.futures import Future
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import CacheRequest, CacheResponse
from repro.resilience.errors import AllBackendsFailed
from repro.serving.coalescer import AdmissionRejected, ServiceClosed
from repro.serving.service import CacheService

# paraphrase templates wrap a canonical query without destroying its n-gram
# signature — near-threshold lookups that exercise the semantic/generative
# decision, exactly the traffic the paper's rule is for
PARAPHRASES = (
    "could you tell me {}",
    "please explain {}",
    "{} - what is the answer",
    "quick question: {}",
    "i was wondering, {}",
)
COMBINER = "{} and also {}"  # two-source prompts poke the generative rule


@dataclass
class TrafficConfig:
    n_requests: int = 512
    n_users: int = 24
    corpus_size: int = 64
    zipf_s: float = 1.1  # popularity skew: weight(rank) ~ (rank+1)^-s
    uniform_rate: float = 0.15  # tail revisits: re-ask an evicted cold entry (tier-1 path)
    paraphrase_rate: float = 0.30
    combine_rate: float = 0.08
    novel_rate: float = 0.25  # one-off never-seen prompts: the true-miss slice
    arrival: str = "bursty"  # "poisson" | "bursty"
    mean_interarrival_s: float = 0.03  # per-user mean think time
    burst_len: int = 4
    burst_rate_factor: float = 25.0  # in-burst arrivals are this much faster
    priority_choices: Tuple[int, ...] = (0, 0, 0, 1, 3)
    deadline_fraction: float = 0.2
    deadline_ms: Tuple[float, float] = (250.0, 2000.0)
    ttl_fraction: float = 0.25
    ttl_choices_s: Tuple[float, ...] = (60.0, 600.0)
    stream_fraction: float = 0.5  # http mode: fraction served over SSE
    max_tokens: int = 64
    seed: int = 0


@dataclass
class TimedRequest:
    t: float  # arrival offset from replay start (seconds)
    user: int
    prompt: str
    canonical: int  # corpus rank the prompt derives from (-1 = combined)
    priority: int = 0
    deadline_s: Optional[float] = None
    ttl_s: Optional[float] = None
    stream: bool = False
    max_tokens: int = 64
    allow_stale: bool = False  # stale-if-error opt-in (chaos replays)
    max_stale_s: Optional[float] = None

    def to_cache_request(self) -> CacheRequest:
        return CacheRequest(
            self.prompt, max_tokens=self.max_tokens, priority=self.priority,
            deadline_s=self.deadline_s, ttl_s=self.ttl_s, stream=self.stream,
            allow_stale=self.allow_stale, max_stale_s=self.max_stale_s,
        )

    def to_payload(self) -> Dict[str, Any]:
        body: Dict[str, Any] = {
            "prompt": self.prompt, "max_tokens": self.max_tokens,
            "stream": self.stream, "priority": self.priority,
        }
        if self.deadline_s is not None:
            body["deadline_ms"] = self.deadline_s * 1e3
        if self.ttl_s is not None:
            body["ttl_s"] = self.ttl_s
        if self.allow_stale:
            body["allow_stale"] = True
            if self.max_stale_s is not None:
                body["max_stale_s"] = self.max_stale_s
        return body


def make_corpus(cfg: TrafficConfig) -> List[str]:
    """Seeded canonical queries, rank-ordered by popularity."""
    return [
        f"how does component {i} of the {['storage', 'serving', 'routing', 'billing'][i % 4]} "
        f"subsystem behave under heavy load"
        for i in range(cfg.corpus_size)
    ]


def generate_workload(cfg: TrafficConfig) -> List[TimedRequest]:
    """One seed -> one workload, independent of wall clock or host."""
    rng = np.random.default_rng(cfg.seed)
    corpus = make_corpus(cfg)
    weights = (np.arange(cfg.corpus_size) + 1.0) ** -cfg.zipf_s
    weights /= weights.sum()

    # spread the request budget across users, +-25% so users aren't uniform
    quota = np.maximum(
        1, rng.poisson(cfg.n_requests / cfg.n_users, size=cfg.n_users)
    )
    while quota.sum() > cfg.n_requests:
        quota[int(rng.integers(cfg.n_users))] = max(
            1, quota[int(rng.integers(cfg.n_users))] - 1
        )
    while quota.sum() < cfg.n_requests:
        quota[int(rng.integers(cfg.n_users))] += 1

    events: List[TimedRequest] = []
    novel_seq = 0
    for user in range(cfg.n_users):
        t = float(rng.exponential(cfg.mean_interarrival_s))
        burst_left = 0
        for _ in range(int(quota[user])):
            # mostly Zipf-popular queries; a uniform slice revisits the cold
            # tail, whose entries have usually demoted to tier 1 by then
            if rng.random() < cfg.uniform_rate:
                rank = int(rng.integers(cfg.corpus_size))
            else:
                rank = int(rng.choice(cfg.corpus_size, p=weights))
            roll = rng.random()
            if roll < cfg.novel_rate:
                # a question nobody asked before and nobody asks again: the
                # long tail that must reach the backend (the miss lane)
                novel_seq += 1
                prompt = (
                    f"one-off question {novel_seq} from user {user}: what is "
                    f"the provenance of artifact {novel_seq * 7919} in run {user}"
                )
                canonical = -2
            elif roll < cfg.novel_rate + cfg.combine_rate and cfg.corpus_size >= 2:
                other = int(rng.choice(cfg.corpus_size, p=weights))
                prompt = COMBINER.format(corpus[rank], corpus[other])
                canonical = -1
            elif roll < cfg.novel_rate + cfg.combine_rate + cfg.paraphrase_rate:
                tmpl = PARAPHRASES[int(rng.integers(len(PARAPHRASES)))]
                prompt, canonical = tmpl.format(corpus[rank]), rank
            else:
                prompt, canonical = corpus[rank], rank
            deadline_s = (
                float(rng.uniform(*cfg.deadline_ms)) / 1e3
                if rng.random() < cfg.deadline_fraction
                else None
            )
            ttl_s = (
                float(cfg.ttl_choices_s[int(rng.integers(len(cfg.ttl_choices_s)))])
                if rng.random() < cfg.ttl_fraction
                else None
            )
            events.append(TimedRequest(
                t, user, prompt, canonical,
                priority=int(cfg.priority_choices[int(rng.integers(len(cfg.priority_choices)))]),
                deadline_s=deadline_s, ttl_s=ttl_s,
                stream=bool(rng.random() < cfg.stream_fraction),
                max_tokens=cfg.max_tokens,
            ))
            # advance this user's clock: Poisson think time, or a burst of
            # near-back-to-back arrivals (ON/OFF, the SCALM burstiness shape)
            if cfg.arrival == "bursty":
                if burst_left > 0:
                    burst_left -= 1
                    t += float(rng.exponential(
                        cfg.mean_interarrival_s / cfg.burst_rate_factor
                    ))
                else:
                    if rng.random() < 0.35:
                        burst_left = cfg.burst_len - 1
                    t += float(rng.exponential(cfg.mean_interarrival_s))
            else:
                t += float(rng.exponential(cfg.mean_interarrival_s))
    events.sort(key=lambda e: (e.t, e.user))
    return events


def apply_stale_policy(
    workload: Sequence[TimedRequest],
    fraction: float = 1.0,
    *,
    max_stale_s: Optional[float] = None,
    seed: int = 1,
) -> None:
    """Mark a seeded ``fraction`` of ``workload`` as ``allow_stale`` in
    place — the opt-in the chaos replay uses. Drawn from its OWN rng so the
    base workload stays byte-identical to the non-chaos replay (same seed
    -> same prompts, arrivals, deadlines)."""
    rng = np.random.default_rng(seed)
    for tr in workload:
        if rng.random() < fraction:
            tr.allow_stale = True
            tr.max_stale_s = max_stale_s


# -- measurement ----------------------------------------------------------------


CLASSES = ("hit", "generative", "tier1", "miss", "stale")


@dataclass
class TrafficReport:
    mode: str
    n_requests: int = 0
    wall_s: float = 0.0
    latencies_s: Dict[str, List[float]] = field(
        default_factory=lambda: {c: [] for c in CLASSES}
    )
    shed: int = 0  # 429 / AdmissionRejected
    expired: int = 0  # 504 / DEADLINE_EXCEEDED
    errors: int = 0  # anything else that wasn't a served answer
    backend_unavailable: int = 0  # 503 / AllBackendsFailed with no stale entry
    dropped_at_drain: int = 0  # accepted but unresolved after shutdown — MUST be 0
    drain_clean: bool = True

    def record(self, cls: str, latency_s: float) -> None:
        self.latencies_s.setdefault(cls, []).append(latency_s)

    @property
    def hit_latencies(self) -> List[float]:
        return [
            x for c in ("hit", "generative", "tier1") for x in self.latencies_s[c]
        ]

    def to_dict(self) -> Dict[str, Any]:
        def pct(xs: Sequence[float], q: float) -> float:
            return float(np.percentile(np.asarray(xs) * 1e3, q)) if xs else float("nan")

        served = sum(len(v) for v in self.latencies_s.values())
        hits, misses = self.hit_latencies, self.latencies_s["miss"]
        hit_p50, miss_p50 = pct(hits, 50), pct(misses, 50)
        return {
            "mode": self.mode,
            "n_requests": self.n_requests,
            "wall_s": self.wall_s,
            "throughput_rps": served / self.wall_s if self.wall_s else 0.0,
            "latency_ms": {
                cls: {
                    "p50": pct(xs, 50), "p95": pct(xs, 95), "p99": pct(xs, 99),
                    "n": len(xs),
                }
                for cls, xs in self.latencies_s.items()
            },
            "level_fractions": {
                cls: len(xs) / served if served else 0.0
                for cls, xs in self.latencies_s.items()
            },
            "hit_p50_ms": hit_p50,
            "miss_p50_ms": miss_p50,
            "hit_vs_miss_p50_ratio": (
                miss_p50 / hit_p50 if hits and misses and hit_p50 > 0 else float("nan")
            ),
            "shed": self.shed,
            "expired": self.expired,
            "errors": self.errors,
            "backend_unavailable": self.backend_unavailable,
            "dropped_at_drain": self.dropped_at_drain,
            "drain_clean": self.drain_clean,
            "stale_served": len(self.latencies_s.get("stale", [])),
            # of requests that ran to a terminal outcome (sheds and queue
            # expiries excluded — those are load/deadline policy, not
            # failures), the fraction answered with content. Stale counts:
            # serving yesterday's answer IS the availability mechanism.
            "availability": (
                served / (served + self.errors + self.backend_unavailable)
                if served + self.errors + self.backend_unavailable
                else 1.0
            ),
        }


def _classify(resp: CacheResponse) -> str:
    return "expired" if resp.expired else resp.cache_status


# -- drivers --------------------------------------------------------------------


def run_inprocess(
    service: CacheService,
    workload: Sequence[TimedRequest],
    *,
    time_scale: float = 1.0,
    close_service: bool = True,
) -> TrafficReport:
    """Replay arrivals against ``service.submit`` and drain at the end.

    Latency is submit-to-future-resolution per request. ``close_service``
    runs the graceful drain (``service.close()``) and counts futures still
    unresolved afterwards — the zero-dropped gate."""
    report = TrafficReport("inprocess", n_requests=len(workload))
    lock = threading.Lock()
    futures: List[Future] = []
    t0 = time.perf_counter()
    for tr in workload:
        target = t0 + tr.t * time_scale
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t_submit = time.perf_counter()
        try:
            fut = service.submit(tr.to_cache_request())
        except AdmissionRejected:
            with lock:
                report.shed += 1
            continue
        except ServiceClosed:
            with lock:
                report.errors += 1
            continue
        futures.append(fut)

        def cb(f: Future, t_submit: float = t_submit) -> None:
            lat = time.perf_counter() - t_submit
            try:
                resp = f.result()
            except AllBackendsFailed:
                # every backend open/down and no stale entry could answer —
                # the degradation ladder's floor, counted apart from bugs
                with lock:
                    report.backend_unavailable += 1
                return
            except Exception:  # noqa: BLE001 — counted, not raised mid-replay
                with lock:
                    report.errors += 1
                return
            cls = _classify(resp)
            with lock:
                if cls == "expired":
                    report.expired += 1
                else:
                    report.record(cls, lat)

        fut.add_done_callback(cb)
    if close_service:
        service.close()  # graceful drain: every accepted future resolves
    else:
        for f in futures:
            try:
                f.result(timeout=60)
            except Exception:  # noqa: BLE001 — already counted by the callback
                pass
    report.wall_s = time.perf_counter() - t0
    report.dropped_at_drain = sum(1 for f in futures if not f.done())
    report.drain_clean = report.dropped_at_drain == 0
    return report


def run_http(
    host: str,
    port: int,
    workload: Sequence[TimedRequest],
    *,
    time_scale: float = 1.0,
) -> TrafficReport:
    """Replay over real HTTP: one thread + one keep-alive connection per
    user (the SDK-client shape), each replaying its own arrival timeline.
    Streamed requests count their latency to stream completion."""
    from repro.gateway.client import GatewayClient

    report = TrafficReport("http", n_requests=len(workload))
    lock = threading.Lock()
    by_user: Dict[int, List[TimedRequest]] = {}
    for tr in workload:
        by_user.setdefault(tr.user, []).append(tr)
    t0 = time.perf_counter()

    def worker(items: List[TimedRequest]) -> None:
        with GatewayClient(host, port, timeout=60.0) as client:
            for tr in items:
                target = t0 + tr.t * time_scale
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                t_send = time.perf_counter()
                try:
                    reply = client.request("POST", "/v1/completions", tr.to_payload())
                except Exception:  # noqa: BLE001 — a vanished reply is a drop
                    with lock:
                        report.dropped_at_drain += 1
                    continue
                lat = time.perf_counter() - t_send
                with lock:
                    if reply.status == 200:
                        report.record(
                            reply.headers.get("x-cache", "miss"), lat
                        )
                    elif reply.status == 429:
                        report.shed += 1
                    elif reply.status == 503:
                        report.backend_unavailable += 1
                    elif reply.status == 504:
                        report.expired += 1
                    else:
                        report.errors += 1

    threads = [
        threading.Thread(target=worker, args=(items,), daemon=True)
        for items in by_user.values()
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120)
    report.wall_s = time.perf_counter() - t0
    report.drain_clean = report.dropped_at_drain == 0
    return report


# -- stack construction + CLI ---------------------------------------------------


def build_stack(
    *,
    backend_latency_s: float = 0.12,
    capacity: int = 2048,
    tier1_capacity: int = 0,
    max_inflight: int = 512,
    threshold: float = 0.8,
):
    """A MockLLM-backed cache stack shaped like the serving deployments:
    GenerativeCache (semantic + generative rule), optional host-RAM tier 1
    behind a small tier 0 (so the replay exercises ``X-Cache: tier1``)."""
    from repro.core import (
        EnhancedClient,
        GenerativeCache,
        MockLLM,
        NgramHashEmbedder,
    )
    from repro.core.tiers import HostRamTier
    from repro.core.vector_store import InMemoryVectorStore

    emb = NgramHashEmbedder()
    store = None
    if tier1_capacity:
        store = InMemoryVectorStore(
            emb.dim, capacity=capacity, eviction="lru",
            tier1=HostRamTier(emb.dim, capacity=tier1_capacity),
        )
    cache = GenerativeCache(
        emb, threshold=threshold, t_single=0.45, t_combined=1.0,
        capacity=capacity, store=store, cache_synthesized=False,
    )
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("replay-backend", latency_s=backend_latency_s))
    service = CacheService(client, max_batch=16, max_wait_ms=2.0,
                           max_inflight=max_inflight)
    return service, client, cache


CHAOS_BACKENDS = ("chaos-flappy", "chaos-primary", "chaos-reserve")


def build_chaos_stack(
    *,
    backend_latency_s: float = 0.04,
    capacity: int = 2048,
    tier1_capacity: int = 0,
    max_inflight: int = 512,
    threshold: float = 0.8,
    fault_rate: float = 0.3,
    flap_period: int = 6,
    seed: int = 0,
):
    """``build_stack``'s resilience twin: three MockLLM backends behind ONE
    seeded ``FaultInjector`` — a primary that drops/slows ~``fault_rate``
    of calls, a flapping secondary (the mode that trips breakers via the
    health score), and a mostly-healthy reserve so the escalation ladder
    has a floor. Fast breaker recovery + tight backoffs keep the replay's
    wall clock bench-sized. Returns ``(service, client, cache, injector)``;
    replay the same workload against ``build_stack`` for the clean baseline
    (same seed -> same faults, the whole point of the seeded injector)."""
    from repro.core import (
        EnhancedClient,
        GenerativeCache,
        MockLLM,
        NgramHashEmbedder,
    )
    from repro.core.tiers import HostRamTier
    from repro.core.vector_store import InMemoryVectorStore
    from repro.resilience import CircuitBreaker, FaultInjector, FaultSpec, RetryPolicy

    emb = NgramHashEmbedder()
    store = None
    if tier1_capacity:
        store = InMemoryVectorStore(
            emb.dim, capacity=capacity, eviction="lru",
            tier1=HostRamTier(emb.dim, capacity=tier1_capacity),
        )
    cache = GenerativeCache(
        emb, threshold=threshold, t_single=0.45, t_combined=1.0,
        capacity=capacity, store=store, cache_synthesized=False,
    )
    client = EnhancedClient(
        cache=cache,
        retry_policy=RetryPolicy(max_attempts=2, base_backoff_s=0.004,
                                 max_backoff_s=0.02),
        breaker_factory=lambda name: CircuitBreaker(
            name, failure_threshold=3, recovery_s=0.2
        ),
    )
    # escalation order == registration order: the FLAPPING backend is first,
    # so every miss walks into the flap schedule (down phases trip its
    # breaker, up phases close it again), fails over to a lossy primary,
    # and only then to the mostly-healthy reserve
    injector = FaultInjector(seed=seed)
    injector.schedule(
        CHAOS_BACKENDS[0],
        FaultSpec("flap", period=flap_period, message="flapping upstream"),
        FaultSpec("error", p=0.1, message="injected connection reset"),
    )
    injector.schedule(
        CHAOS_BACKENDS[1],
        FaultSpec("error", p=fault_rate, message="injected connection reset"),
        FaultSpec("latency", p=0.1, latency_s=3 * backend_latency_s),
    )
    injector.schedule(
        CHAOS_BACKENDS[2],
        FaultSpec("error", p=0.05, message="injected connection reset"),
    )
    for name in CHAOS_BACKENDS:
        client.register_backend(
            injector.wrap_backend(MockLLM(name, latency_s=backend_latency_s))
        )
    # lookup batching stays at build_stack's 16, but dispatch groups are
    # capped small: each group is ONE failover walk, and the chaos replay
    # wants many walks through the fault schedule, not a handful of big
    # coalesced batches that dodge the injector
    service = CacheService(client, max_batch=16, max_wait_ms=2.0,
                           dispatch_batch=2, max_inflight=max_inflight)
    return service, client, cache, injector


def all_backends_down(injector) -> None:
    """Rewrite every chaos backend's schedule to hard-fail from here on —
    the total-outage window the stale-serving gate replays through."""
    from repro.resilience import FaultSpec

    for name in CHAOS_BACKENDS:
        injector.schedule(name, FaultSpec("error", message="backend down"))


def _warm(service: CacheService, cache) -> None:
    """Compile the per-bucket jit variants outside the timed replay."""
    for b in (1, 2, 4, 8, 16):
        cache.lookup_batch([f"warmup probe {b} {j}" for j in range(b)])
        cache.insert_batch([f"warmup insert {b} {j}" for j in range(b)], ["w"] * b)
    service.submit(CacheRequest("warmup roundtrip request")).result()


def prewarm(cache, corpus: Sequence[str], *, churn: int) -> None:
    """Put the replay in a long-running deployment's steady state: the
    canonical corpus is already cached (these queries have been answered
    before), then ``churn`` filler inserts push every corpus entry out of
    tier 0 into the host tier. The replay's first ask of each rank is then
    a genuine tier-1 promote (``X-Cache: tier1``), repeats are tier-0
    hits, and only below-threshold paraphrases / non-synthesizable
    combines reach the backend. Also compiles the eviction->demote and
    tier-1 consult kernels outside the timed window."""
    answers = [f"warm answer for: {q}" for q in corpus]
    for i in range(0, len(corpus), 16):
        cache.insert_batch(list(corpus[i:i + 16]), answers[i:i + 16])
    fillers = [f"churn filler {i}" for i in range(churn)]
    for i in range(0, churn, 16):
        chunk = fillers[i:i + 16]
        cache.insert_batch(chunk, ["x"] * len(chunk))
    # the store is full now, so inserts take the evict->demote program — a
    # DIFFERENT jit variant per padded batch shape than the fill-phase
    # inserts _warm compiled. Compile each one here (plus the tier-1
    # consult variants), or the first mid-replay backfill pays a ~400 ms
    # compile while holding the cache lock, stalling every in-flight hit.
    for b in (1, 2, 4, 8, 16):
        # mixed ttls: the replay's backfills carry per-entry TTLs, which is
        # its own jit variant of the scatter
        cache.insert_batch(
            [f"churn filler evict {b} {j}" for j in range(b)], ["x"] * b,
            ttls=[60.0 if j % 2 == 0 else None for j in range(b)],
        )
        cache.lookup_batch([f"absent tier1 probe {b} {j}" for j in range(b)])
    # compile the tier-1 promote path (the probe promotes rank 0, which
    # the replay's first ask would have promoted within milliseconds anyway)
    cache.lookup_batch([corpus[0]])


# -- chaos mode -----------------------------------------------------------------


def _all_down_window(
    service: CacheService, cache, client, injector, *, n: int = 24, ttl_s: float = 0.05
) -> Dict[str, Any]:
    """Total-outage replay: cache 2n fresh answers (half on a tiny TTL),
    wait past expiry, kill every backend, then ask everything back plus a
    slice of never-cached prompts. The gate: valid entries still answer
    ``hit``, expired ones answer ``stale`` byte-identically (the ladder's
    stale-if-error rung), and only the never-cached slice surfaces the
    typed 503. Runs both in-process and through a live gateway so the
    ``X-Cache: stale|hit`` header contract is what's actually measured."""
    from repro.gateway.app import serve_in_thread
    from repro.gateway.client import GatewayClient

    # three textually DISJOINT prompt families (n-gram sim across families is
    # far below t_single), so an expired prompt can only be answered by its
    # own stale entry — never by a live hit or a generative synthesis from
    # the valid family, which would mask the ladder rung under test
    stale_prompts = [f"obsolete telemetry shard {i} checksum {i * 31 + 7}" for i in range(n)]
    fresh_prompts = [f"healthy inventory ledger {i} balance {i * 17 + 3}" for i in range(n)]
    novel_prompts = [f"uncharted frontier question {i} nobody ever asked" for i in range(max(2, n // 4))]
    # valid entries FIRST: inserting them after the TTL'd batch can land past
    # the short TTL, and the evictor reclaims expired slots before live ones —
    # it would overwrite the very stale inventory this window serves
    cache.insert_batch(fresh_prompts, [f"valid answer {i}" for i in range(n)])
    cache.insert_batch(
        stale_prompts, [f"expired answer {i}" for i in range(n)], ttls=[ttl_s] * n
    )
    time.sleep(2.5 * ttl_s)  # the TTL'd half is now past expiry
    all_backends_down(injector)

    win: Dict[str, Any] = {
        "n_expired": n, "n_valid": n, "n_novel": len(novel_prompts),
        "stale": 0, "hit": 0, "unavailable": 0, "other": 0,
        "stale_byte_parity": True,
    }
    for i, p in enumerate(stale_prompts):
        try:
            resp = service.submit(CacheRequest(p, allow_stale=True)).result(timeout=30)
        except AllBackendsFailed:
            win["unavailable"] += 1
            continue
        if resp.cache_status == "stale":
            win["stale"] += 1
            if resp.text != f"expired answer {i}":
                win["stale_byte_parity"] = False
        else:
            win["other"] += 1
    for p in fresh_prompts:
        resp = service.submit(CacheRequest(p, allow_stale=True)).result(timeout=30)
        win["hit" if resp.from_cache and resp.cache_status != "stale" else "other"] += 1
    for p in novel_prompts:
        try:
            service.submit(CacheRequest(p, allow_stale=True)).result(timeout=30)
            win["other"] += 1
        except AllBackendsFailed:
            win["unavailable"] += 1

    # the same contract over the wire: X-Cache is what clients dispatch on
    runner = serve_in_thread(service)
    http: Dict[str, Any] = {"stale": 0, "hit": 0, "503": 0, "other": 0}
    try:
        with GatewayClient("127.0.0.1", runner.gateway.port, timeout=30.0) as gw:
            probes = (
                [(p, "stale") for p in stale_prompts[: n // 2]]
                + [(p, "hit") for p in fresh_prompts[: n // 2]]
                + [(p, "503") for p in novel_prompts[:2]]
            )
            for p, want in probes:
                reply = gw.request(
                    "POST", "/v1/completions",
                    {"prompt": p, "allow_stale": True, "max_tokens": 64},
                )
                if reply.status == 503:
                    http["503"] += 1
                elif reply.status == 200:
                    xc = reply.headers.get("x-cache", "")
                    http[xc if xc in ("stale", "hit") else "other"] += 1
                else:
                    http["other"] += 1
                if want == "503":
                    http.setdefault("novel_got_retry_after", True)
                    if reply.status != 503 or not reply.headers.get("retry-after"):
                        http["novel_got_retry_after"] = False
    finally:
        runner.stop()
    win["http"] = http
    win["stale_serve_rate"] = win["stale"] / max(1, win["n_expired"])
    return win


def run_chaos_replay(
    cfg: TrafficConfig,
    *,
    backend_latency_s: float = 0.04,
    time_scale: float = 1.0,
    fault_rate: float = 0.3,
    stale_fraction: float = 0.9,
    seed: int = 0,
) -> Dict[str, Any]:
    """The fault-schedule replay mode: the SAME seeded workload as the
    clean replay, driven through ``build_chaos_stack`` while ~``fault_rate``
    of backend calls fault and one backend flaps, then an all-backends-down
    window that must keep answering from the cache (``hit``/``stale``).
    Deterministic end to end: workload seed + injector seed fix which calls
    fault. Returns the chaos section of ``BENCH_chaos.json``."""
    from dataclasses import asdict as _asdict

    workload = generate_workload(cfg)
    apply_stale_policy(workload, stale_fraction, seed=cfg.seed + 1)
    service, client, cache, injector = build_chaos_stack(
        backend_latency_s=backend_latency_s, tier1_capacity=8 * cfg.corpus_size,
        capacity=2 * cfg.corpus_size, max_inflight=256,
        fault_rate=fault_rate, seed=seed,
    )
    _warm(service, cache)
    prewarm(cache, make_corpus(cfg), churn=2 * cfg.corpus_size)
    rep = run_inprocess(service, workload, time_scale=time_scale,
                        close_service=False)
    chaos = rep.to_dict()
    # fault accounting for the CHAOS phase only — the all-down window that
    # follows injects on every call and would swamp the ~fault_rate share
    chaos_faults = injector.snapshot()
    window = _all_down_window(service, cache, client, injector)
    service.close()
    chaos["dropped_at_drain"] = rep.dropped_at_drain
    faults = injector.snapshot()
    total_calls = sum(chaos_faults["calls"].values())
    return {
        "fault_rate": fault_rate,
        "stale_fraction": stale_fraction,
        "chaos": chaos,
        "all_down_window": window,
        "faults": faults,
        "chaos_faults": chaos_faults,
        "fault_share": chaos_faults["total_injected"] / max(1, total_calls),
        "breakers": client.breaker_snapshot(),
        "retry_budget": client.retry_budget.snapshot(),
        "client_stats": _asdict(client.stats),
        "service_stats": _asdict(service.stats),
    }


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-schedule replay: faulting/flapping backends, "
                         "stale serving, and an all-backends-down window")
    ap.add_argument("--fault-rate", type=float, default=0.3)
    ap.add_argument("--mode", choices=("inprocess", "http", "both"), default="both")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--users", type=int, default=0)
    ap.add_argument("--backend-latency-ms", type=float, default=0.0)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pace-ms", type=float, default=0.0,
                    help="gateway SSE pacing between chunks (http mode)")
    ap.add_argument("--out", default="BENCH_traffic.json")
    args = ap.parse_args(argv)

    cfg = TrafficConfig(
        n_requests=args.requests or (192 if args.smoke else 512),
        n_users=args.users or (16 if args.smoke else 24),
        corpus_size=32 if args.smoke else 64,
        seed=args.seed,
    )
    backend_s = (args.backend_latency_ms or (120.0 if args.smoke else 200.0)) / 1e3
    workload = generate_workload(cfg)
    span = workload[-1].t if workload else 0.0
    print(f"workload: {len(workload)} requests / {cfg.n_users} users / "
          f"{cfg.corpus_size} canonical queries, span {span:.2f}s "
          f"(zipf_s={cfg.zipf_s}, paraphrase={cfg.paraphrase_rate}, "
          f"combine={cfg.combine_rate}, arrival={cfg.arrival})")

    out: Dict[str, Any] = {"config": asdict(cfg),
                           "backend_latency_ms": backend_s * 1e3}

    if args.chaos:
        res = run_chaos_replay(
            cfg, backend_latency_s=backend_s, time_scale=args.time_scale,
            fault_rate=args.fault_rate, seed=args.seed,
        )
        out.update(res)
        d, w = res["chaos"], res["all_down_window"]
        print(f"[chaos]     availability={d['availability']:.4f} | "
              f"fault_share={res['fault_share']:.2f} | "
              f"stale_served={d['stale_served']} "
              f"unavailable={d['backend_unavailable']} "
              f"dropped={d['dropped_at_drain']}")
        print(f"[all-down]  stale={w['stale']}/{w['n_expired']} "
              f"hit={w['hit']}/{w['n_valid']} 503={w['unavailable']} "
              f"byte_parity={w['stale_byte_parity']} http={w['http']}")
        path = args.out if args.out != "BENCH_traffic.json" else "BENCH_chaos.json"
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"-> {path}")
        return out

    if args.mode in ("inprocess", "both"):
        service, client, cache = build_stack(
            backend_latency_s=backend_s, tier1_capacity=8 * cfg.corpus_size,
            capacity=2 * cfg.corpus_size, max_inflight=256,
        )
        _warm(service, cache)
        prewarm(cache, make_corpus(cfg), churn=2 * cfg.corpus_size)
        rep = run_inprocess(service, workload, time_scale=args.time_scale)
        out["inprocess"] = rep.to_dict()
        d = out["inprocess"]
        print(f"[inprocess] {d['throughput_rps']:.0f} req/s | hit p50 "
              f"{d['hit_p50_ms']:.1f} ms vs miss p50 {d['miss_p50_ms']:.1f} ms "
              f"({d['hit_vs_miss_p50_ratio']:.1f}x) | shed={d['shed']} "
              f"expired={d['expired']} dropped={d['dropped_at_drain']}")

    if args.mode in ("http", "both"):
        from repro.gateway.app import serve_in_thread

        service, client, cache = build_stack(
            backend_latency_s=backend_s, tier1_capacity=8 * cfg.corpus_size,
            capacity=2 * cfg.corpus_size, max_inflight=256,
        )
        _warm(service, cache)
        prewarm(cache, make_corpus(cfg), churn=2 * cfg.corpus_size)
        runner = serve_in_thread(service, pace_ms=args.pace_ms, own_service=True)
        try:
            rep = run_http(
                "127.0.0.1", runner.gateway.port, workload,
                time_scale=args.time_scale,
            )
        finally:
            rep.drain_clean = runner.stop() and rep.drain_clean
        out["http"] = rep.to_dict()
        out["http"]["drain_clean"] = rep.drain_clean
        d = out["http"]
        print(f"[http]      {d['throughput_rps']:.0f} req/s | hit p50 "
              f"{d['hit_p50_ms']:.1f} ms vs miss p50 {d['miss_p50_ms']:.1f} ms "
              f"({d['hit_vs_miss_p50_ratio']:.1f}x) | shed={d['shed']} "
              f"expired={d['expired']} dropped={d['dropped_at_drain']}")

    # headline gate numbers: in-process when available, else http
    head = out.get("inprocess") or out.get("http")
    out["hit_p50_ms"] = head["hit_p50_ms"]
    out["miss_p50_ms"] = head["miss_p50_ms"]
    out["hit_vs_miss_p50_ratio"] = head["hit_vs_miss_p50_ratio"]
    out["dropped_at_drain"] = max(
        out[m]["dropped_at_drain"] for m in ("inprocess", "http") if m in out
    )

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"-> {args.out}")
    return out


if __name__ == "__main__":
    main()
