"""The HTTP gateway: OpenAI-compatible serving surface over ``CacheService``.

``Gateway`` binds the stdlib HTTP layer (``repro.gateway.http``) to the
async cache service: POST bodies parse into ``CacheRequest``s, responses
come back as OpenAI ``chat.completion`` / ``text_completion`` objects with
the cache-status header contract (``X-Cache: hit|generative|tier1|miss``,
``X-Cache-Similarity``, ``X-Cache-Level``, ``X-Service-Latency-Ms``), and
``"stream": true`` serves Server-Sent Events for hits AND misses through
``CacheService.astream`` — a cached answer replays token-by-token with a
pacing knob (``pace_ms``) so a client watching the stream can't tell a
millisecond replay from a live generation.

Routes::

    GET  /healthz              liveness + drain state
    GET  /v1/cache/stats       service/cache/gateway counters (JSON)
    POST /v1/chat/completions  OpenAI chat API (messages array)
    POST /v1/completions       OpenAI completions API (prompt string)

Shutdown is a graceful drain (``aclose``): the listener stops accepting,
in-flight requests finish and their futures resolve, and only then — when
the gateway owns the service (``own_service=True``, the ``launch/serve
--http`` wiring) — does ``CacheService.close()`` run.

``serve_in_thread`` runs a gateway on a private event loop in a daemon
thread — the harness the HTTP traffic driver, the tests, and the example
all share.
"""
from __future__ import annotations

import asyncio
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.core.request import CacheRequest, CacheResponse
from repro.gateway import errors as gwerrors
from repro.gateway.http import GatewayHttpServer, HttpRequest, Response
from repro.gateway.protocol import (
    SSE_DONE,
    cache_headers,
    completion_body,
    parse_chat_request,
    parse_completion_request,
    sse_event,
    stream_chunk_body,
)
from repro.serving.service import CacheService


class GatewayStats:
    """Request-class counters for ``/v1/cache/stats`` — one bucket per
    ``X-Cache`` value plus the error statuses. Thread-safe: handler
    coroutines and stats readers may sit on different loops/threads."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._by_class: Dict[str, int] = {}  # guarded-by: _lock
        self._by_status: Dict[int, int] = {}  # guarded-by: _lock
        self._streamed = 0  # guarded-by: _lock

    def record(self, status: int, cache_class: Optional[str], streamed: bool) -> None:
        with self._lock:
            self._by_status[status] = self._by_status.get(status, 0) + 1
            if cache_class is not None:
                self._by_class[cache_class] = self._by_class.get(cache_class, 0) + 1
            if streamed:
                self._streamed += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            served = sum(self._by_class.values())
            return {
                "by_cache_class": dict(self._by_class),
                "by_status": {str(k): v for k, v in self._by_status.items()},
                "streamed": self._streamed,
                "hit_fraction": (
                    sum(v for k, v in self._by_class.items() if k != "miss") / served
                    if served
                    else 0.0
                ),
            }


class Gateway:
    def __init__(
        self,
        service: CacheService,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        pace_ms: float = 0.0,
        chunk_tokens: int = 1,
        own_service: bool = False,
    ):
        self.service = service
        self.http = GatewayHttpServer(self.handle, host=host, port=port)
        self.pace_s = pace_ms / 1e3
        self.chunk_tokens = max(1, chunk_tokens)
        self.own_service = own_service
        self.stats = GatewayStats()

    # -- lifecycle -------------------------------------------------------------

    async def start(self) -> Tuple[str, int]:
        return await self.http.start()

    async def aclose(self, timeout: float = 10.0) -> bool:
        """Graceful drain: stop accepting, flush in-flight requests (their
        service futures resolve before the HTTP response finishes), then —
        if the gateway owns it — close the service so its schedulers drain
        every remaining accepted future."""
        clean = await self.http.drain(timeout=timeout)
        if self.own_service:
            self.service.close()
        return clean

    @property
    def port(self) -> int:
        return self.http.port

    # -- routing ---------------------------------------------------------------

    async def handle(self, request: HttpRequest) -> Response:
        route = (request.method, request.path)
        if route == ("GET", "/healthz"):
            return self._healthz()
        if route == ("GET", "/v1/cache/stats"):
            return self._cache_stats()
        if route == ("POST", "/v1/chat/completions"):
            return await self._completions(request, chat=True)
        if route == ("POST", "/v1/completions"):
            return await self._completions(request, chat=False)
        if request.path in ("/healthz", "/v1/cache/stats"):
            status, headers, body = gwerrors.method_not_allowed(request.method, "GET")
        elif request.path in ("/v1/chat/completions", "/v1/completions"):
            status, headers, body = gwerrors.method_not_allowed(request.method, "POST")
        else:
            status, headers, body = gwerrors.not_found(request.path)
        self.stats.record(status, None, False)
        return Response(status, headers, body)

    # -- handlers --------------------------------------------------------------

    def _healthz(self) -> Response:
        breakers = self.service.client.breaker_snapshot()
        # the gateway stays "ok" while ANY backend is closed/half-open; all
        # breakers open means new misses ride the stale ladder or get 503s
        degraded = bool(breakers) and all(
            b["state"] == "open" for b in breakers.values()
        )
        status = "draining" if self.http.draining else ("degraded" if degraded else "ok")
        payload = {
            "status": status,
            "inflight_http": self.http.inflight,
            "inflight_service": self.service.inflight,
            "requests_served": self.http.requests_served,
            "breakers": breakers,
        }
        self.stats.record(200, None, False)
        return Response.json_response(payload)

    def _cache_stats(self) -> Response:
        svc, client = self.service.stats, self.service.client.stats
        lookup, dispatch = self.service.scheduler_stats
        payload = {
            "gateway": self.stats.snapshot(),
            "service": {
                "submitted": svc.submitted,
                "hits": svc.hits,
                "generated": svc.generated,
                "expired": svc.expired,
                "rejected": svc.rejected,
                "deduped": svc.deduped,
                "stale_served": svc.stale_served,
                "backend_unavailable": svc.backend_unavailable,
                "inflight": self.service.inflight,
            },
            "client": {
                "requests": client.requests,
                "cache_hits": client.cache_hits,
                "llm_calls": client.llm_calls,
                "llm_errors": client.llm_errors,
                "retries": client.retries,
                "breaker_trips": client.breaker_trips,
                "breaker_open_skips": client.breaker_open_skips,
                "all_backends_failed": client.all_backends_failed,
                "total_cost_usd": client.total_cost_usd,
            },
            "breakers": self.service.client.breaker_snapshot(),
            "retry_budget": self.service.client.retry_budget.snapshot(),
            "schedulers": {
                "lookup_avg_batch": lookup.avg_batch if lookup else 0.0,
                "dispatch_avg_batch": dispatch.avg_batch if dispatch else 0.0,
            },
        }
        self.stats.record(200, None, False)
        return Response.json_response(payload)

    async def _completions(self, request: HttpRequest, *, chat: bool) -> Response:
        # ProtocolError (malformed JSON / bad fields) propagates to the HTTP
        # layer's dispatcher, which maps it to a 400 — but record it here so
        # the stats see parse failures too
        try:
            creq = (parse_chat_request if chat else parse_completion_request)(
                request.json()
            )
        except Exception as e:  # noqa: BLE001 — re-raised after recording
            status, _, _ = gwerrors.map_exception(e)
            self.stats.record(status, None, False)
            raise
        if creq.stream:
            return await self._stream_response(creq, chat=chat)
        try:
            resp = await self.service.asubmit(creq)
        except Exception as e:  # noqa: BLE001 — typed shed/closed mapping
            status, headers, body = gwerrors.map_exception(e)
            self.stats.record(status, None, False)
            return Response(status, headers, body)
        if resp.expired:
            status, headers, body = gwerrors.map_expired_response(resp)
            self.stats.record(status, None, False)
            return Response(status, headers, body)
        self.stats.record(200, resp.cache_status, False)
        return Response.json_response(
            completion_body(resp, creq, chat=chat), headers=cache_headers(resp)
        )

    async def _stream_response(self, creq: CacheRequest, *, chat: bool) -> Response:
        """SSE for hits and misses alike. The stream generator is primed
        BEFORE headers go out: the first chunk (which already carries the
        fully resolved ``CacheResponse``) decides the cache-status headers,
        and a typed expiry becomes a clean 504 instead of a broken stream."""
        agen = self.service.astream(
            creq, pace_s=self.pace_s, chunk_tokens=self.chunk_tokens
        )
        try:
            first = await agen.__anext__()
        except StopAsyncIteration:  # astream always yields; belt and braces
            status, headers, body = gwerrors.map_exception(
                RuntimeError("empty stream")
            )
            self.stats.record(status, None, False)
            return Response(status, headers, body)
        except Exception as e:  # noqa: BLE001 — shed/closed before any byte
            status, headers, body = gwerrors.map_exception(e)
            self.stats.record(status, None, False)
            return Response(status, headers, body)
        resp = first.response
        if resp.expired:
            status, headers, body = gwerrors.map_expired_response(resp)
            self.stats.record(status, None, False)
            return Response(status, headers, body)
        self.stats.record(200, resp.cache_status, True)

        async def sse(resp: CacheResponse = resp) -> Any:
            sent_any = False
            chunk = first
            while True:
                body = stream_chunk_body(
                    resp, chat=chat, text=chunk.text, first=not sent_any,
                    final=chunk.final,
                )
                sent_any = True
                yield sse_event(body)
                if chunk.final:
                    break
                try:
                    chunk = await agen.__anext__()
                except StopAsyncIteration:
                    break
            yield SSE_DONE

        headers: List[Tuple[str, str]] = [
            ("Cache-Control", "no-cache"),
            *cache_headers(resp),
        ]
        return Response(
            200, headers, content_type="text/event-stream", chunks=sse()
        )


# -- threaded runner (tests, HTTP traffic driver, examples) ---------------------


class GatewayThread:
    """A gateway serving on its own event loop in a daemon thread.

    ``start()`` blocks until the port is bound and returns (host, port);
    ``stop()`` runs the graceful drain on the gateway's loop and joins the
    thread. The loop is private to this thread, so the caller's asyncio
    state (if any) is never touched."""

    def __init__(self, gateway: Gateway):
        self.gateway = gateway
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._ready = threading.Event()
        self._stopped = threading.Event()
        self._addr: Optional[Tuple[str, int]] = None
        self._drained_clean: Optional[bool] = None

    def start(self, timeout: float = 10.0) -> Tuple[str, int]:
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        if not self._ready.wait(timeout=timeout):
            raise RuntimeError("gateway failed to start in time")
        assert self._addr is not None
        return self._addr

    def _run(self) -> None:
        asyncio.run(self._main())

    async def _main(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._addr = await self.gateway.start()
        self._ready.set()
        # serve until stop() flips the event from another thread
        while not self._stopped.is_set():
            await asyncio.sleep(0.02)
        self._drained_clean = await self.gateway.aclose()

    def stop(self, timeout: float = 15.0) -> bool:
        """Drain and shut down; returns True when every in-flight request
        finished before the drain timeout."""
        self._stopped.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        return bool(self._drained_clean)

    def __enter__(self) -> "GatewayThread":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


def serve_in_thread(
    service: CacheService,
    *,
    host: str = "127.0.0.1",
    port: int = 0,
    pace_ms: float = 0.0,
    own_service: bool = False,
) -> GatewayThread:
    """Convenience: build a ``Gateway`` and serve it from a daemon thread."""
    gw = Gateway(
        service, host=host, port=port, pace_ms=pace_ms, own_service=own_service
    )
    runner = GatewayThread(gw)
    runner.start()
    return runner
