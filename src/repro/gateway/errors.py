"""Typed service failure -> stable HTTP status + JSON error body.

Every way the serving stack can refuse or abandon a request has ONE
documented HTTP shape, so load-balancers and client retry loops can act on
the status code without parsing bodies:

    ==========================  ======  ===========================
    failure                     status  notes
    ==========================  ======  ===========================
    malformed request           400     ``ProtocolError`` (parse layer)
    unknown route               404
    wrong method on a route     405     ``Allow`` header
    ``AdmissionRejected``       429     ``Retry-After`` header (shed)
    ``AllBackendsFailed``       503     ``Retry-After``; structured causes
    ``ServiceClosed``           503     draining/closed
    ``DEADLINE_EXCEEDED`` resp  504     typed response, not an exception
    anything else               500     repr'd, never a raw traceback
    ==========================  ======  ===========================
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core.request import CacheResponse
from repro.gateway.protocol import ProtocolError, error_body
from repro.resilience.errors import AllBackendsFailed
from repro.serving.coalescer import AdmissionRejected, ServiceClosed

# (status, headers, body) — what the HTTP layer writes
ErrorTriple = Tuple[int, List[Tuple[str, str]], bytes]

RETRY_AFTER_S = 1  # advisory backoff for shed load; the budget drains in ms


def map_exception(exc: BaseException) -> ErrorTriple:
    """Map a request-handling exception to its wire shape."""
    if isinstance(exc, ProtocolError):
        return (
            exc.status,
            [],
            error_body(str(exc), exc.err_type, exc.code),
        )
    if isinstance(exc, AdmissionRejected):
        return (
            429,
            [("Retry-After", str(RETRY_AFTER_S))],
            error_body(
                f"server overloaded: {exc}", "rate_limit_error", "admission_rejected"
            ),
        )
    if isinstance(exc, AllBackendsFailed):
        # the degradation ladder's floor: every backend open/down AND no
        # stale entry could answer — a retryable outage, not a client error
        return (
            503,
            [("Retry-After", str(RETRY_AFTER_S))],
            error_body(
                f"no backend available: {exc}",
                "service_unavailable",
                "backend_unavailable",
            ),
        )
    if isinstance(exc, ServiceClosed):
        return (
            503,
            [],
            error_body(
                f"service unavailable: {exc}", "service_unavailable", "service_closed"
            ),
        )
    return (
        500,
        [],
        error_body(f"internal error: {exc!r}", "internal_error", None),
    )


def map_expired_response(resp: CacheResponse) -> ErrorTriple:
    """A miss whose deadline passed resolves typed (no backend call / a
    canceled mid-flight generation) — surface it as a gateway timeout."""
    return (
        504,
        [("X-Request-Id", str(resp.request_id))],
        error_body(
            f"deadline exceeded after {resp.latency_s * 1e3:.1f} ms in service",
            "timeout_error",
            "deadline_exceeded",
        ),
    )


def not_found(path: str) -> ErrorTriple:
    return 404, [], error_body(f"no route for {path}", "invalid_request_error", "not_found")


def method_not_allowed(method: str, allow: str) -> ErrorTriple:
    return (
        405,
        [("Allow", allow)],
        error_body(f"{method} not allowed here", "invalid_request_error", "method_not_allowed"),
    )


def draining_unavailable(reason: Optional[str] = None) -> ErrorTriple:
    return (
        503,
        [("Retry-After", str(RETRY_AFTER_S))],
        error_body(reason or "gateway is draining", "service_unavailable", "draining"),
    )
