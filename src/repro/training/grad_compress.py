"""Gradient compression for the cross-pod (DCN) axis.

int8 absmax compression with error feedback: before the pod-axis all-reduce,
gradients are quantized to int8 (per last-axis row scales); the quantization
residual is carried into the next step's gradient (error feedback keeps the
scheme unbiased over time). ICI (in-pod) reductions stay full precision —
DCN is ~10x thinner than ICI, so that is where the 4x byte shrink matters.

Used by train_step when `compress_dcn=True` and the mesh has a "pod" axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compress_with_error_feedback(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Returns (compressed-then-decompressed grads, new error state).

    The round-trip models what the DCN all-reduce transports; XLA sees int8
    tensors at the reduce boundary when this wraps the pod-axis psum.
    """

    def one(g, e):
        g = g.astype(F32) + e
        q, s = compress(g)
        deq = decompress(q, s)
        return deq.astype(g.dtype), (g - deq)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error) if error is not None else [
        jnp.zeros(g.shape, F32) for g in flat_g
    ]
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten([o[1] for o in out])


def init_error_state(grads_template: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads_template)
