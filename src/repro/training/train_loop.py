"""Training step factory: grad accumulation + remat + AdamW(+8bit) + metrics.

``make_train_step(cfg)`` builds the jittable (state, batch) -> (state,
metrics) function the dry-run lowers and train.py drives. Grad accumulation
scans over microbatches (bounding live activations so 27B..671B configs fit
HBM with full remat); gradients accumulate in f32 except under the 8-bit
optimizer where bf16 accumulation keeps the 671B config inside 16 GB/chip
(recorded approximation, DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.training import schedule as sched
from repro.training.optimizer import (
    AdamWConfig,
    adamw_update,
    init_opt_state,
    opt_state_specs,
)

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array  # int32 scalar


def init_train_state(cfg, key) -> Tuple[TrainState, Dict[str, Any]]:
    params, param_specs = T.init_params(cfg, key)
    opt_cfg = AdamWConfig(quantized=cfg.optimizer == "adamw8bit")
    opt_state = init_opt_state(params, opt_cfg)
    specs = TrainState(
        params=param_specs,
        opt_state=opt_state_specs(
            param_specs, params, opt_cfg, pod_extend=getattr(cfg, "opt_pod_sharded", False)
        ),
        step=(),
    )
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32)), specs


def abstract_train_state(cfg, key=None) -> Tuple[TrainState, Dict[str, Any]]:
    """Shape-only TrainState (no allocation) for dry-run lowering.

    Specs are pure-python (trace-independent), so they are captured via a
    side channel while eval_shape abstracts the arrays.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    captured = {}

    def build(k):
        state, specs = init_train_state(cfg, k)
        captured["specs"] = specs
        return state

    shapes = jax.eval_shape(build, key)
    return shapes, captured["specs"]


def _microbatch_grads(cfg, params, batch, accum_dtype):
    """Scan microbatches, accumulating grads + metrics."""
    accum = max(cfg.grad_accum, 1)
    tokens = batch["tokens"]
    gb = tokens.shape[0]
    assert gb % accum == 0, (gb, accum)
    mb = gb // accum

    def reshape(t):
        return t.reshape(accum, mb, *t.shape[1:])

    mb_batches = jax.tree.map(reshape, batch)

    def loss_of(p, b):
        loss, metrics = T.loss_fn(p, cfg, b)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    if accum == 1:
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, loss, metrics

    def body(carry, mb_batch):
        g_acc, loss_acc = carry
        (loss, metrics), g = grad_fn(params, mb_batch)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), g_acc, g)
        return (g_acc, loss_acc + loss), metrics

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (grads, loss_sum), metrics = jax.lax.scan(body, (g0, jnp.zeros((), F32)), mb_batches)
    grads = jax.tree.map(lambda g: (g / accum).astype(accum_dtype), grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return grads, loss_sum / accum, metrics


def make_train_step(
    cfg,
    *,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    schedule: Callable = sched.warmup_cosine,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    opt_cfg = AdamWConfig(quantized=cfg.optimizer == "adamw8bit")
    accum_dtype = jnp.bfloat16 if opt_cfg.quantized else F32

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        grads, loss, metrics = _microbatch_grads(cfg, state.params, batch, accum_dtype)
        lr = schedule(state.step, peak_lr=peak_lr, warmup_steps=warmup_steps,
                      total_steps=total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt_state, state.step, lr, opt_cfg
        )
        metrics = dict(metrics, loss=loss, lr=lr, **opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = T.loss_fn(params, cfg, batch)
        return dict(metrics, loss=loss)

    return eval_step
