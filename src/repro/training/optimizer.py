"""Optimizers: AdamW and AdamW with 8-bit block-quantized moments.

The 8-bit variant (blockwise absmax quantization of m and v, per last-axis
rows) is what lets deepseek-v3-671b train on a 256-chip pod: moments drop
from 8 bytes/param (f32 m+v) to 2 bytes/param + tiny scales. Moment state is
sharded exactly like its parameter (FSDP over `data` + TP over `model`), so
the optimizer update is fully local after the grad reduce-scatter.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantized: bool = False  # 8-bit moments


# ---------------------------------------------------------------------------
# 8-bit blockwise quantization (per last-axis row absmax)
# ---------------------------------------------------------------------------


def _quantize(x: jax.Array) -> Dict[str, jax.Array]:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(F32)}


def _dequantize(s: Dict[str, jax.Array]) -> jax.Array:
    return s["q"].astype(F32) * s["scale"]


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def init_opt_state(params, cfg: AdamWConfig):
    def one(p):
        # m and v must be DISTINCT buffers: sharing one zeros array breaks
        # buffer donation (same buffer donated twice)
        if cfg.quantized and p.ndim >= 1 and p.shape[-1] >= 8:
            return {
                "m": _quantize(jnp.zeros(p.shape, F32)),
                "v": _quantize(jnp.zeros(p.shape, F32)),
            }
        return {"m": jnp.zeros(p.shape, F32), "v": jnp.zeros(p.shape, F32)}

    return jax.tree.map(one, params)


def opt_state_specs(param_specs, params, cfg: AdamWConfig, pod_extend: bool = False):
    """Moment sharding mirrors the parameter sharding (scales drop last axis).

    pod_extend=True additionally shards moments over the `pod` (DCN) axis —
    cross-pod ZeRO-1: optimizer state is touched once per step, so the DCN
    gather amortizes, and the per-chip moment footprint halves on 2 pods.
    """

    def one(spec, p):
        spec = tuple(spec) if spec is not None else (None,) * p.ndim
        if pod_extend:
            spec = tuple(
                ("pod", "data") if e == "data" else e for e in spec
            )
        if cfg.quantized and p.ndim >= 1 and p.shape[-1] >= 8:
            scale_spec = spec[:-1] + (None,)
            return {"m": {"q": spec, "scale": scale_spec}, "v": {"q": spec, "scale": scale_spec}}
        return {"m": spec, "v": spec}

    return jax.tree.map(
        one, param_specs, params, is_leaf=lambda s: isinstance(s, tuple) or s is None
    )


def global_norm(tree) -> jax.Array:
    def leaf_normsq(l):
        if l.ndim >= 3 and l.shape[0] >= 4:
            # scan the reduction over the stack axis: a full-leaf f32 upcast of
            # a 100B-param stacked tensor is a multi-GiB materialization
            def body(acc, sl):
                return acc + jnp.sum(jnp.square(sl.astype(F32))), None

            acc, _ = jax.lax.scan(body, jnp.zeros((), F32), l)
            return acc
        return jnp.sum(jnp.square(l.astype(F32)))

    return jnp.sqrt(sum(leaf_normsq(l) for l in jax.tree.leaves(tree)))


def adamw_update(
    params, grads, opt_state, step: jax.Array, lr: jax.Array, cfg: AdamWConfig
) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) if cfg.clip_norm else 1.0
    t = step.astype(F32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def one(p, g, s):
        g = g.astype(F32) * scale
        quant = isinstance(s["m"], dict)
        m = _dequantize(s["m"]) if quant else s["m"]
        v = _dequantize(s["v"]) if quant else s["v"]
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/bias vectors
            update = update + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * update).astype(p.dtype)
        new_s = (
            {"m": _quantize(m), "v": _quantize(v)} if quant else {"m": m, "v": v}
        )
        return new_p, new_s

    def one_leaf(p, g, s):
        # layer-stacked arrays scan the update over the stack axis so the
        # (dequantized-f32) working set is one layer slice, not the whole
        # 100B+-param leaf — without this, deepseek's optimizer transients
        # alone exceed HBM.
        if p.ndim >= 3 and p.shape[0] >= 4:
            def body(_, xs):
                return None, one(*xs)

            _, (new_p, new_s) = jax.lax.scan(body, None, (p, g, s))
            return new_p, new_s
        return one(p, g, s)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(opt_state)
    out = [one_leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = treedef.unflatten([o[1] for o in out])
    return new_params, new_state, {"grad_norm": gnorm}
