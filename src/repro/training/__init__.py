from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state  # noqa: F401
from repro.training.train_loop import (  # noqa: F401
    TrainState,
    abstract_train_state,
    init_train_state,
    make_eval_step,
    make_train_step,
)
