"""CLI: ``python -m repro.analysis [paths] [--baseline FILE]``.

Exit status is 0 when every finding is baselined or suppressed, 1 when
new findings exist, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import os
import sys
from collections import Counter

from repro.analysis import CHECKERS, run_checks
from repro.analysis.core import Baseline


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas-aware static analysis for this repo.",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=None,
        help="files/directories to scan (default: src/repro)",
    )
    parser.add_argument("--baseline", help="baseline file of grandfathered findings")
    parser.add_argument(
        "--write-baseline",
        metavar="FILE",
        help="write current findings as the new baseline and exit 0",
    )
    parser.add_argument(
        "--root", default=".", help="repo root findings are reported relative to"
    )
    parser.add_argument("--list-codes", action="store_true", help="list checkers and exit")
    args = parser.parse_args(argv)

    if args.list_codes:
        import repro.analysis.checkers  # noqa: F401

        for name in sorted(CHECKERS):
            print(name)
        return 0

    root = os.path.abspath(args.root)
    paths = args.paths or [os.path.join(root, "src", "repro")]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    findings = run_checks(paths, root)

    if args.write_baseline:
        Baseline.write(args.write_baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    baseline = Baseline.load(args.baseline) if args.baseline else Baseline(set())
    new, grandfathered, stale = baseline.split(findings)

    for f in new:
        print(f.render())
    counts = Counter(f.code for f in new)
    summary = ", ".join(f"{code}={n}" for code, n in sorted(counts.items())) or "none"
    print(
        f"repro.analysis: {len(new)} new finding(s) [{summary}], "
        f"{len(grandfathered)} baselined, {len(stale)} stale baseline entr"
        f"{'y' if len(stale) == 1 else 'ies'}"
    )
    for key in stale:
        print(f"  stale baseline entry (fixed? remove it): {key}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
