"""repro.analysis — JAX/Pallas-aware static analysis for this codebase.

The repo's performance story rests on invariants no generic linter knows
about: the hot read path must stay one fused device dispatch with zero
host hops, jitted call sites must not retrace per request, the serving
layer's hand-maintained locks must actually cover the state they claim
to, donated device buffers must never be touched after donation, and the
int32 logical clocks must rebase before they saturate. Each checker here
encodes one of those invariants over the stdlib ``ast`` (no third-party
dependencies), seeded with an interprocedural call graph so a host sync
three calls below a ``jax.jit`` region is still caught.

Run it as ``python -m repro.analysis [--baseline analysis_baseline.txt]``.
Findings print as ``path:line: CODE message``. Grandfathered findings live
in the committed baseline (keyed without line numbers, so they survive
drift); new code suppresses an intentional finding inline with
``# repro: noqa[CODE]`` plus a short justification.

Codes:
  RA101  host sync inside a jit/pallas-reachable function
  RA201  retrace hazard at a jit creation/call site
  RA202  Python branch on a traced value
  RA301  guarded attribute accessed without its lock
  RA401  donated buffer referenced after donation
  RA501  int32 monotonic counter incremented without a rebase guard
  RA502  float32 narrowing of an absolute timestamp
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.analysis.core import Baseline, Finding, SourceModule, collect_modules

CHECKERS: Dict[str, Callable] = {}


def register(name: str) -> Callable:
    def deco(fn: Callable) -> Callable:
        CHECKERS[name] = fn
        return fn

    return deco


def run_checks(paths: List[str], root: str) -> List[Finding]:
    """Parse ``paths`` (files or directories), run every registered checker,
    and return suppression-filtered findings sorted by location."""
    from repro.analysis.project import ProjectIndex
    import repro.analysis.checkers  # noqa: F401 — registers the checkers

    modules = collect_modules(paths, root)
    project = ProjectIndex(modules)
    findings: List[Finding] = []
    for checker in CHECKERS.values():
        findings.extend(checker(project))
    by_rel = {m.rel: m for m in modules}
    kept = [
        f
        for f in set(findings)
        if not by_rel[f.path].suppressed(f.line, f.code)
    ]
    return sorted(kept, key=lambda f: (f.path, f.line, f.code, f.message))


__all__ = ["Baseline", "Finding", "SourceModule", "CHECKERS", "register", "run_checks"]
