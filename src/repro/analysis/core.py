"""Framework plumbing: findings, suppressions, baselines, file walking."""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set

NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Z0-9,\s\*]+)\]")
GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
HOLDS_RE = re.compile(r"#\s*repro:\s*holds\[([A-Za-z_]\w*)\]")


@dataclass(frozen=True)
class Finding:
    path: str  # repo-relative posix path
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    @property
    def baseline_key(self) -> str:
        # Line numbers are deliberately excluded so baselined findings
        # survive unrelated edits above them.
        return f"{self.path}: {self.code} {self.message}"


class SourceModule:
    """One parsed source file plus its comment/suppression side tables."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=rel)
        self.lines = source.splitlines()
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(source).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:
            pass
        self.noqa: Dict[int, Set[str]] = {}
        for ln, comment in self.comments.items():
            m = NOQA_RE.search(comment)
            if m:
                self.noqa[ln] = {c.strip() for c in m.group(1).split(",") if c.strip()}
        # Parents let checkers walk outward (enclosing statement, with-blocks,
        # loops) without re-deriving scope every time.
        self.parent: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node

    @property
    def modname(self) -> Optional[str]:
        """Dotted module name for files under ``src/`` (``None`` otherwise)."""
        parts = self.rel.split("/")
        if "src" in parts:
            parts = parts[parts.index("src") + 1 :]
        if not parts or not parts[-1].endswith(".py"):
            return None
        parts[-1] = parts[-1][: -len(".py")]
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts) if parts else None

    def suppressed(self, line: int, code: str) -> bool:
        codes = self.noqa.get(line)
        if codes is None:
            # Multi-line statements: honor a noqa on the first line of the
            # enclosing statement too.
            return False
        return code in codes or "*" in codes

    def stmt_of(self, node: ast.AST) -> ast.stmt:
        cur = node
        while not isinstance(cur, ast.stmt):
            cur = self.parent[cur]
        return cur

    def enclosing(self, node: ast.AST, kinds) -> List[ast.AST]:
        """All ancestors of ``node`` (inner-first) matching ``kinds``."""
        out: List[ast.AST] = []
        cur = self.parent.get(node)
        while cur is not None:
            if isinstance(cur, kinds):
                out.append(cur)
            cur = self.parent.get(cur)
        return out


def collect_modules(paths: Sequence[str], root: str) -> List[SourceModule]:
    files: List[str] = []
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
                files.extend(
                    os.path.join(dirpath, f) for f in sorted(filenames) if f.endswith(".py")
                )
        elif p.endswith(".py"):
            files.append(p)
    root = os.path.abspath(root)
    modules = []
    for f in dict.fromkeys(files):
        rel = os.path.relpath(f, root).replace(os.sep, "/")
        with open(f, "r", encoding="utf-8") as fh:
            modules.append(SourceModule(f, rel, fh.read()))
    return modules


class Baseline:
    """Grandfathered findings, keyed without line numbers."""

    def __init__(self, keys: Set[str]):
        self.keys = keys

    @classmethod
    def load(cls, path: str) -> "Baseline":
        keys: Set[str] = set()
        if os.path.exists(path):
            with open(path, "r", encoding="utf-8") as fh:
                for raw in fh:
                    line = raw.strip()
                    if line and not line.startswith("#"):
                        keys.add(line)
        return cls(keys)

    def split(self, findings: Sequence[Finding]):
        """Partition into (new, grandfathered) and report stale keys."""
        new = [f for f in findings if f.baseline_key not in self.keys]
        old = [f for f in findings if f.baseline_key in self.keys]
        stale = self.keys - {f.baseline_key for f in findings}
        return new, old, sorted(stale)

    @staticmethod
    def write(path: str, findings: Sequence[Finding]) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            fh.write("# repro.analysis baseline — grandfathered findings.\n")
            fh.write("# Keys are line-number-free: `path: CODE message`.\n")
            for key in sorted({f.baseline_key for f in findings}):
                fh.write(key + "\n")
