"""Interprocedural index: imports, jit/pallas roots, call graph, donation map.

The index is deliberately syntactic — it resolves names through ``import``
aliases, module-level defs, same-class methods, and nested defs, which is
enough to follow this repo's dispatch structure (``jax.jit`` over local
functions, ``functools.partial``-bound kernels, donated jits stashed on
``self``) without a type checker.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import SourceModule

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted(node: ast.AST) -> Optional[str]:
    """`a.b.c` text for Name/Attribute chains, None for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


@dataclass
class FuncInfo:
    module: "ModuleIndex"
    node: ast.AST  # FunctionDef / AsyncFunctionDef / Lambda
    qualname: str
    cls: Optional[str] = None

    @property
    def params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


@dataclass
class JitRoot:
    func: FuncInfo
    statics: Set[str] = field(default_factory=set)
    donate: Set[int] = field(default_factory=set)
    kind: str = "jit"  # jit | pallas | shard_map


class ModuleIndex:
    def __init__(self, src: SourceModule):
        self.src = src
        self.import_mods: Dict[str, str] = {}  # alias -> dotted module
        self.import_syms: Dict[str, Tuple[str, str]] = {}  # name -> (module, symbol)
        self.defs: Dict[str, List[FuncInfo]] = {}
        self.methods: Dict[Tuple[str, str], FuncInfo] = {}
        self.classes: Dict[str, ast.ClassDef] = {}
        self._scan()

    def _scan(self) -> None:
        for node in ast.walk(self.src.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    self.import_mods[alias.asname or alias.name.split(".")[0]] = alias.name
            elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
                for alias in node.names:
                    self.import_syms[alias.asname or alias.name] = (node.module, alias.name)
            elif isinstance(node, ast.ClassDef):
                self.classes[node.name] = node
        for node in ast.walk(self.src.tree):
            if isinstance(node, FuncNode):
                cls = self._owning_class(node)
                qual = f"{cls}.{node.name}" if cls else node.name
                info = FuncInfo(self, node, qual, cls)
                self.defs.setdefault(node.name, []).append(info)
                if cls:
                    self.methods[(cls, node.name)] = info

    def _owning_class(self, node: ast.AST) -> Optional[str]:
        cur = self.src.parent.get(node)
        if isinstance(cur, ast.ClassDef):
            return cur.name
        return None

    def alias_for(self, target_module: str) -> Optional[str]:
        for alias, mod in self.import_mods.items():
            if mod == target_module:
                return alias
        return None

    def resolve_local(self, name: str, at: ast.AST) -> Optional[FuncInfo]:
        """Resolve ``name`` to a def visible from ``at``: nested defs of the
        enclosing function chain first, then module level."""
        candidates = self.defs.get(name)
        if not candidates:
            return None
        enclosing = set(self.src.enclosing(at, FuncNode))
        for info in candidates:
            if self.src.parent.get(info.node) in enclosing:
                return info
        for info in candidates:
            if info.cls is None and isinstance(self.src.parent.get(info.node), ast.Module):
                return info
        return candidates[0]


class ProjectIndex:
    def __init__(self, modules: Sequence[SourceModule]):
        self.modules: List[ModuleIndex] = [ModuleIndex(m) for m in modules]
        self.by_name: Dict[str, ModuleIndex] = {
            m.src.modname: m for m in self.modules if m.src.modname
        }
        self.jit_roots: List[JitRoot] = []
        for m in self.modules:
            self._find_roots(m)
        self.device_funcs: Dict[int, FuncInfo] = {}
        self._propagate()

    # -- name resolution ---------------------------------------------------
    def resolve_call(self, mod: ModuleIndex, call: ast.Call) -> Optional[FuncInfo]:
        fn = call.func
        if isinstance(fn, ast.Name):
            info = mod.resolve_local(fn.id, call)
            if info is not None:
                return info
            imp = mod.import_syms.get(fn.id)
            if imp and imp[0] in self.by_name:
                other = self.by_name[imp[0]]
                for cand in other.defs.get(imp[1], []):
                    if cand.cls is None:
                        return cand
            return None
        if isinstance(fn, ast.Attribute):
            if isinstance(fn.value, ast.Name):
                if fn.value.id == "self":
                    cls = self._enclosing_class(mod, call)
                    if cls:
                        return mod.methods.get((cls, fn.attr))
                    return None
                target = mod.import_mods.get(fn.value.id)
                if target is None and fn.value.id in mod.import_syms:
                    # `from repro.models import transformer as T` — a module
                    # imported as a symbol.
                    pkg, sym = mod.import_syms[fn.value.id]
                    target = f"{pkg}.{sym}"
                if target in self.by_name:
                    other = self.by_name[target]
                    for cand in other.defs.get(fn.attr, []):
                        if cand.cls is None:
                            return cand
        return None

    def _enclosing_class(self, mod: ModuleIndex, node: ast.AST) -> Optional[str]:
        for anc in mod.src.enclosing(node, (ast.ClassDef,)):
            return anc.name
        return None

    # -- jit root discovery ------------------------------------------------
    def _jit_kind(self, mod: ModuleIndex, fn: ast.AST) -> Optional[str]:
        text = dotted(fn)
        if text is None:
            return None
        if text == "jax.jit" or text.endswith(".jit"):
            return "jit"
        if text == "jit" and mod.import_syms.get("jit", ("", ""))[0].startswith("jax"):
            return "jit"
        if text.endswith("pallas_call"):
            return "pallas"
        if text.endswith("shard_map"):
            return "shard_map"
        return None

    @staticmethod
    def _const_names(node: ast.AST) -> Set[str]:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return {node.value}
        if isinstance(node, (ast.Tuple, ast.List)):
            out: Set[str] = set()
            for elt in node.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    out.add(elt.value)
            return out
        return set()

    @staticmethod
    def _const_ints(node: ast.AST) -> Set[int]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return {node.value}
        if isinstance(node, (ast.Tuple, ast.List)):
            return {
                e.value
                for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)
            }
        return set()

    def _jit_opts(self, call: ast.Call, target: FuncInfo) -> Tuple[Set[str], Set[int]]:
        statics: Set[str] = set()
        donate: Set[int] = set()
        params = target.params
        for kw in call.keywords:
            if kw.arg in ("static_argnames",):
                statics |= self._const_names(kw.value)
            elif kw.arg in ("static_argnums", "static_argnum"):
                for i in self._const_ints(kw.value):
                    if 0 <= i < len(params):
                        statics.add(params[i])
            elif kw.arg in ("donate_argnums", "donate_argnames"):
                donate |= self._const_ints(kw.value)
                statics_from_names = self._const_names(kw.value)
                for name in statics_from_names:
                    if name in params:
                        donate.add(params.index(name))
        return statics, donate

    def _unwrap_partial(
        self, mod: ModuleIndex, node: ast.AST
    ) -> Tuple[Optional[ast.AST], Set[str], int]:
        """Peel ``functools.partial(f, ...)``: returns (inner, bound kwarg
        names, count of bound positional args)."""
        if (
            isinstance(node, ast.Call)
            and dotted(node.func) in ("functools.partial", "partial")
            and node.args
        ):
            kw = {k.arg for k in node.keywords if k.arg}
            return node.args[0], kw, len(node.args) - 1
        return None, set(), 0

    def _target_info(self, mod: ModuleIndex, node: ast.AST, at: ast.AST):
        """Resolve the function object a jit/pallas call wraps."""
        statics: Set[str] = set()
        inner, kw, npos = self._unwrap_partial(mod, node)
        if inner is not None:
            info = self._target_info(mod, inner, at)
            if info is None:
                return None
            fi, extra = info
            params = fi.params
            extra |= kw
            extra |= set(params[:npos])
            return fi, extra
        if isinstance(node, ast.Lambda):
            return FuncInfo(mod, node, "<lambda>"), statics
        if isinstance(node, ast.Name):
            fi = mod.resolve_local(node.id, at)
            if fi is None:
                imp = mod.import_syms.get(node.id)
                if imp and imp[0] in self.by_name:
                    other = self.by_name[imp[0]]
                    for cand in other.defs.get(imp[1], []):
                        if cand.cls is None:
                            fi = cand
                            break
            return (fi, statics) if fi else None
        return None

    def _find_roots(self, mod: ModuleIndex) -> None:
        for node in ast.walk(mod.src.tree):
            if isinstance(node, FuncNode):
                for deco in node.decorator_list:
                    kind = None
                    statics: Set[str] = set()
                    donate: Set[int] = set()
                    if self._jit_kind(mod, deco):
                        kind = self._jit_kind(mod, deco)
                    elif isinstance(deco, ast.Call):
                        if self._jit_kind(mod, deco.func):
                            kind = self._jit_kind(mod, deco.func)
                            fi = FuncInfo(mod, node, node.name, mod._owning_class(node))
                            statics, donate = self._jit_opts(deco, fi)
                        elif dotted(deco.func) in ("functools.partial", "partial") and deco.args:
                            if self._jit_kind(mod, deco.args[0]):
                                kind = self._jit_kind(mod, deco.args[0])
                                fi = FuncInfo(mod, node, node.name, mod._owning_class(node))
                                statics, donate = self._jit_opts(deco, fi)
                    if kind:
                        cls = mod._owning_class(node)
                        qual = f"{cls}.{node.name}" if cls else node.name
                        self.jit_roots.append(
                            JitRoot(FuncInfo(mod, node, qual, cls), statics, donate, kind)
                        )
            elif isinstance(node, ast.Call):
                kind = self._jit_kind(mod, node.func)
                if not kind or not node.args:
                    continue
                info = self._target_info(mod, node.args[0], node)
                if info is None:
                    continue
                fi, partial_statics = info
                statics, donate = self._jit_opts(node, fi)
                self.jit_roots.append(
                    JitRoot(fi, statics | partial_statics, donate, kind)
                )

    # -- reachability ------------------------------------------------------
    def _propagate(self) -> None:
        queue: List[FuncInfo] = [r.func for r in self.jit_roots]
        while queue:
            fi = queue.pop()
            if id(fi.node) in self.device_funcs:
                continue
            self.device_funcs[id(fi.node)] = fi
            body = fi.node.body if isinstance(fi.node.body, list) else [fi.node.body]
            for stmt in body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call):
                        callee = self.resolve_call(fi.module, sub)
                        if callee is not None and id(callee.node) not in self.device_funcs:
                            queue.append(callee)

    def is_device_func(self, node: ast.AST) -> bool:
        return id(node) in self.device_funcs
