"""RA201/RA202 — retrace hazards (the PR-3 per-batch-size stall class).

RA201 flags jit *construction/call* sites that defeat the compile cache:
a ``jax.jit(...)`` invoked immediately (fresh trace per call), a jit
built inside a loop without being cached into a subscript/attribute, an
unhashable literal passed to a known static parameter, and a static
argument derived from per-request sizes (``len(...)`` / ``.shape``)
without going through the power-of-two bucketing helpers.

RA202 flags Python ``if``/``while`` branches on traced parameters inside
jit root functions — those burn a concrete value into the trace and
retrace (or crash) on the next distinct input. Parameters bound via
``static_argnames``/``static_argnums`` or ``functools.partial`` are
exempt by construction.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis import register
from repro.analysis.core import Finding
from repro.analysis.project import FuncNode, JitRoot, ProjectIndex, dotted

_CACHED_TARGET = (ast.Subscript, ast.Attribute)


def _is_jit_call(project: ProjectIndex, mod, node: ast.Call) -> bool:
    return project._jit_kind(mod, node.func) == "jit"


def _unhashable(node: ast.AST) -> bool:
    return isinstance(
        node,
        (ast.List, ast.Set, ast.Dict, ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp),
    )


def _size_derived(node: ast.AST) -> bool:
    """True when the expression computes a per-request size (len/.shape)
    without routing through a bucketing helper."""
    text = ast.unparse(node)
    if "bucket" in text:
        return False
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) and sub.func.id == "len":
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == "shape":
            return True
    return False


@register("retrace")
def check(project: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []

    # Map jitted-def nodes -> their static parameter names, for call-site
    # checks against known jitted functions.
    statics_by_def: Dict[int, JitRoot] = {}
    for root in project.jit_roots:
        if isinstance(root.func.node, FuncNode):
            statics_by_def[id(root.func.node)] = root

    for mod in project.modules:
        src = mod.src
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            if _is_jit_call(project, mod, node):
                parent = src.parent.get(node)
                # jax.jit(f)(args...) — a fresh trace on every call.
                if isinstance(parent, ast.Call) and parent.func is node:
                    findings.append(
                        Finding(
                            src.rel,
                            node.lineno,
                            "RA201",
                            "jax.jit(...) invoked immediately — the traced function is "
                            "rebuilt (and retraced) on every call; hoist the jit to "
                            "module/init scope or cache it",
                        )
                    )
                # jit constructed inside a loop without a subscript/attribute
                # cache slot to land in.
                elif any(src.enclosing(node, (ast.For, ast.While))):
                    stmt = src.stmt_of(node)
                    cached = isinstance(stmt, ast.Assign) and all(
                        isinstance(t, _CACHED_TARGET) for t in stmt.targets
                    )
                    if not cached:
                        findings.append(
                            Finding(
                                src.rel,
                                node.lineno,
                                "RA201",
                                "jax.jit(...) constructed inside a loop without being "
                                "cached — each iteration pays a full retrace",
                            )
                        )
                continue

            # Calls *to* known jitted functions: inspect static arguments.
            callee = project.resolve_call(mod, node)
            root = statics_by_def.get(id(callee.node)) if callee else None
            if root is None or not root.statics:
                continue
            params = root.func.params
            static_args = []
            for i, arg in enumerate(node.args):
                if i < len(params) and params[i] in root.statics:
                    static_args.append((params[i], arg))
            for kw in node.keywords:
                if kw.arg in root.statics:
                    static_args.append((kw.arg, kw.value))
            for name, value in static_args:
                if _unhashable(value):
                    findings.append(
                        Finding(
                            src.rel,
                            value.lineno,
                            "RA201",
                            f"unhashable literal passed to static arg `{name}` of "
                            f"jitted `{root.func.qualname}` — every call retraces; "
                            "pass a tuple or hashable scalar",
                        )
                    )
                elif _size_derived(value):
                    findings.append(
                        Finding(
                            src.rel,
                            value.lineno,
                            "RA201",
                            f"static arg `{name}` of jitted `{root.func.qualname}` is "
                            "derived from a per-request size — bucket it "
                            "(see store_bank.bucket_len) or the compile cache grows "
                            "per distinct size",
                        )
                    )

    # RA202: branches on traced parameters inside jit roots.
    seen: Set[int] = set()
    for root in project.jit_roots:
        node = root.func.node
        if not isinstance(node, FuncNode) or id(node) in seen:
            continue
        seen.add(id(node))
        traced = {
            p for p in root.func.params if p not in root.statics and p not in ("self", "cls")
        }
        if not traced:
            continue
        src = root.func.module.src
        for sub in ast.walk(node):
            if isinstance(sub, (ast.If, ast.While, ast.IfExp)):
                test_names = {
                    n.id for n in ast.walk(sub.test) if isinstance(n, ast.Name)
                }
                hit = test_names & traced
                if hit:
                    findings.append(
                        Finding(
                            src.rel,
                            sub.test.lineno,
                            "RA202",
                            f"Python branch on traced value `{sorted(hit)[0]}` inside "
                            f"jitted `{root.func.qualname}` — use jnp.where/lax.cond "
                            "or mark the arg static",
                        )
                    )
    return findings
