"""RA401 — donated device buffers referenced after donation.

``donate_argnums`` lets the StoreBank scatter/free/touch jits reuse their
input buffers in place — after the call, the donated array is dead and
reading it raises (or worse, silently returns garbage under some
backends). The safe idiom in this repo is to rebind every donated buffer
from the jit's results *in the same statement*::

    (self.buf, self.valid, ...) = _bank_scatter(self.buf, self.valid, ...)

This checker builds a registry of donated jits (decorated defs,
``self.x = jax.jit(..., donate_argnums=...)`` assignments, aliases of
known donated jits, and locals returned from factories like
``_build_program``), then flags any later *read* of a donated argument
expression that was not rebound at the call site.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import register
from repro.analysis.core import Finding
from repro.analysis.project import FuncNode, ProjectIndex, dotted


def _donated_registry(project: ProjectIndex):
    by_name: Dict[Tuple[str, str], Set[int]] = {}  # (module, func name) -> positions
    by_attr: Dict[str, Set[int]] = {}  # attribute name -> positions (class-agnostic)
    factories: Dict[int, Set[int]] = {}  # factory def id -> union of donate positions

    for root in project.jit_roots:
        if not root.donate:
            continue
        node = root.func.node
        rel = root.func.module.src.rel
        if isinstance(node, FuncNode) and root.func.cls is None:
            by_name[(rel, node.name)] = by_name.get((rel, node.name), set()) | root.donate
        # Call-form roots (`self.x = jax.jit(...)`) are recovered from the
        # assignment scan below.

    for mod in project.modules:
        src = mod.src
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            val = node.value
            donate = _donate_of_jit_call(project, mod, val)
            if donate:
                if isinstance(tgt, ast.Attribute):
                    by_attr[tgt.attr] = by_attr.get(tgt.attr, set()) | donate
                elif isinstance(tgt, ast.Name):
                    by_name[(src.rel, tgt.id)] = by_name.get((src.rel, tgt.id), set()) | donate
            elif isinstance(tgt, ast.Attribute) and isinstance(val, ast.Name):
                # Alias: self._free_jit = _bank_free
                known = by_name.get((src.rel, val.id))
                if known:
                    by_attr[tgt.attr] = by_attr.get(tgt.attr, set()) | known

    # Factories: module-level defs whose returns are jax.jit(..., donate_argnums=...).
    for mod in project.modules:
        for infos in mod.defs.values():
            for fi in infos:
                if not isinstance(fi.node, FuncNode):
                    continue
                union: Set[int] = set()
                for sub in ast.walk(fi.node):
                    if isinstance(sub, ast.Return) and sub.value is not None:
                        union |= _donate_of_jit_call(project, mod, sub.value)
                if union:
                    factories[id(fi.node)] = union
    return by_name, by_attr, factories


def _donate_of_jit_call(project: ProjectIndex, mod, node: ast.AST) -> Set[int]:
    if not isinstance(node, ast.Call) or project._jit_kind(mod, node.func) != "jit":
        return set()
    donate: Set[int] = set()
    for kw in node.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            donate |= project._const_ints(kw.value)
    return donate


def _assigned_names(stmt: ast.stmt) -> Set[str]:
    if not isinstance(stmt, ast.Assign):
        return set()
    out: Set[str] = set()
    for tgt in stmt.targets:
        elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) else [tgt]
        for e in elts:
            text = dotted(e)
            if text:
                out.add(text)
    return out


def _statements_after(src, stmt: ast.stmt) -> List[ast.stmt]:
    """Statements that can execute after ``stmt``: suffixes of every
    enclosing block, plus whole bodies of enclosing loops (a later
    iteration re-executes the top of the loop)."""
    after: List[ast.stmt] = []
    cur: ast.AST = stmt
    while True:
        parent = src.parent.get(cur)
        if parent is None:
            break
        for field in ("body", "orelse", "finalbody", "handlers"):
            block = getattr(parent, field, None)
            if isinstance(block, list) and cur in block:
                after.extend(block[block.index(cur) + 1 :])
        if isinstance(parent, (ast.For, ast.While)):
            after.extend(parent.body)
        if isinstance(parent, FuncNode):
            break
        cur = parent
    return after


@register("donation")
def check(project: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    by_name, by_attr, factories = _donated_registry(project)

    for mod in project.modules:
        src = mod.src
        for func in [n for n in ast.walk(src.tree) if isinstance(n, FuncNode)]:
            # Locals bound from donated-jit factories inside this function.
            local_donated: Dict[str, Set[int]] = {}
            for stmt in ast.walk(func):
                if (
                    isinstance(stmt, ast.Assign)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and isinstance(stmt.value, ast.Call)
                ):
                    callee = project.resolve_call(mod, stmt.value)
                    if callee is not None and id(callee.node) in factories:
                        local_donated[stmt.targets[0].id] = factories[id(callee.node)]

            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                positions = _donated_positions(mod, node, by_name, by_attr, local_donated)
                if not positions:
                    continue
                stmt = src.stmt_of(node)
                rebound = _assigned_names(stmt)
                callee_text = dotted(node.func) or "<jit>"
                for pos in sorted(positions):
                    if pos >= len(node.args):
                        continue
                    expr = dotted(node.args[pos])
                    if expr is None or expr in rebound:
                        continue
                    for later in _statements_after(src, stmt):
                        for use in ast.walk(later):
                            if (
                                isinstance(use, (ast.Attribute, ast.Name))
                                and isinstance(getattr(use, "ctx", None), ast.Load)
                                and dotted(use) == expr
                            ):
                                findings.append(
                                    Finding(
                                        src.rel,
                                        use.lineno,
                                        "RA401",
                                        f"`{expr}` was donated to `{callee_text}` "
                                        f"(line {node.lineno}) and read afterwards — "
                                        "a donated buffer is dead after the call; "
                                        "rebind it from the jit's results",
                                    )
                                )
                                break  # one finding per later statement is enough
    return findings


def _donated_positions(mod, call: ast.Call, by_name, by_attr, local_donated) -> Set[int]:
    fn = call.func
    if isinstance(fn, ast.Name):
        if fn.id in local_donated:
            return local_donated[fn.id]
        return by_name.get((mod.src.rel, fn.id), set())
    if isinstance(fn, ast.Attribute):
        return by_attr.get(fn.attr, set())
    return set()
