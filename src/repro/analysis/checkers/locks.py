"""RA301 — `# guarded-by: <lock>` attributes accessed outside their lock.

The serving layer (CacheService, BatchCoalescer, ServingEngine,
EnhancedClient) keeps its threaded schedulers correct with hand-maintained
locks. Attributes declare their lock with a trailing comment on the
``__init__`` assignment::

    self._inflight = 0  # guarded-by: _lock

Every later ``self.<attr>`` read or write (outside ``__init__``) must then
sit lexically inside ``with self._lock:``. Condition variables constructed
over a lock (``self._capacity = threading.Condition(self._lock)``) count
as aliases of that lock. A method that is documented to be called with the
lock already held can declare ``# repro: holds[_lock]`` on its ``def``
line.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis import register
from repro.analysis.core import GUARDED_RE, HOLDS_RE, Finding
from repro.analysis.project import FuncNode, ProjectIndex, dotted


def _class_lock_tables(src, cls: ast.ClassDef):
    guarded: Dict[str, str] = {}  # attr -> lock attr
    aliases: Dict[str, str] = {}  # condition attr -> underlying lock attr
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
            ):
                comment = src.comments.get(node.lineno, "")
                m = GUARDED_RE.search(comment)
                if m:
                    guarded[tgt.attr] = m.group(1)
                val = node.value
                if (
                    isinstance(val, ast.Call)
                    and dotted(val.func) in ("threading.Condition", "Condition")
                    and val.args
                ):
                    lock = dotted(val.args[0])
                    if lock and lock.startswith("self."):
                        aliases[tgt.attr] = lock.split(".", 1)[1]
    return guarded, aliases


def _locks_held(src, node: ast.AST, aliases: Dict[str, str]) -> Set[str]:
    held: Set[str] = set()
    for w in src.enclosing(node, (ast.With, ast.AsyncWith)):
        for item in w.items:
            text = dotted(item.context_expr)
            if text and text.startswith("self."):
                attr = text.split(".", 1)[1]
                held.add(attr)
                if attr in aliases:
                    held.add(aliases[attr])
    return held


@register("locks")
def check(project: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        src = mod.src
        for cls in mod.classes.values():
            guarded, aliases = _class_lock_tables(src, cls)
            if not guarded:
                continue
            for method in cls.body:
                if not isinstance(method, FuncNode) or method.name == "__init__":
                    continue
                holds: Set[str] = set()
                m = HOLDS_RE.search(src.comments.get(method.lineno, ""))
                if m:
                    holds.add(m.group(1))
                for node in ast.walk(method):
                    if not (
                        isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"
                        and node.attr in guarded
                    ):
                        continue
                    lock = guarded[node.attr]
                    held = _locks_held(src, node, aliases) | holds
                    if lock not in held:
                        findings.append(
                            Finding(
                                src.rel,
                                node.lineno,
                                "RA301",
                                f"{cls.name}.{node.attr} is guarded-by self.{lock} "
                                f"but `{method.name}` accesses it without holding "
                                "the lock",
                            )
                        )
    return findings
