"""Importing this package registers every checker with the registry."""
from repro.analysis.checkers import donation, host_sync, locks, overflow, retrace  # noqa: F401
