"""RA101 — implicit device→host syncs inside jit/pallas-reachable code.

The zero-host-hop contract (PR 5/6) is that everything between embed and
decide runs as one device program. A stray ``.item()``, ``float()`` on a
traced array, or ``np.asarray`` of a jnp value forces a blocking transfer
and silently re-introduces the host round-trip the fused read path was
built to remove. Device regions are every function reachable (through the
call graph) from a ``jax.jit`` / ``pallas_call`` / ``shard_map`` root.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis import register
from repro.analysis.core import Finding
from repro.analysis.project import ProjectIndex, dotted

_HOST_BUILTINS = {"float", "int", "bool", "complex"}
_HOST_METHODS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}
_NUMPY_FUNCS = {"asarray", "array", "copy", "ascontiguousarray"}


@register("host-sync")
def check(project: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    for fi in project.device_funcs.values():
        mod = fi.module
        numpy_aliases = {
            alias for alias, target in mod.import_mods.items() if target == "numpy"
        }
        body = fi.node.body if isinstance(fi.node.body, list) else [fi.node.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                msg = None
                fn = node.func
                if isinstance(fn, ast.Attribute) and fn.attr in _HOST_METHODS and not node.args:
                    msg = (
                        f".{fn.attr}() forces a device->host sync inside "
                        f"device region `{fi.qualname}`"
                    )
                elif isinstance(fn, ast.Name) and fn.id in _HOST_BUILTINS and node.args:
                    msg = (
                        f"host {fn.id}() conversion inside device region "
                        f"`{fi.qualname}` blocks on a device->host transfer"
                    )
                elif isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
                    base, attr = fn.value.id, fn.attr
                    if base in numpy_aliases and attr in _NUMPY_FUNCS:
                        msg = (
                            f"{base}.{attr}() materializes a device value on host "
                            f"inside device region `{fi.qualname}`"
                        )
                    elif dotted(fn) == "jax.device_get":
                        msg = (
                            f"jax.device_get inside device region `{fi.qualname}` "
                            "is a host round-trip"
                        )
                if msg:
                    findings.append(Finding(mod.src.rel, node.lineno, "RA101", msg))
    return findings
