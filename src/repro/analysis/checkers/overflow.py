"""RA501/RA502 — int32 clock saturation and timestamp-precision mixing.

RA501: StoreBank's recency ticks and insertion seqs live in int32 device
buffers, so the host-side monotonic counters that feed them must rebase
(compact) before ``iinfo(int32).max``. In any module that participates in
the compaction protocol (references ``_TICK_COMPACT_AT`` / ``compact_``),
a ``+=`` on a tick/seq-named attribute must sit in a function that also
references the compaction guard — an unguarded increment is exactly the
PR-6 overflow bug re-introduced.

RA502: lifecycle truth (created/expires wall-clock stamps) is float64 on
host; the device copies are float32 *relative* offsets. Casting an
absolute epoch timestamp (``time.time()`` or a ``*_at`` value) straight to
float32 silently loses whole seconds of precision (~128s granularity at
today's epoch) and corrupts TTL math.
"""
from __future__ import annotations

import ast
import re
from typing import List

from repro.analysis import register
from repro.analysis.core import Finding
from repro.analysis.project import ProjectIndex, dotted

_COUNTER_RE = re.compile(r"(^|_)(tick|seq)s?$")
_COMPACT_RE = re.compile(r"compact", re.IGNORECASE)
_ABS_TIME_RE = re.compile(r"time\.time\(\)|monotonic\(\)|_at\b|\bnow_s\b")


def _module_in_compact_protocol(src) -> bool:
    return bool(_COMPACT_RE.search(src.source))


@register("overflow")
def check(project: ProjectIndex) -> List[Finding]:
    findings: List[Finding] = []
    for mod in project.modules:
        src = mod.src
        if _module_in_compact_protocol(src):
            for node in ast.walk(src.tree):
                if not (
                    isinstance(node, ast.AugAssign)
                    and isinstance(node.op, ast.Add)
                    and isinstance(node.target, ast.Attribute)
                    and _COUNTER_RE.search(node.target.attr)
                ):
                    continue
                funcs = src.enclosing(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)
                )
                guarded = False
                for fn in funcs[:1]:  # the innermost enclosing function
                    text = ast.unparse(fn)
                    if _COMPACT_RE.search(text):
                        guarded = True
                if not guarded:
                    findings.append(
                        Finding(
                            src.rel,
                            node.lineno,
                            "RA501",
                            f"int32 monotonic counter `{node.target.attr}` is "
                            "incremented without a visible rebase guard — compare "
                            "against _TICK_COMPACT_AT and compact before the int32 "
                            "ceiling (see StoreBank.next_tick)",
                        )
                    )

        # RA502: float32 casts of absolute timestamps.
        for node in ast.walk(src.tree):
            operand = None
            if isinstance(node, ast.Call):
                fn_text = dotted(node.func) or ""
                if fn_text.endswith("float32") and node.args:
                    operand = node.args[0]
                elif (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype"
                    and node.args
                ):
                    arg_text = ast.unparse(node.args[0])
                    if "float32" in arg_text:
                        operand = node.func.value
            if operand is None:
                continue
            text = ast.unparse(operand)
            if _ABS_TIME_RE.search(text):
                findings.append(
                    Finding(
                        src.rel,
                        node.lineno,
                        "RA502",
                        "absolute timestamp narrowed to float32 — epoch-scale "
                        "values lose ~2 minutes of precision in f32; keep host "
                        "lifecycle stamps f64 and ship f32 *relative* offsets "
                        "(see StoreBank.rel_now/to_rel)",
                    )
                )
    return findings
