"""Seeded, schedule-driven fault injection for backends and tiers.

Chaos that can't be replayed can't be debugged: every wrapper here draws
from ONE seeded RNG owned by the ``FaultInjector`` and advances a
per-target call counter, so a fault schedule (``FaultSpec`` windows over
call indices) produces the *same* faults on the same call sequence — in a
unit test, in the traffic harness, and in CI.

Failure modes:

- ``error``: raise a typed ``InjectedFault`` immediately (connection-reset
  shaped).
- ``hang``: block until the batch's soonest deadline has passed (or
  ``hang_s`` when no deadline travels with the call), then raise — the
  shape of a TCP black hole.
- ``latency``: sleep ``latency_s`` before forwarding (slow but correct).
- ``flap``: alternate ``period`` calls down / ``period`` calls up — the
  mode that defeats consecutive-failure breakers and needs health scoring.
- ``slow_tokens``: forward, then stall proportionally to the tokens
  generated (decode-bound slowness rather than connect-bound).

``FaultyBackend`` deliberately does NOT import the client module (the
client imports this package; a module-level import back would cycle) — it
duck-types the ``LLMBackend`` surface (``name``, ``supports_deadlines``,
``generate``, ``generate_batch``) which is all the failover path touches.
"""
from __future__ import annotations

import inspect
import random
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.resilience.errors import InjectedFault

KINDS = ("error", "hang", "latency", "flap", "slow_tokens")


@dataclass(frozen=True)
class FaultSpec:
    """One failure mode active over a window of call indices."""

    kind: str  # one of KINDS
    p: float = 1.0  # per-call probability inside the window
    start: int = 0  # first call index (inclusive)
    stop: Optional[int] = None  # first call index past the window; None = forever
    latency_s: float = 0.05  # latency / slow_tokens stall
    hang_s: float = 0.25  # hang duration when no deadline travels with the call
    period: int = 4  # flap: this many calls down, then this many up
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected one of {KINDS}")

    def active(self, idx: int) -> bool:
        if idx < self.start or (self.stop is not None and idx >= self.stop):
            return False
        if self.kind == "flap":
            # phase 0 (down) first so a schedule starting at `start` faults
            return ((idx - self.start) // max(1, self.period)) % 2 == 0
        return True


class FaultInjector:
    """Owns the seed, the per-target call counters, and the schedules."""

    def __init__(self, seed: int = 0, sleep_fn=time.sleep, time_fn=time.perf_counter):
        self._rng = random.Random(seed)  # guarded-by: _lock
        self._sleep = sleep_fn
        self._time = time_fn
        self._lock = threading.Lock()
        self._schedules: Dict[str, Tuple[FaultSpec, ...]] = {}  # guarded-by: _lock
        self._calls: Dict[str, int] = {}  # guarded-by: _lock
        self._injected: Dict[str, int] = {}  # guarded-by: _lock

    def schedule(self, name: str, *specs: FaultSpec) -> None:
        """Attach ``specs`` to target ``name`` (replaces any prior schedule)."""
        with self._lock:
            self._schedules[name] = tuple(specs)
            self._calls.setdefault(name, 0)

    def plan(self, name: str) -> Tuple[int, Optional[FaultSpec]]:
        """Advance ``name``'s call counter and pick the fault (if any) for
        this call — first active spec whose probability draw fires."""
        with self._lock:
            idx = self._calls.get(name, 0)
            self._calls[name] = idx + 1
            for spec in self._schedules.get(name, ()):
                if not spec.active(idx):
                    continue
                if spec.p >= 1.0 or self._rng.random() < spec.p:
                    key = f"{name}:{spec.kind}"
                    self._injected[key] = self._injected.get(key, 0) + 1
                    return idx, spec
            return idx, None

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "calls": dict(self._calls),
                "injected": dict(self._injected),
                "total_injected": sum(self._injected.values()),
            }

    # -- wrappers -----------------------------------------------------------

    def wrap_backend(self, backend) -> "FaultyBackend":
        return FaultyBackend(backend, self)

    def wrap_tier(self, tier, name: str = "tier1") -> "FaultyTier":
        return FaultyTier(tier, self, name)


def _inner_accepts_deadlines(backend) -> bool:
    declared = getattr(backend, "supports_deadlines", None)
    if declared is not None:
        return bool(declared)
    try:
        return "deadlines" in inspect.signature(type(backend).generate_batch).parameters
    except (AttributeError, TypeError, ValueError):
        return False


class FaultyBackend:
    """Chaos wrapper around an ``LLMBackend`` (duck-typed, see module doc)."""

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector
        self.name = inner.name
        # declare explicitly so the client's tri-state probe never inspects
        # THIS signature and mistakes the wrapper for the wrapped
        self.supports_deadlines = _inner_accepts_deadlines(inner)

    def generate(self, prompt: str, max_tokens: int = 256, temperature: float = 0.0):
        return self.generate_batch([prompt], max_tokens, temperature)[0]

    def generate_batch(
        self,
        prompts: Sequence[str],
        max_tokens: int = 256,
        temperature: float = 0.0,
        deadlines: Optional[Sequence[Optional[float]]] = None,
    ):
        _, spec = self.injector.plan(self.name)
        if spec is not None:
            if spec.kind in ("error", "flap"):
                raise InjectedFault(f"{self.name}: {spec.message}", spec.kind, self.name)
            if spec.kind == "hang":
                self._hang(deadlines, spec)
                raise InjectedFault(f"{self.name}: hang past deadline", "hang", self.name)
            if spec.kind == "latency":
                self.injector._sleep(spec.latency_s)
        out = self._forward(prompts, max_tokens, temperature, deadlines)
        if spec is not None and spec.kind == "slow_tokens" and out:
            stall = min(spec.hang_s, spec.latency_s * max(r.tokens_out for r in out))
            if stall > 0:
                self.injector._sleep(stall)
                for r in out:
                    r.latency_s += stall
        return out

    def _hang(self, deadlines, spec: FaultSpec) -> None:
        """Block like a black-holed connection: until the soonest deadline in
        the batch has passed (plus a hair), or ``hang_s`` with no deadline."""
        stamps = [d for d in (deadlines or []) if d is not None]
        if stamps:
            self.injector._sleep(max(0.0, min(stamps) - self.injector._time()) + 0.002)
        else:
            self.injector._sleep(spec.hang_s)

    def _forward(self, prompts, max_tokens, temperature, deadlines):
        if deadlines is not None and _inner_accepts_deadlines(self.inner):
            return self.inner.generate_batch(prompts, max_tokens, temperature, deadlines=deadlines)
        return self.inner.generate_batch(prompts, max_tokens, temperature)


class FaultyTier:
    """Chaos proxy for a host tier (``HostRamTier``-shaped): ``search`` /
    ``put`` / ``pop`` consult the schedule; everything else forwards."""

    _INTERCEPTED = ("search", "put", "pop")

    def __init__(self, inner, injector: FaultInjector, name: str = "tier1"):
        # bypass __setattr__-style surprises by writing through __dict__ is
        # unnecessary here; plain attributes are fine for a proxy
        self.inner = inner
        self.injector = injector
        self.fault_name = name

    def _gate(self, op: str):
        _, spec = self.injector.plan(self.fault_name)
        if spec is None:
            return
        if spec.kind in ("error", "flap", "hang"):
            raise InjectedFault(f"{self.fault_name}.{op}: {spec.message}", spec.kind, self.fault_name)
        if spec.kind in ("latency", "slow_tokens"):
            self.injector._sleep(spec.latency_s)

    def search(self, *args, **kwargs):
        self._gate("search")
        return self.inner.search(*args, **kwargs)

    def put(self, *args, **kwargs):
        self._gate("put")
        return self.inner.put(*args, **kwargs)

    def pop(self, *args, **kwargs):
        self._gate("pop")
        return self.inner.pop(*args, **kwargs)

    def __getattr__(self, item):
        return getattr(self.inner, item)

    def __len__(self):
        return len(self.inner)
