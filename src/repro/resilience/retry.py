"""Retry policy (exponential backoff + jitter) and a global retry budget.

``RetryPolicy`` is pure arithmetic — the caller supplies the RNG draw so
determinism stays in one place (the client seeds one ``random.Random`` and
draws under its state lock). ``RetryBudget`` is the classic token bucket
that caps *fleet-wide* retry amplification: every first attempt deposits a
fraction of a token, every retry spends a whole one, so under a correlated
outage retries self-limit to ``ratio`` of organic traffic instead of
multiplying the load on whatever is still standing.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass


@dataclass(frozen=True)
class RetryPolicy:
    """How one backend is retried before failover moves on.

    ``max_attempts`` counts the first try: 3 means 1 call + up to 2
    retries. Backoff grows ``base * multiplier**(attempt-1)`` capped at
    ``max_backoff_s``; ``jitter`` is the +/- fraction applied from a
    uniform draw, which decorrelates retry waves across callers.
    """

    max_attempts: int = 3
    base_backoff_s: float = 0.05
    max_backoff_s: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.5

    def backoff_s(self, attempt: int, draw: float = 0.5) -> float:
        """Sleep before retry number ``attempt`` (1-based). ``draw`` is a
        uniform [0,1) sample supplied by the caller's seeded RNG."""
        base = min(self.base_backoff_s * (self.multiplier ** max(0, attempt - 1)), self.max_backoff_s)
        span = self.jitter * base
        return max(0.0, base - span + 2.0 * span * draw)


class RetryBudget:
    """Token bucket bounding total retries relative to organic traffic.

    Each first attempt deposits ``ratio`` tokens (capped at ``capacity``);
    each retry spends 1.0. When the bucket is dry, retries are refused and
    failover moves to the next backend immediately — the standard defense
    against retry storms amplifying an outage.
    """

    def __init__(self, capacity: float = 10.0, ratio: float = 0.1):
        self.capacity = float(capacity)
        self.ratio = float(ratio)
        self._lock = threading.Lock()
        self._tokens = float(capacity)  # guarded-by: _lock
        self._spent = 0  # guarded-by: _lock
        self._refused = 0  # guarded-by: _lock

    def deposit(self, n: int = 1) -> None:
        """Credit ``n`` first attempts."""
        with self._lock:
            self._tokens = min(self.capacity, self._tokens + self.ratio * n)

    def try_spend(self) -> bool:
        """Reserve one retry. False = budget exhausted, do not retry."""
        with self._lock:
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                self._spent += 1
                return True
            self._refused += 1
            return False

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "tokens": round(self._tokens, 3),
                "capacity": self.capacity,
                "spent": self._spent,
                "refused": self._refused,
            }
