"""Per-backend circuit breaker with health scoring.

Classic three-state machine:

    CLOSED --[trip: consecutive failures or health < floor]--> OPEN
    OPEN   --[recovery_s elapsed]--> HALF_OPEN (probe budget)
    HALF_OPEN --[probe succeeds]--> CLOSED
    HALF_OPEN --[probe fails]--> OPEN (recovery timer restarts)

While OPEN the breaker fast-fails ``allow()`` so a dead backend costs a
dict lookup instead of a connect timeout per request. Health is an EMA of
call outcomes (1.0 = success, 0.0 = failure) so a *flapping* backend —
which never accumulates ``failure_threshold`` consecutive failures — still
trips once its score sinks below ``health_floor``.

The clock is injectable (``time_fn``) so tests drive open -> half-open
transitions without sleeping, and every mutation happens under one lock
with ``# guarded-by:`` annotations (RA301).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class BreakerOpen(ConnectionError):
    """Raised by ``call``-style helpers when the breaker refuses a call."""

    def __init__(self, backend: str):
        super().__init__(f"circuit breaker open for backend {backend!r}")
        self.backend = backend


class CircuitBreaker:
    def __init__(
        self,
        name: str = "backend",
        failure_threshold: int = 3,
        recovery_s: float = 5.0,
        half_open_probes: int = 1,
        health_alpha: float = 0.2,
        health_floor: float = 0.25,
        time_fn: Optional[Callable[[], float]] = None,
    ):
        self.name = name
        self.failure_threshold = int(failure_threshold)
        self.recovery_s = float(recovery_s)
        self.half_open_probes = int(half_open_probes)
        self.health_alpha = float(health_alpha)
        self.health_floor = float(health_floor)
        self._time = time_fn or time.monotonic
        self._lock = threading.Lock()
        self._state = CLOSED  # guarded-by: _lock
        self._consecutive_failures = 0  # guarded-by: _lock
        self._health = 1.0  # guarded-by: _lock
        self._opened_at = 0.0  # guarded-by: _lock
        self._probes_inflight = 0  # guarded-by: _lock
        self._trips = 0  # guarded-by: _lock
        self._successes = 0  # guarded-by: _lock
        self._failures = 0  # guarded-by: _lock
        self._open_skips = 0  # guarded-by: _lock

    # -- state ---------------------------------------------------------------

    @property
    def state(self) -> str:
        with self._lock:
            self._maybe_half_open()
            return self._state

    def _maybe_half_open(self) -> None:  # repro: holds[_lock]
        if self._state == OPEN and self._time() - self._opened_at >= self.recovery_s:
            self._state = HALF_OPEN
            self._probes_inflight = 0

    def allow(self) -> bool:
        """May a call go to this backend right now? HALF_OPEN admits at most
        ``half_open_probes`` concurrent probes; OPEN admits none (and counts
        the skip)."""
        with self._lock:
            self._maybe_half_open()
            if self._state == CLOSED:
                return True
            if self._state == HALF_OPEN:
                if self._probes_inflight < self.half_open_probes:
                    self._probes_inflight += 1
                    return True
                self._open_skips += 1
                return False
            self._open_skips += 1
            return False

    # -- outcome recording -----------------------------------------------------

    def record_success(self) -> None:
        with self._lock:
            self._successes += 1
            self._consecutive_failures = 0
            self._health += self.health_alpha * (1.0 - self._health)
            if self._state == HALF_OPEN:
                # probe came back healthy: close and forgive the score so the
                # next organic failure doesn't instantly re-trip on old EMA
                self._state = CLOSED
                self._probes_inflight = 0
                self._health = max(self._health, 0.5)

    def record_failure(self) -> bool:
        """Record a failed call. Returns True when THIS failure tripped the
        breaker (closed/half-open -> open), so callers can count trips."""
        with self._lock:
            self._failures += 1
            self._consecutive_failures += 1
            self._health += self.health_alpha * (0.0 - self._health)
            if self._state == HALF_OPEN:
                self._trip()
                return True
            if self._state == CLOSED and (
                self._consecutive_failures >= self.failure_threshold
                or self._health < self.health_floor
            ):
                self._trip()
                return True
            return False

    def _trip(self) -> None:  # repro: holds[_lock]
        self._state = OPEN
        self._opened_at = self._time()
        self._probes_inflight = 0
        self._trips += 1

    def force_open(self) -> None:
        """Administratively open (used by chaos drills / tests)."""
        with self._lock:
            if self._state != OPEN:
                self._trip()

    def reset(self) -> None:
        with self._lock:
            self._state = CLOSED
            self._consecutive_failures = 0
            self._health = 1.0
            self._probes_inflight = 0

    # -- introspection ---------------------------------------------------------

    def snapshot(self) -> dict:
        with self._lock:
            self._maybe_half_open()
            return {
                "state": self._state,
                "health": round(self._health, 4),
                "consecutive_failures": self._consecutive_failures,
                "trips": self._trips,
                "successes": self._successes,
                "failures": self._failures,
                "open_skips": self._open_skips,
            }
