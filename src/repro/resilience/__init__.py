"""Resilience subsystem: fault injection + fault handling for the serving stack.

The paper's availability story ("If an LLM is unresponsive... other LLMs can
be queried", §2) needs more than a fall-through loop. This package supplies
the pieces the client/service/gateway thread together:

- ``errors``: typed failure envelopes (``AllBackendsFailed`` with structured
  per-backend causes, ``InjectedFault`` for chaos-originated errors).
- ``breaker``: a per-backend closed/open/half-open circuit breaker with an
  EMA health score, so a flapping backend fast-fails instead of eating a
  timeout per request.
- ``retry``: exponential-backoff-with-jitter retry policy plus a global
  retry token budget that caps retry storms under correlated failure.
- ``faults``: a seeded, schedule-driven ``FaultInjector`` whose wrappers
  make every failure mode (typed error, hang-until-deadline, latency spike,
  flapping, slow tokens) reproducible in tests and the traffic harness.

Everything here is deterministic under a fixed seed and injectable clock —
chaos runs replay bit-identically.
"""
from repro.resilience.breaker import BreakerOpen, CircuitBreaker, CLOSED, HALF_OPEN, OPEN
from repro.resilience.errors import AllBackendsFailed, BackendFailure, InjectedFault
from repro.resilience.faults import FaultInjector, FaultSpec, FaultyBackend, FaultyTier
from repro.resilience.retry import RetryBudget, RetryPolicy

__all__ = [
    "AllBackendsFailed",
    "BackendFailure",
    "BreakerOpen",
    "CircuitBreaker",
    "CLOSED",
    "FaultInjector",
    "FaultSpec",
    "FaultyBackend",
    "FaultyTier",
    "HALF_OPEN",
    "InjectedFault",
    "OPEN",
    "RetryBudget",
    "RetryPolicy",
]
