"""Typed failure envelopes for the failover path.

The old loop raised ``ConnectionError(f"all backends failed: {tried}")`` —
the string kept the reprs but lost the exception *types*, so the gateway
could not tell an injected chaos error from an auth failure, and tests
could only assert on substrings. ``AllBackendsFailed`` keeps structured
per-backend causes (name, attempts, the exception kinds seen, whether the
breaker skipped it without a call) and the gateway maps it to a typed
``backend_unavailable`` 503 + Retry-After envelope.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class BackendFailure:
    """What happened on ONE backend during a failover walk."""

    backend: str
    attempts: int = 0  # calls actually made (0 == breaker fast-fail skip)
    skipped: bool = False  # breaker was open; no call burned
    errors: List[str] = field(default_factory=list)  # repr() per attempt
    kinds: List[str] = field(default_factory=list)  # exception type names

    def to_dict(self) -> dict:
        return {
            "backend": self.backend,
            "attempts": self.attempts,
            "skipped": self.skipped,
            "errors": list(self.errors),
            "kinds": list(self.kinds),
        }


class AllBackendsFailed(ConnectionError):
    """Every backend was skipped (breaker open) or exhausted its retries.

    Carries the structured per-backend causes so callers can branch on
    exception *types* (``kinds``) instead of parsing a repr string. The
    gateway maps this to 503 + ``Retry-After`` with error code
    ``backend_unavailable``; the service consults the serve-stale ladder
    before letting it reach a future.
    """

    def __init__(self, causes: List[BackendFailure], message: Optional[str] = None):
        self.causes = list(causes)
        if message is None:
            parts = []
            for c in self.causes:
                if c.skipped and not c.attempts:
                    parts.append(f"{c.backend}: breaker open")
                else:
                    kinds = ",".join(c.kinds) or "no error recorded"
                    parts.append(f"{c.backend}: {c.attempts} attempt(s) [{kinds}]")
            message = "all backends failed: " + "; ".join(parts) if parts else "no backends available"
        super().__init__(message)

    @property
    def skipped_backends(self) -> List[str]:
        return [c.backend for c in self.causes if c.skipped]

    def to_dict(self) -> dict:
        return {"causes": [c.to_dict() for c in self.causes]}


class InjectedFault(ConnectionError):
    """An error raised by the ``FaultInjector`` — typed so chaos tests can
    distinguish injected failures from organic ones, and so availability
    accounting in the chaos harness attributes errors correctly."""

    def __init__(self, message: str, kind: str = "error", backend: str = ""):
        super().__init__(message)
        self.kind = kind
        self.backend = backend
