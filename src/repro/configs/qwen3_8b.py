"""qwen3-8b [dense] — GQA kv=8 with per-head RMS qk-norm.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
[hf:Qwen/Qwen3-8B; hf tier]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    max_seq_len=32768,
    attn_pattern=("global",),
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=False,
    loss_chunk=512,
    grad_accum=4,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        loss_chunk=0,
        attn_chunk=32,
        grad_accum=1,
    )
