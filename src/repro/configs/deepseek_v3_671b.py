"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, MTP.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
[arXiv:2412.19437; hf tier]
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,  # qk head dim (128 nope + 64 rope); v_head_dim = 128
    d_ff=18432,  # dense-layer FFN width (first 3 layers)
    vocab_size=129280,
    max_seq_len=131072,
    attn_pattern=("global",),
    rope_theta=10_000.0,
    act="silu",
    tie_embeddings=False,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        d_ff_shared=2048,
        capacity_factor=1.25,
        router="sigmoid_bias",  # aux-loss-free load balancing
        routed_scaling=2.5,
        first_k_dense=3,
        d_ff_dense=18432,
    ),
    mtp_depth=1,
    loss_chunk=512,
    optimizer="adamw8bit",  # 671B params: int8 block-quantized moments to fit HBM
    grad_accum=32,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=4,  # 1 dense + 3 MoE
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=24,
        d_ff=192,
        vocab_size=512,
        max_seq_len=512,
        mla=MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            d_ff_expert=64,
            num_shared_experts=1,
            d_ff_shared=64,
            capacity_factor=1.5,
            router="sigmoid_bias",
            routed_scaling=2.5,
            first_k_dense=1,
            d_ff_dense=192,
        ),
        mtp_depth=1,
        loss_chunk=0,
        attn_chunk=32,
        optimizer="adamw",
        grad_accum=1,
    )
