"""Embedding encoder configs for the cache's semantic similarity calculator.

The paper's measured default is facebook/contriever-msmarco (a BERT-base
bi-encoder with mean pooling, 110M params); e5-large-v2 is the second local
model in Fig 7. Both are expressed here as encoder configs for the JAX
encoder in repro.core.embeddings.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    max_seq_len: int = 512
    pooling: str = "mean"  # contriever-style mean pooling
    norm_eps: float = 1e-12
    dtype: str = "float32"


CONTRIEVER_MSMARCO = EncoderConfig(
    name="contriever-msmarco",
    num_layers=12,
    d_model=768,
    num_heads=12,
    d_ff=3072,
    vocab_size=30522,
)

E5_LARGE_V2 = EncoderConfig(
    name="e5-large-v2",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    d_ff=4096,
    vocab_size=30522,
)


def smoke() -> EncoderConfig:
    return EncoderConfig(
        name="contriever-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        d_ff=128,
        vocab_size=4096,
        max_seq_len=128,
    )
