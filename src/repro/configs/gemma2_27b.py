"""gemma2-27b [dense] — local+global alternating attention, logit softcapping.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
[arXiv:2408.00118; hf tier]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    max_seq_len=8192,
    attn_pattern=("local", "global"),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=144.0 ** -0.5,  # query_pre_attn_scalar = d_model / num_heads = 144
    rope_theta=10_000.0,
    post_norms=True,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
    loss_chunk=512,
    grad_accum=8,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=4,  # two local:global cycles
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        max_seq_len=512,
        window_size=16,
        query_scale=16.0 ** -0.5,
        loss_chunk=0,
        attn_chunk=32,
        grad_accum=1,
    )
