"""zamba2-7b [hybrid] — Mamba2 backbone + alternating shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Two *shared* transformer blocks (parameters reused across applications) are
applied after every 6 Mamba2 blocks, operating at 2*d_model on
concat(hidden, original_embeddings) and projected back to d_model.
[arXiv:2411.15242; unverified tier]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=224,  # shared attn runs at 2*d_model = 7168; 7168 / 32 = 224
    d_ff=14336,
    vocab_size=32000,
    max_seq_len=1_048_576,
    rope_theta=10_000.0,
    act="gelu",
    mlp_gated=False,  # shared-block MLP is a plain GELU FFN
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, headdim=64, ngroups=2, chunk_size=256),
    hybrid_period=6,
    num_shared_blocks=2,
    norm_eps=1e-5,
    loss_chunk=512,
    grad_accum=16,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=5,  # 2 hybrid groups of 2 + remainder 1
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,  # 2*d_model / num_heads = 128 / 4
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=32, ngroups=1, chunk_size=32),
        hybrid_period=2,
        num_shared_blocks=2,
        loss_chunk=0,
        attn_chunk=32,
        grad_accum=1,
    )
