"""Config system for the repro framework.

Every assigned architecture is expressed as a single frozen ``ModelConfig``;
reduced smoke variants preserve the family mechanisms (MoE stays MoE, MLA stays
MLA, hybrid stays hybrid) at tiny widths so they run a real forward/train step
on CPU in a pytest.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax"  # "softmax" | "sigmoid_bias" (DeepSeek aux-loss-free)
    routed_scaling: float = 1.0
    first_k_dense: int = 0  # leading dense (non-MoE) layers
    d_ff_dense: int = 0  # d_ff of those leading dense layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    chunk_size: int = 256


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    max_seq_len: int = 131072

    # --- attention pattern -------------------------------------------------
    # cycled over layers; entries: "global" | "local" | "nope_global"
    attn_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 0  # sliding window for "local" layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0  # 0 => same as rope_theta
    query_scale: float = 0.0  # 0 => 1/sqrt(head_dim)
    post_norms: bool = False  # gemma-style pre+post block norms
    act: str = "silu"  # "silu" | "gelu"
    mlp_gated: bool = True  # gated (SwiGLU/GeGLU) vs plain 2-matrix MLP
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d_model)

    # --- family sub-configs --------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # --- hybrid (zamba2) ------------------------------------------------------
    hybrid_period: int = 0  # apply a shared attn block after every N ssm blocks
    num_shared_blocks: int = 0  # alternating shared attention blocks

    # --- modality frontends (stubs per assignment) ----------------------------
    modality: str = "text"  # text | vision | audio
    num_codebooks: int = 0  # musicgen: EnCodec codebooks
    vision_patches: int = 0  # llava stub: number of patch embeddings per image
    d_frontend: int = 0  # dim of stub frontend embeddings

    # --- multi-token prediction (deepseek-v3) ---------------------------------
    mtp_depth: int = 0

    # --- numerics / performance knobs ------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 0  # chunk CE over the sequence axis; 0 = off
    attn_chunk: int = 1024  # query-chunk for memory-safe jnp attention
    # use Pallas kernels where available; the interpret-vs-compiled backend is
    # auto-selected per jax.default_backend() (CPU -> interpret), overridable
    # via kernel_interpret / REPRO_KERNEL_INTERPRET (repro.kernels.backend)
    use_pallas: bool = False
    kernel_interpret: Optional[bool] = None  # None = auto-select per backend
    # similarity-top-k kernel tuning defaults, baked from the
    # benchmarks/tune_topk.py sweep (block 512 / lanes_outer won the
    # CPU-interpret smoke sweep — a smoke signal ONLY; re-run the sweep on
    # real TPU/GPU hardware and update these). The REPRO_TOPK_BLOCK_N /
    # REPRO_TOPK_GRID_ORDER env vars always win over these config values.
    topk_block_n: Optional[int] = 512  # positive multiple of 128; None = leave env/default
    topk_grid_order: Optional[str] = "lanes_outer"  # lanes_outer | blocks_outer | None
    optimizer: str = "adamw"  # "adamw" | "adamw8bit"
    grad_accum: int = 1  # microbatch count for train_step
    unroll: bool = False  # python-loop layers instead of lax.scan (exact HLO cost accounting)
    remat_policy: str = "full"  # "full" (save nothing) | "dots" (save matmul outputs)
    infer_params_tp_only: bool = False  # replicate params over `data` at inference (no FSDP AGs)
    kv_cache_dtype: str = ""  # KV cache storage dtype ("" = model dtype; e.g. "float8_e4m3fn")
    opt_pod_sharded: bool = False  # cross-pod ZeRO-1: shard optimizer state over `pod` (DCN)
    gqa_repeat_kv: bool = False  # materialize repeated KV so attention stays H-sharded on TP

    # -----------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.headdim if self.ssm else 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds for the whole stack."""
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        if self.family == "hybrid":
            return ("ssm",) * self.num_layers  # shared attn handled separately
        kinds = []
        for i in range(self.num_layers):
            kinds.append(self.attn_pattern[i % len(self.attn_pattern)])
        return tuple(kinds)

    def active_params_per_token(self) -> int:
        """N_active for 6*N*D MODEL_FLOPS accounting (embeddings excluded)."""
        d, l = self.d_model, self.num_layers
        if self.family in ("ssm", "hybrid"):
            ssm = self.ssm
            di = self.d_inner
            conv_dim = di + 2 * ssm.ngroups * ssm.d_state
            per = (
                d * (2 * di + 2 * ssm.ngroups * ssm.d_state + self.ssm_heads)  # in_proj
                + conv_dim * ssm.d_conv
                + di * d  # out_proj
            )
            n = l * per
            if self.family == "hybrid" and self.hybrid_period:
                n_shared_applications = self.num_layers // self.hybrid_period
                dm2 = 2 * d
                att = 2 * (
                    dm2 * self.num_heads * self.head_dim
                    + dm2 * 2 * self.num_kv_heads * self.head_dim
                    + self.num_heads * self.head_dim * dm2
                    + 3 * dm2 * self.d_ff
                ) // 2 + dm2 * d
                n += n_shared_applications * att
            return n
        if self.mla:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.num_heads * m.qk_head_dim
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * d
            )
        else:
            attn = (
                d * self.num_heads * self.head_dim
                + 2 * d * self.num_kv_heads * self.head_dim
                + self.num_heads * self.head_dim * d
            )
        if self.moe:
            mo = self.moe
            moe_ffn = 3 * d * mo.d_ff_expert * mo.top_k
            moe_ffn += 3 * d * mo.d_ff_shared * mo.num_shared_experts
            dense_ffn = 3 * d * (mo.d_ff_dense or self.d_ff)
            n = (
                mo.first_k_dense * (attn + dense_ffn)
                + (l - mo.first_k_dense) * (attn + moe_ffn)
            )
        else:
            n = l * (attn + 3 * d * self.d_ff)
        return n

    def total_params(self) -> int:
        """Approximate total parameter count (for memory napkin math)."""
        d, l = self.d_model, self.num_layers
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.moe:
            mo = self.moe
            per_moe = 3 * d * mo.d_ff_expert * mo.num_experts
            per_moe += 3 * d * mo.d_ff_shared * mo.num_shared_experts
            per_moe += d * mo.num_experts  # router
            dense = 3 * d * (mo.d_ff_dense or self.d_ff)
            n += mo.first_k_dense * dense + (l - mo.first_k_dense) * per_moe
            attn_active = self.active_params_per_token()
            # attention part of active == attention part of total
            if self.mla:
                m = self.mla
                attn = (
                    d * m.q_lora_rank
                    + m.q_lora_rank * self.num_heads * m.qk_head_dim
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d
                )
            else:
                attn = (
                    d * self.num_heads * self.head_dim
                    + 2 * d * self.num_kv_heads * self.head_dim
                    + self.num_heads * self.head_dim * d
                )
            n += l * attn
            return n
        return n + self.active_params_per_token()


# ---------------------------------------------------------------------------
# Input shapes (assignment)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Archs allowed to run long_500k (sub-quadratic / bounded-KV); see DESIGN.md.
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "zamba2-7b", "gemma3-4b", "gemma2-27b")


def cells_for(arch_name: str):
    """The (shape) cells this arch runs in the dry-run."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
