"""qwen1.5-0.5b [dense] — MHA with QKV bias.

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
[hf:Qwen/Qwen1.5-0.5B; hf tier]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    max_seq_len=32768,
    attn_pattern=("global",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=True,
    loss_chunk=512,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        loss_chunk=0,
        attn_chunk=32,
    )
