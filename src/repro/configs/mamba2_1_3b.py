"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 vocab=50280, ssm_state=128.
[arXiv:2405.21060; unverified tier]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=64,  # d_inner / headdim = 4096 / 64
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    max_seq_len=1_048_576,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, ngroups=1, chunk_size=256),
    norm_eps=1e-5,
    tie_embeddings=True,
    loss_chunk=512,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=3,
        d_model=64,
        num_heads=4,
        head_dim=16,
        vocab_size=512,
        max_seq_len=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=32, ngroups=1, chunk_size=32),
        loss_chunk=0,
    )
