"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 per codebook, 4 codebooks
with a delay pattern. Only the transformer BACKBONE is built; the EnCodec
encoder/decoder frontend is a STUB per the assignment — inputs are the 4
codebook token streams, which *are* the frame-token interface.
[arXiv:2306.05284; hf tier]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    max_seq_len=32768,
    attn_pattern=("global",),
    rope_theta=10_000.0,  # adaptation: RoPE in place of sinusoidal embeds (DESIGN.md)
    act="gelu",
    mlp_gated=False,  # standard 2-matrix transformer FFN
    tie_embeddings=False,
    modality="audio",
    num_codebooks=4,
    loss_chunk=0,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        max_seq_len=512,
        num_codebooks=4,
        attn_chunk=32,
    )
