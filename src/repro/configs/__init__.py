"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from repro.configs.base import (
    LONG_CONTEXT_ARCHS,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SHAPES,
    ShapeSpec,
    SSMConfig,
    cells_for,
)

from repro.configs import (
    deepseek_v3_671b,
    gemma2_27b,
    gemma3_4b,
    llama4_scout,
    llava_next_mistral_7b,
    mamba2_1_3b,
    musicgen_large,
    qwen15_0_5b,
    qwen3_8b,
    zamba2_7b,
)

_MODULES = {
    "gemma3-4b": gemma3_4b,
    "qwen1.5-0.5b": qwen15_0_5b,
    "gemma2-27b": gemma2_27b,
    "qwen3-8b": qwen3_8b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "llama4-scout-17b-a16e": llama4_scout,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "mamba2-1.3b": mamba2_1_3b,
    "musicgen-large": musicgen_large,
    "zamba2-7b": zamba2_7b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[name]
    return mod.smoke() if smoke else mod.CONFIG


__all__ = [
    "ARCH_NAMES",
    "LONG_CONTEXT_ARCHS",
    "MLAConfig",
    "MoEConfig",
    "ModelConfig",
    "SHAPES",
    "SSMConfig",
    "ShapeSpec",
    "cells_for",
    "get_config",
]
