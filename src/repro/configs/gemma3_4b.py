"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
[hf:google/gemma-3-4b-pt family; unverified tier per assignment]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    max_seq_len=131072,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    post_norms=True,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
    loss_chunk=512,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=6,  # one full local:global pattern cycle
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        window_size=16,
        loss_chunk=0,
        attn_chunk=32,
    )
