"""llava-next-mistral-7b [vlm] — mistral-7b backbone, anyres tiling.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
Modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings [B, num_patches, d_frontend]; the model owns the
2-layer MLP projector into the backbone width.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified tier]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    max_seq_len=32768,
    attn_pattern=("global",),
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=False,
    modality="vision",
    vision_patches=2880,  # anyres: 5 tiles x 576 patches (24x24 @ CLIP-L/14, 336px)
    d_frontend=1024,  # CLIP ViT-L/14 hidden size
    loss_chunk=512,
    grad_accum=4,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        vision_patches=8,
        d_frontend=32,
        loss_chunk=0,
        attn_chunk=32,
        grad_accum=1,
    )
