"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
Attention: 3 chunked-local (8192) layers : 1 global NoPE layer.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified tier]
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    max_seq_len=131072,
    attn_pattern=("local", "local", "local", "nope_global"),
    window_size=8192,  # chunked attention approximated as sliding window (DESIGN.md)
    qk_norm=True,
    rope_theta=500_000.0,
    act="silu",
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
        capacity_factor=1.25,
        router="softmax",
    ),
    loss_chunk=512,
    grad_accum=8,
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        num_layers=4,  # one local/local/local/nope_global cycle
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=512,
        window_size=16,
        moe=MoEConfig(
            num_experts=4,
            top_k=1,
            d_ff_expert=128,
            num_shared_experts=1,
            d_ff_shared=128,
            capacity_factor=1.5,
            router="softmax",
        ),
        loss_chunk=0,
        attn_chunk=32,
        grad_accum=1,
    )
