"""ShapeDtypeStruct stand-ins for every model input (assignment step 2).

``input_specs(cfg, shape_name)`` returns weak-type-correct, shardable,
allocation-free abstract inputs for the step function the cell lowers:
train_step (train_*), prefill (prefill_*), or decode_step (decode_* /
long_*). ``abstract_params`` / ``abstract_cache`` eval_shape the real
constructors so dry-run shapes can never drift from the real ones.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import BATCH
from repro.models import transformer as T

I32 = jnp.int32
BF16 = jnp.bfloat16


def abstract_params(cfg: ModelConfig) -> Tuple[Any, Any]:
    captured = {}

    def build(k):
        p, s = T.init_params(cfg, k)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Tuple[Any, Any]:
    captured = {}

    def build():
        c, s = T.init_cache(cfg, batch, max_seq)
        captured["specs"] = s
        return c

    shapes = jax.eval_shape(build)
    return shapes, captured["specs"]


def _token_batch(cfg: ModelConfig, batch: int, seq: int) -> Tuple[Dict, Dict]:
    """(abstract batch dict, batch specs dict) for the given token count."""
    if cfg.modality == "audio":
        return (
            {"tokens": jax.ShapeDtypeStruct((batch, cfg.num_codebooks, seq), I32)},
            {"tokens": (BATCH, None, None)},
        )
    if cfg.modality == "vision":
        text = max(seq - cfg.vision_patches, 16)
        return (
            {
                "tokens": jax.ShapeDtypeStruct((batch, text), I32),
                "vision_embeds": jax.ShapeDtypeStruct((batch, cfg.vision_patches, cfg.d_frontend), BF16),
            },
            {"tokens": (BATCH, None), "vision_embeds": (BATCH, None, None)},
        )
    return (
        {"tokens": jax.ShapeDtypeStruct((batch, seq), I32)},
        {"tokens": (BATCH, None)},
    )


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Everything dryrun.py needs to lower one (arch x shape) cell."""
    if shape.kind == "train":
        batch, batch_specs = _token_batch(cfg, shape.global_batch, shape.seq_len)
        return {"kind": "train", "batch": batch, "batch_specs": batch_specs}

    if shape.kind == "prefill":
        batch, batch_specs = _token_batch(cfg, shape.global_batch, shape.seq_len)
        cache, cache_specs = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        return {
            "kind": "prefill",
            "batch": batch,
            "batch_specs": batch_specs,
            "cache": cache,
            "cache_specs": cache_specs,
        }

    # decode: one new token against a seq_len-deep cache
    B = shape.global_batch
    if cfg.modality == "audio":
        tokens = jax.ShapeDtypeStruct((B, cfg.num_codebooks, 1), I32)
        tok_spec = (BATCH, None, None)
    else:
        tokens = jax.ShapeDtypeStruct((B, 1), I32)
        tok_spec = (BATCH, None)
    cache, cache_specs = abstract_cache(cfg, B, shape.seq_len)
    return {
        "kind": "decode",
        "tokens": tokens,
        "tokens_spec": tok_spec,
        "pos": jax.ShapeDtypeStruct((B,), I32),
        "pos_spec": (BATCH,),
        "cache": cache,
        "cache_specs": cache_specs,
    }
