import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input-shape) cell, lower + compile the real step
function (train_step / prefill / decode_step) against the production mesh —
single-pod (16, 16) and multi-pod (2, 16, 16) — with full production
shardings, and record:

  * memory_analysis()  — per-device bytes (argument/output/temp) => fits HBM?
  * cost_analysis()    — per-device HLO FLOPs / bytes accessed (roofline)
  * collective bytes   — parsed from the partitioned HLO (hlo_analysis.py)

plus a `cache_lookup` pseudo-cell lowering the paper's sharded cache search
on the same meshes. Results append incrementally to a JSON file so a long
sweep resumes where it left off.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single   # roofline pass
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, SHAPES, cells_for, get_config
from repro.distributed.sharding import shardings_for, use_mesh
from repro.launch.hlo_analysis import parse_collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import abstract_params, input_specs
from repro.models import transformer as T
from repro.training.train_loop import abstract_train_state, make_train_step

HBM_PER_CHIP = 16 * 1024**3  # v5e


def _mem_stats(compiled):
    m = compiled.memory_analysis()
    return {
        "argument_bytes": int(m.argument_size_in_bytes),
        "output_bytes": int(m.output_size_in_bytes),
        "temp_bytes": int(m.temp_size_in_bytes),
        "alias_bytes": int(m.alias_size_in_bytes),
        "code_bytes": int(m.generated_code_size_in_bytes),
    }


def _cost_stats(compiled):
    c = compiled.cost_analysis() or {}
    return {
        "flops": float(c.get("flops", 0.0)),
        "transcendentals": float(c.get("transcendentals", 0.0)),
        "bytes_accessed": float(c.get("bytes accessed", 0.0)),
    }


def lower_cell(arch: str, shape_name: str, mesh, *, parse_hlo: bool = True, cfg=None,
               adapt_accum: bool = True):
    """Lower + compile one cell. Returns the result record."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    # memory-optimal grad accumulation: one sequence per batch shard per
    # microbatch. More accumulation can't shard (activations replicate when
    # mb < shards); less holds needlessly many sequences live. Cost-extraction
    # configs pass adapt_accum=False (the accum scan is a while loop whose
    # body XLA's cost analysis counts once — accum must stay 1 there).
    if adapt_accum:
        batch_shards = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                batch_shards *= mesh.shape[a]
        max_accum = max(shape.global_batch // batch_shards, 1)
        if shape.kind == "train" and cfg.grad_accum != max_accum:
            cfg = dataclasses.replace(cfg, grad_accum=max_accum)
    spec = input_specs(cfg, shape)
    n_dev = len(mesh.devices.flatten())

    t0 = time.time()
    with use_mesh(mesh):
        if spec["kind"] == "train":
            state, state_specs = abstract_train_state(cfg)
            train_step = make_train_step(cfg)
            in_shardings = (
                shardings_for(mesh, state_specs, state),
                shardings_for(mesh, spec["batch_specs"], spec["batch"]),
            )
            fn = jax.jit(train_step, in_shardings=in_shardings, donate_argnums=(0,))
            lowered = fn.lower(state, spec["batch"])
        elif spec["kind"] == "prefill":
            params, param_specs = abstract_params(cfg)
            if getattr(cfg, "infer_params_tp_only", False):
                param_specs = despec_params_for_inference(param_specs)

            def prefill_fn(p, batch, cache):
                return T.prefill(p, cfg, batch, cache)

            in_shardings = (
                shardings_for(mesh, param_specs, params),
                shardings_for(mesh, spec["batch_specs"], spec["batch"]),
                shardings_for(mesh, spec["cache_specs"], spec["cache"]),
            )
            fn = jax.jit(prefill_fn, in_shardings=in_shardings, donate_argnums=(2,))
            lowered = fn.lower(params, spec["batch"], spec["cache"])
        else:  # decode
            params, param_specs = abstract_params(cfg)
            if getattr(cfg, "infer_params_tp_only", False):
                param_specs = despec_params_for_inference(param_specs)

            def decode_fn(p, tokens, pos, cache):
                return T.decode_step(p, cfg, tokens, pos, cache)

            in_shardings = (
                shardings_for(mesh, param_specs, params),
                shardings_for(mesh, spec["tokens_spec"], spec["tokens"]),
                shardings_for(mesh, spec["pos_spec"], spec["pos"]),
                shardings_for(mesh, spec["cache_specs"], spec["cache"]),
            )
            fn = jax.jit(decode_fn, in_shardings=in_shardings, donate_argnums=(3,))
            lowered = fn.lower(params, spec["tokens"], spec["pos"], spec["cache"])

        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev,
        "kind": spec["kind"],
        "lower_s": round(lower_s, 1),
        "compile_s": round(compile_s, 1),
        "memory": _mem_stats(compiled),
        "cost": _cost_stats(compiled),
    }
    total_dev_bytes = sum(
        rec["memory"][k] for k in ("argument_bytes", "output_bytes", "temp_bytes")
    ) - rec["memory"]["alias_bytes"]
    rec["bytes_per_device"] = int(total_dev_bytes)
    rec["fits_hbm"] = bool(total_dev_bytes <= HBM_PER_CHIP)
    if parse_hlo:
        txt = compiled.as_text()
        rec["collective_bytes_per_device"] = parse_collective_bytes(txt)
        rec["hlo_len"] = len(txt)
    return rec


def despec_params_for_inference(specs):
    """Drop the FSDP (`data`) axis from parameter specs: inference wants
    TP-sharded + data-replicated weights (no per-layer all-gathers). Only
    valid when params fit HBM at 1/TP scale — deepseek-v3 (84 GB/chip at
    TP=16) must keep FSDP."""

    def one(spec):
        if spec is None:
            return None
        out = []
        for e in spec:
            if e == "data":
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != "data")
                out.append(kept if kept else None)
            else:
                out.append(e)
        return tuple(out)

    from repro.distributed.sharding import is_spec_leaf
    import jax

    return jax.tree.map(one, specs, is_leaf=is_spec_leaf)


def _unit_layers(cfg):
    """Smallest + double-depth configs whose layer composition matches the
    full stack's repeating unit (pattern cycle / hybrid group)."""
    if cfg.family == "hybrid":
        u = cfg.hybrid_period
    elif cfg.family == "ssm":
        u = 1
    else:
        u = len(cfg.attn_pattern)
    base = cfg.moe.first_k_dense if cfg.moe else 0
    # slope over 2 units: calibration vs a fully-unrolled qwen1.5-0.5b ground
    # truth gives flops within ~8%, bytes within ~30%, collectives exact
    # (EXPERIMENTS.md §Roofline methodology)
    return base + u, base + 3 * u


def extrapolate_costs(arch: str, shape_name: str, mesh, cfg=None):
    """Exact per-device cost terms via two small *unrolled* compiles.

    XLA's cost_analysis counts while-loop bodies once, so the scanned
    full-depth compile undercounts by ~L x. Lowering the SAME cell at unit
    depth L1 and 2x-unit depth L2 with every loop unrolled gives exact
    HLO costs whose per-layer slope extrapolates linearly to full depth:
        total(L) = f(L1) + (f(L2) - f(L1)) / (L2 - L1) * (L - L1).
    grad_accum is folded to 1 (same total tokens -> identical FLOPs; the
    memory term is the one-pass equivalent, see EXPERIMENTS.md note).
    """
    cfg = cfg if cfg is not None else get_config(arch)
    L_full = cfg.num_layers
    L1, L2 = _unit_layers(cfg)
    points = {}
    for L in (L1, L2):
        cfg_s = dataclasses.replace(cfg, num_layers=L, unroll=True, grad_accum=1)
        rec = lower_cell(arch, shape_name, mesh, parse_hlo=True, cfg=cfg_s, adapt_accum=False)
        points[L] = rec

    def lerp(get):
        f1, f2 = get(points[L1]), get(points[L2])
        slope = (f2 - f1) / (L2 - L1)
        return f1 + slope * (L_full - L1)

    coll_keys = set(points[L1]["collective_bytes_per_device"]) | set(
        points[L2]["collective_bytes_per_device"]
    )
    return {
        "method": f"unrolled L={L1},{L2} -> {L_full}",
        "flops": lerp(lambda r: r["cost"]["flops"]),
        "bytes_accessed": lerp(lambda r: r["cost"]["bytes_accessed"]),
        "transcendentals": lerp(lambda r: r["cost"]["transcendentals"]),
        "collectives": {
            k: lerp(lambda r: r["collective_bytes_per_device"].get(k, 0.0)) for k in coll_keys
        },
        "compile_s": points[L1]["compile_s"] + points[L2]["compile_s"],
    }


def lower_cache_lookup(mesh, *, n_entries: int = 1 << 20, dim: int = 768, q: int = 16, k: int = 8):
    """Lower the paper's sharded cache lookup on the production mesh."""
    from repro.distributed.sharded_store import make_sharded_lookup

    n_dev = len(mesh.devices.flatten())
    n = n_entries - (n_entries % n_dev)
    lookup = make_sharded_lookup(mesh, k=k)
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    from jax.sharding import NamedSharding, PartitionSpec as P

    axis = axes if len(axes) > 1 else axes[0]
    db = jax.ShapeDtypeStruct((n, dim), jnp.float32)
    valid = jax.ShapeDtypeStruct((n,), jnp.bool_)
    qv = jax.ShapeDtypeStruct((q, dim), jnp.float32)
    fn = jax.jit(
        lookup,
        in_shardings=(
            NamedSharding(mesh, P(axis, None)),
            NamedSharding(mesh, P(axis)),
            NamedSharding(mesh, P()),
        ),
    )
    t0 = time.time()
    lowered = fn.lower(db, valid, qv)
    compiled = lowered.compile()
    rec = {
        "arch": "cache_lookup",
        "shape": f"n{n_entries >> 20}m_d{dim}_q{q}_k{k}",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": n_dev,
        "kind": "cache",
        "compile_s": round(time.time() - t0, 1),
        "memory": _mem_stats(compiled),
        "cost": _cost_stats(compiled),
        "collective_bytes_per_device": parse_collective_bytes(compiled.as_text()),
    }
    total = sum(rec["memory"][k] for k in ("argument_bytes", "output_bytes", "temp_bytes"))
    rec["bytes_per_device"] = int(total)
    rec["fits_hbm"] = bool(total <= HBM_PER_CHIP)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all for arch)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--skip-cache-cell", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--force", action="store_true", help="redo cells already in --out")
    args = ap.parse_args()

    results = []
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results if "error" not in r}

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.mesh in ("multi", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    for mesh in meshes:
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
        for arch in archs:
            shapes = [args.shape] if args.shape else cells_for(arch)
            for shape_name in shapes:
                key = (arch, shape_name, mesh_name)
                if key in done:
                    print(f"[skip] {key}")
                    continue
                print(f"[cell] {arch} x {shape_name} on {mesh_name} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh)
                    if len(mesh.axis_names) == 2 and not args.no_extrapolate:
                        # roofline cost terms (single-pod pass only)
                        rec["cost_extrapolated"] = extrapolate_costs(arch, shape_name, mesh)
                    gb = rec["bytes_per_device"] / 2**30
                    flops = rec.get("cost_extrapolated", rec["cost"])["flops"]
                    print(
                        f"  ok  compile={rec['compile_s']}s mem/dev={gb:.2f}GiB "
                        f"fits={rec['fits_hbm']} flops/dev={flops:.3e}",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"  FAIL {type(e).__name__}: {str(e)[:200]}", flush=True)
                results = [r for r in results if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
        if not args.skip_cache_cell:
            key = ("cache_lookup", "n1m_d768_q16_k8", mesh_name)
            if key not in done:
                print(f"[cell] cache_lookup on {mesh_name} ...", flush=True)
                rec = lower_cache_lookup(mesh)
                results = [r for r in results if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                print(f"  ok  compile={rec['compile_s']}s", flush=True)

    n_ok = sum(1 for r in results if "error" not in r)
    print(f"\ndone: {n_ok}/{len(results)} cells compiled clean -> {args.out}")


if __name__ == "__main__":
    main()
