"""Fault-tolerant training driver.

Features (scaled-down single-process embodiment of the 1000-node design,
DESIGN.md §5):
  * checkpoint/restart — atomic manifest commits every --ckpt-every steps;
    on start, resumes from the latest valid checkpoint (params + optimizer
    + step + dataloader cursor), restoring onto whatever mesh is current
    (elastic re-shard).
  * preemption handling — SIGTERM/SIGINT trigger a final checkpoint before
    exit, so a preempted worker loses at most one step.
  * straggler mitigation — the data pipeline is positionally deterministic
    (loader.py), so a replacement host reproduces any batch without peer
    coordination; per-step wall-time is logged and steps slower than
    --straggler-factor x the trailing median are flagged (on real fleets
    this feeds the scheduler's hot-spare swap).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import statistics
import sys
import time

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.loader import ShardedLoader
from repro.training.train_loop import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=None, help="default: steps // 10")
    ap.add_argument("--total-steps", type=int, default=None,
                    help="LR schedule horizon (default: --steps); lets a partial "
                         "run share the schedule of the full job it resumes")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.kernel_interpret is not None:
        from repro.kernels.backend import set_interpret_override

        set_interpret_override(cfg.kernel_interpret)
    # top-k kernel tuning defaults from the benchmarks/tune_topk.py sweep
    # (CPU-interpret winners are a smoke signal only — re-sweep on real
    # hardware); explicit REPRO_TOPK_* env vars win over the config
    from repro.kernels.similarity_topk.ops import apply_topk_tuning

    apply_topk_tuning(cfg.topk_block_n, cfg.topk_grid_order)
    state, specs = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    loader = ShardedLoader(cfg.vocab_size, args.global_batch, args.seq_len, seed=args.seed)

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, extra = restore_checkpoint(args.ckpt_dir, state)
        loader.restore(extra["loader"])
        start = int(extra["loader"]["step"])
        print(f"[restore] resumed at step {start}")

    warmup = args.warmup if args.warmup is not None else max(args.steps // 10, 1)
    horizon = args.total_steps or args.steps
    train_step = jax.jit(
        make_train_step(cfg, peak_lr=args.lr, warmup_steps=warmup, total_steps=horizon),
        donate_argnums=(0,),
    )

    stop = {"now": False}

    def _sig(_signo, _frame):
        print("[preempt] signal received — checkpointing before exit", flush=True)
        stop["now"] = True

    signal.signal(signal.SIGTERM, _sig)
    signal.signal(signal.SIGINT, _sig)

    def checkpoint(step):
        if args.ckpt_dir:
            path = save_checkpoint(args.ckpt_dir, step, state, extra={"loader": loader.state()})
            print(f"[ckpt] step {step} -> {path}", flush=True)

    step_times = []
    losses = []
    for step in range(start, args.steps):
        batch = next(loader)
        t0 = time.perf_counter()
        state, metrics = train_step(state, {"tokens": batch["tokens"]})
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        step_times.append(dt)
        losses.append(loss)
        if len(step_times) >= 8:
            med = statistics.median(step_times[-20:])
            if dt > args.straggler_factor * med:
                print(f"[straggler] step {step} took {dt:.2f}s (median {med:.2f}s)", flush=True)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} grad_norm "
                  f"{float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e} "
                  f"({dt:.2f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint(step + 1)
        if stop["now"]:
            checkpoint(step + 1)
            sys.exit(0)

    checkpoint(args.steps)
    print(f"final loss {losses[-1]:.4f} (uniform = {np.log(cfg.vocab_size):.4f})")
    return losses


if __name__ == "__main__":
    main()
