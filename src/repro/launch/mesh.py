"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only
dryrun.py is allowed to force 512 host devices).

Axes:
  pod   — DCN axis across pods (multi-pod only)
  data  — in-pod data-parallel / FSDP / context-parallel axis
  model — tensor/expert-parallel axis
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType

    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))

except ImportError:  # older jax: meshes are implicitly Auto

    def _mesh(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (requires >= prod(shape) devices)."""
    return _mesh(shape, axes)


def make_cache_mesh(n_shards=None):
    """1-axis ("data",) mesh for a sharded cache DB: the store's key-sharded
    lanes spread over ``n_shards`` devices (default: all available). The
    sharded read path only collectives over pod/data axes, so a cache-only
    deployment never needs a model axis."""
    n = len(jax.devices()) if n_shards is None else int(n_shards)
    return _mesh((n,), ("data",))
