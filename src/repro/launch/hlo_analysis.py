"""Post-compile HLO analysis: collective-byte accounting for the roofline.

collective_bytes is not in cost_analysis(), so we parse the partitioned HLO
module text: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction contributes the byte size of its operands
(per-device shapes — the module is post-SPMD-partitioning). Instructions
inside `while` bodies are weighted by the loop trip count (scan-over-layers
puts every per-layer collective inside a while), recovered from the loop
condition's comparison constant.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^\s*%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST_DEF_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\).*direction=(LT|GT|LE|GE)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _trip_count_of(cond_lines: List[str]) -> int:
    """Trip count from a while condition: the constant operand of the loop
    bound compare (canonical scan conds are `iter < constant(N)`)."""
    consts = {}
    for line in cond_lines:
        m = _CONST_DEF_RE.match(line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        m = _COMPARE_RE.search(line)
        if m:
            ops = re.findall(r"%([\w.\-]+)", m.group(1))
            bound = [consts[o] for o in ops if o in consts]
            if bound:
                return max(bound[0], 1)
            # constant inlined in the compare operand list: `s32[] constant(8)`
            inline = re.search(r"constant\((\d+)\)", m.group(1))
            if inline:
                return max(int(inline.group(1)), 1)
    return 1


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Returns per-device bytes by collective kind (while-body weighted)."""
    # 1. split into computations
    comps: Dict[str, List[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        if line.startswith("%") or (line and not line.startswith(" ") and "{" in line):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if m and "{" in line:
                current = m.group(1)
                comps[current] = []
                continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)

    # 2. symbol table: name -> bytes(result type), per computation
    def_types: Dict[Tuple[str, str], str] = {}
    for cname, lines in comps.items():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                def_types[(cname, m.group(1))] = m.group(2)

    # 3. trip counts: while(...) condition compares against a constant
    trip_count: Dict[str, int] = {}  # body computation -> n
    parent_of: Dict[str, str] = {}  # body computation -> computation containing the while
    for cname, lines in comps.items():
        for line in lines:
            if "while(" in line:
                m = _WHILE_RE.search(line)
                if not m:
                    continue
                cond, body = m.group(1), m.group(2)
                n = _trip_count_of(comps.get(cond, []))
                trip_count[body] = n
                parent_of[body] = cname

    def weight_of(cname: str) -> int:
        w = 1
        seen = set()
        while cname in trip_count and cname not in seen:
            seen.add(cname)
            w *= trip_count[cname]
            cname = parent_of.get(cname, "")
        return w

    # 4. accumulate collective operand bytes
    out: Dict[str, float] = defaultdict(float)
    for cname, lines in comps.items():
        w = weight_of(cname)
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            op = None
            for c in COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", rhs):
                    op = c
                    break
            if op is None:
                continue
            # operand bytes: types inline if present, else look up operand names
            paren = rhs[rhs.index("(") + 1 :]
            operand_bytes = _type_bytes(paren)
            if operand_bytes == 0:
                for name in re.findall(r"%([\w.\-]+)", paren):
                    t = def_types.get((cname, name))
                    if t:
                        operand_bytes += _type_bytes(t)
            out[op] += w * operand_bytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, opcodes=("fusion", "dot", "convolution")) -> Dict[str, int]:
    out = {}
    for op in opcodes:
        out[op] = len(re.findall(rf"=\s*\S+\s+{op}\(", hlo_text))
    return out
