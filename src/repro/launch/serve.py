"""Serving driver: a zoo model behind the GenerativeCache-fronted client.

Runs batched requests (paraphrase-clustered synthetic queries) through the
full stack — embed -> semantic/generative lookup -> miss -> continuous-
batching engine -> insert — and prints hit-rate / latency / cost stats.

With ``--coalesce`` the driver simulates concurrent users against the
async-first ``CacheService``: each user submits a ``CacheRequest`` and gets
a future; the priority-aware front scheduler micro-batches the lookups (one
embed forward + one store search per admitted batch), hit futures resolve
immediately, and the miss residue coalesces by priority into engine passes
in the background. ``--deadline-ms`` attaches a deadline to every request:
misses that would outwait it resolve with a typed ``deadline_exceeded``
response instead of generating.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --requests 40
  PYTHONPATH=src python -m repro.launch.serve --coalesce --coalesce-batch 8
  PYTHONPATH=src python -m repro.launch.serve --coalesce --deadline-ms 2000
"""
from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

from repro.configs import get_config
from repro.core import CacheRequest, EnhancedClient, GenerativeCache, NgramHashEmbedder
from repro.core.adaptive import ModelCostInfo
from repro.data.synthetic import squad_like_qa
from repro.serving.engine import ModelBackend, ServingEngine
from repro.serving.service import CacheService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--threshold", type=float, default=0.6)
    ap.add_argument("--coalesce", action="store_true",
                    help="serve concurrent requests through the async CacheService")
    ap.add_argument("--coalesce-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--concurrency", type=int, default=16,
                    help="simulated concurrent users (--coalesce only)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline; 0 disables (--coalesce only)")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve the OpenAI-compatible HTTP gateway on PORT "
                         "instead of running the replay driver")
    ap.add_argument("--http-pace-ms", type=float, default=0.0,
                    help="SSE pacing between streamed chunks of a cached "
                         "replay (--http only)")
    ap.add_argument("--shards", type=int, default=0,
                    help="key-shard a shared L2 store over an N-device cache "
                         "mesh behind the replicated L1 (0 = L1 only); reads "
                         "go through the one-dispatch collective program")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    if cfg.kernel_interpret is not None:
        # config override for the kernel backend matrix (default: interpret
        # on CPU, compiled Pallas on TPU/GPU — repro.kernels.backend)
        from repro.kernels.backend import set_interpret_override

        set_interpret_override(cfg.kernel_interpret)
    # top-k kernel tuning defaults from the benchmarks/tune_topk.py sweep
    # (CPU-interpret winners are a smoke signal only — re-sweep on real
    # hardware); explicit REPRO_TOPK_* env vars win over the config
    from repro.kernels.similarity_topk.ops import apply_topk_tuning

    apply_topk_tuning(cfg.topk_block_n, cfg.topk_grid_order)
    engine = ServingEngine(cfg, max_batch=args.max_batch, max_seq=256)
    backend = ModelBackend(args.arch, engine)

    cache = GenerativeCache(
        NgramHashEmbedder(), threshold=args.threshold, t_single=0.45, t_combined=1.0
    )
    hierarchy = None
    if args.shards > 0:
        # sharded deployment: the hot L1 stays replicated, the shared L2's
        # DB lanes are key-sharded over a cache mesh, and the hierarchy
        # serves both through ONE collective read program
        # (repro.distributed.sharded_read)
        import jax

        from repro.core import HierarchicalCache
        from repro.distributed.sharded_store import ShardedVectorStore
        from repro.launch.mesh import make_cache_mesh

        mesh = make_cache_mesh(min(args.shards, len(jax.devices())))
        emb = cache.embedder
        l2 = GenerativeCache(
            emb, threshold=args.threshold, t_single=0.45, t_combined=1.0,
            store=ShardedVectorStore(mesh, emb.dim, 4096, k=4),
        )
        hierarchy = HierarchicalCache(cache, l2)
    client = EnhancedClient(cache=cache, hierarchy=hierarchy)
    client.register_backend(backend, ModelCostInfo(0.5, 1.5, 3.0))

    if args.http is not None:
        # real serving surface: the gateway owns the service and drains it
        # (in-flight futures resolve) on Ctrl-C
        from repro.gateway.app import serve_in_thread

        service = CacheService(
            client, max_batch=args.coalesce_batch, max_wait_ms=args.max_wait_ms
        )
        runner = serve_in_thread(
            service, port=args.http, pace_ms=args.http_pace_ms, own_service=True
        )
        host, port = runner.gateway.http.host, runner.gateway.port
        print(f"gateway listening on http://{host}:{port}")
        print(f"  POST http://{host}:{port}/v1/chat/completions")
        print(f"  POST http://{host}:{port}/v1/completions")
        print(f"  GET  http://{host}:{port}/healthz")
        print(f"  GET  http://{host}:{port}/v1/cache/stats")
        try:
            while True:
                time.sleep(0.5)
        except KeyboardInterrupt:
            pass
        clean = runner.stop()
        print(f"drained {'clean' if clean else 'DIRTY'}; "
              f"served={runner.gateway.http.requests_served}")
        return

    qa = squad_like_qa(n_clusters=max(args.requests // 4, 2), paraphrases=4)
    queries = [q for q, _, _ in qa][: args.requests]

    t0 = time.perf_counter()
    if args.coalesce:
        service = CacheService(
            client, max_batch=args.coalesce_batch, max_wait_ms=args.max_wait_ms
        )
        deadline_s = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None

        def one(q: str):
            t = time.perf_counter()
            resp = service.submit(
                CacheRequest(q, max_tokens=args.max_new_tokens, deadline_s=deadline_s)
            ).result()
            return resp, time.perf_counter() - t

        with service, ThreadPoolExecutor(max_workers=args.concurrency) as users:
            results = list(users.map(one, queries))
        for i, (q, (r, wall)) in enumerate(zip(queries, results)):
            tag = {"hit": "HIT ", "generated": "MISS", "deadline_exceeded": "EXPD"}[r.status]
            print(f"[{i:3d}] {tag} {wall*1e3:7.1f} ms  {q[:60]}")
        sst = service.stats
        lk, dp = service.scheduler_stats
        print(f"service: hits={sst.hits} generated={sst.generated} "
              f"deduped={sst.deduped} expired={sst.expired} "
              f"rejected={sst.rejected} lookup_avg_batch={lk.avg_batch:.1f} "
              f"dispatch_avg_batch={dp.avg_batch if dp else 0.0:.1f}")
    else:
        for i, q in enumerate(queries):
            r = client.query(q, max_tokens=args.max_new_tokens)
            tag = "HIT " if r.from_cache else "MISS"
            print(f"[{i:3d}] {tag} {r.latency_s*1e3:7.1f} ms  {q[:60]}")
    wall = time.perf_counter() - t0

    s = client.stats
    print(f"\nrequests={s.requests} hits={s.cache_hits} "
          f"hit_rate={s.cache_hits / max(s.requests, 1):.2f} "
          f"llm_calls={s.llm_calls} cost=${s.total_cost_usd:.6f} wall={wall:.1f}s")
    print(f"engine: {engine.metrics}")
    cs = cache.stats
    print(f"cache: lookups={cs.lookups} generative_hits={cs.generative_hits} "
          f"embed_time={cs.embed_time_s:.2f}s search_time={cs.search_time_s:.3f}s")


if __name__ == "__main__":
    main()
