"""Fault-tolerant checkpointing: sharded npz + atomic manifest, elastic restore.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json (manifest written last via
os.replace — a crash mid-save never corrupts the latest valid checkpoint).

Elastic restore: full (unsharded) arrays are saved; restore device_puts them
under the *target* mesh's shardings, so a checkpoint taken on a 16x16 pod
restores onto 2x16x16 (or a single test device) unchanged. At 1000+ node
scale the same manifest format fans out to per-host shard files — the
single-process writer here is the degenerate case (DESIGN.md §5).

Checkpoints may bundle auxiliary state: dataloader cursors, the cache
store's own persistence directory, preemption metadata.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.distributed.sharding import shardings_for


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    state: Any,
    extra: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **{k.replace("/", "|"): v for k, v in arrays.items()})
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)  # atomic commit

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    return path


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    template: Any,
    step: Optional[int] = None,
    mesh=None,
    specs: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of `template`. With (mesh, specs) the
    arrays are placed sharded on the target mesh (elastic re-shard)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    arrays = {k.replace("|", "/"): z[k] for k in z.files}

    flat_template = _flatten(template)
    missing = set(flat_template) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")

    leaves, treedef = jax.tree.flatten(template)
    keys = list(_flatten(jax.tree.unflatten(treedef, list(range(len(leaves))))).items())
    keys.sort(key=lambda kv: kv[1])
    ordered = [arrays[k] for k, _ in keys]
    restored = jax.tree.unflatten(treedef, ordered)

    def _cast(t, a):
        if not hasattr(t, "dtype"):
            return a
        try:
            return np.asarray(a, t.dtype)
        except (ValueError, TypeError):
            # ml_dtypes (bf16, ...) round-trip through npz as void bytes
            return np.asarray(a).view(t.dtype)

    restored = jax.tree.map(_cast, template, restored)

    if mesh is not None and specs is not None:
        shardings = shardings_for(mesh, specs, restored)
        restored = jax.tree.map(jax.device_put, restored, shardings)
    else:
        restored = jax.tree.map(jax.numpy.asarray, restored)
    return restored, manifest["extra"]
