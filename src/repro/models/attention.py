"""Attention: GQA with sliding-window/softcap/qk-norm variants, and MLA.

Local vs global vs NoPE layers share identical parameter shapes, so one scan
body serves every per-layer pattern: ``window`` (0 = unbounded), ``theta`` and
``use_rope`` arrive as (possibly traced) per-layer scalars.

The jnp path never materializes a full [Sq, Sk] score matrix for long
sequences: queries are processed in chunks of ``cfg.attn_chunk`` (an online
variant lives in kernels/flash_attention for the TPU target).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import FSDP, TP
from repro.models.layers import (
    F32,
    apply_rope,
    dense_init,
    maybe_rope,
    ones_init,
    param_dtype,
    rms_norm,
    softcap,
    stack_spec,
    zeros_init,
)

NEG_INF = -2.3819763e38  # min bf16-representable-ish large negative


# ---------------------------------------------------------------------------
# Standard (GQA) attention
# ---------------------------------------------------------------------------


def init_attn(key, cfg, d_in: Optional[int] = None, stacked: int = 0):
    d_in = d_in or cfg.d_model
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], (d_in, H, Dh), fan_in=d_in, dtype=dt, stacked=stacked),
        "wk": dense_init(ks[1], (d_in, K, Dh), fan_in=d_in, dtype=dt, stacked=stacked),
        "wv": dense_init(ks[2], (d_in, K, Dh), fan_in=d_in, dtype=dt, stacked=stacked),
        "wo": dense_init(ks[3], (H, Dh, d_in), fan_in=H * Dh, dtype=dt, stacked=stacked),
    }
    specs = {
        "wq": stack_spec((FSDP, TP, None), stacked),
        "wk": stack_spec((FSDP, TP, None), stacked),
        "wv": stack_spec((FSDP, TP, None), stacked),
        "wo": stack_spec((TP, None, FSDP), stacked),
    }
    if cfg.qkv_bias:
        params["bq"] = zeros_init((H, Dh), dt, stacked)
        params["bk"] = zeros_init((K, Dh), dt, stacked)
        params["bv"] = zeros_init((K, Dh), dt, stacked)
        specs["bq"] = stack_spec((TP, None), stacked)
        specs["bk"] = stack_spec((TP, None), stacked)
        specs["bv"] = stack_spec((TP, None), stacked)
    if cfg.qk_norm:
        params["q_norm"] = ones_init((Dh,), dt, stacked)
        params["k_norm"] = ones_init((Dh,), dt, stacked)
        specs["q_norm"] = stack_spec((None,), stacked)
        specs["k_norm"] = stack_spec((None,), stacked)
    return params, specs


def _project_qkv(params, cfg, x, positions, theta, use_rope):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = maybe_rope(q, positions, theta, use_rope)
    k = maybe_rope(k, positions, theta, use_rope)
    return q, k, v


def _mask(q_pos, k_pos, window, extra_kv_mask=None):
    """Causal + sliding-window mask. q_pos [B,Sq], k_pos [B,Sk], window scalar."""
    causal = k_pos[:, None, :] <= q_pos[:, :, None]  # [B, Sq, Sk]
    window = jnp.asarray(window, jnp.int32)
    in_window = k_pos[:, None, :] > (q_pos[:, :, None] - window)
    m = causal & jnp.where(window > 0, in_window, True)
    if extra_kv_mask is not None:
        m = m & extra_kv_mask[:, None, :]
    return m


def mha(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Sk, K, Dh]
    v: jax.Array,  # [B, Sk, K, Dh]
    *,
    q_pos: jax.Array,  # [B, Sq]
    k_pos: jax.Array,  # [B, Sk]
    window,
    cap: float,
    scale: float,
    chunk: int,
    kv_mask: Optional[jax.Array] = None,  # [B, Sk] valid-slot mask
    unroll: bool = False,
    repeat_kv: bool = False,
) -> jax.Array:
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    Dv = v.shape[-1]
    if k.dtype != q.dtype:  # quantized KV cache: dequantize on read
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    if repeat_kv and G > 1:
        # keep the head axis TP-shardable: a [K, G] split leaves a K-sized dim
        # no mesh axis divides (e.g. kv=8 over model=16), which forces GSPMD
        # to replicate the score einsums; repeating KV costs G x KV bytes but
        # keeps attention fully head-parallel (§Perf iteration log)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        K, G = H, 1
    q = q.reshape(B, Sq, K, G, Dh)

    def attend(qc, qp):
        # qc [B, c, K, G, Dh]; qp [B, c]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, k, preferred_element_type=F32)
        s = s * scale
        s = softcap(s, cap)
        m = _mask(qp, k_pos, window, kv_mask)  # [B, c, Sk]
        s = jnp.where(m[:, None, None, :, :], s, NEG_INF)
        w = jax.nn.softmax(s.astype(F32), axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", w, v)

    if Sq <= chunk or Sq % chunk != 0:
        out = attend(q, q_pos)
    else:
        nc = Sq // chunk
        qs = q.reshape(B, nc, chunk, K, G, Dh).swapaxes(0, 1)
        ps = q_pos.reshape(B, nc, chunk).swapaxes(0, 1)
        from repro.models.layers import maybe_scan

        _, outs = maybe_scan(lambda c, xs: (c, attend(*xs)), None, (qs, ps), unroll=unroll)
        out = outs.swapaxes(0, 1).reshape(B, Sq, K, G, Dv)
    return out.reshape(B, Sq, H, Dv)


def attention(
    params,
    cfg,
    x: jax.Array,  # [B, S, d_in]
    positions: jax.Array,  # [B, S]
    *,
    window,
    theta,
    use_rope=True,
    cache: Optional[dict] = None,
    cache_positions: Optional[jax.Array] = None,  # [B] write offset for decode
) -> Tuple[jax.Array, Optional[dict]]:
    """Full attention block body (no norms/residual — those live in the caller).

    Train/prefill: cache is None or an empty cache to fill from position 0.
    Decode: x is [B, 1, d], cache holds k/v, cache_positions the write index.
    """
    q, k_new, v_new = _project_qkv(params, cfg, x, positions, theta, use_rope)
    scale = cfg.query_scale or (1.0 / math.sqrt(cfg.head_dim))

    if cache is None:
        out = mha(
            q, k_new, v_new,
            q_pos=positions, k_pos=positions,
            window=window, cap=cfg.attn_logit_softcap, scale=scale, chunk=cfg.attn_chunk, unroll=cfg.unroll, repeat_kv=cfg.gqa_repeat_kv,
        )
        new_cache = None
    elif cache_positions is None:
        # prefill into cache starting at 0
        S = x.shape[1]
        k_buf = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, 0, 0, 0))
        v_buf = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, 0, 0, 0))
        out = mha(
            q, k_new, v_new,
            q_pos=positions, k_pos=positions,
            window=window, cap=cfg.attn_logit_softcap, scale=scale, chunk=cfg.attn_chunk, unroll=cfg.unroll, repeat_kv=cfg.gqa_repeat_kv,
        )
        new_cache = {"k": k_buf, "v": v_buf}
    else:
        B = x.shape[0]
        b_idx = jnp.arange(B)
        k_buf = cache["k"].at[b_idx, cache_positions].set(k_new[:, 0].astype(cache["k"].dtype))
        v_buf = cache["v"].at[b_idx, cache_positions].set(v_new[:, 0].astype(cache["v"].dtype))
        S_max = k_buf.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None], (B, S_max))
        kv_mask = k_pos <= cache_positions[:, None]
        out = mha(
            q, k_buf, v_buf,
            q_pos=positions, k_pos=k_pos,
            window=window, cap=cfg.attn_logit_softcap, scale=scale, chunk=cfg.attn_chunk, unroll=cfg.unroll, repeat_kv=cfg.gqa_repeat_kv,
            kv_mask=kv_mask,
        )
        new_cache = {"k": k_buf, "v": v_buf}

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_attn_cache(cfg, batch: int, max_seq: int, d_in: Optional[int] = None):
    dt = jnp.dtype(cfg.kv_cache_dtype) if cfg.kv_cache_dtype else param_dtype(cfg)
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    cache = {
        "k": jnp.zeros((batch, max_seq, K, Dh), dt),
        "v": jnp.zeros((batch, max_seq, K, Dh), dt),
    }
    # KV heads shard over model when divisible (pass-1 primary); otherwise the
    # sequence axis picks up `model` as a fallback (pass-2 tuple), and `data`
    # when the batch can't use it (context-parallel decode / split-K).
    specs = {
        "k": (("pod", "data"), ("data", "model"), TP, None),
        "v": (("pod", "data"), ("data", "model"), TP, None),
    }
    return cache, specs


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg, stacked: int = 0):
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 5)
    params = {
        "wq_a": dense_init(ks[0], (D, m.q_lora_rank), dtype=dt, stacked=stacked),
        "q_norm": ones_init((m.q_lora_rank,), dt, stacked),
        "wq_b": dense_init(
            ks[1], (m.q_lora_rank, H, m.qk_head_dim), fan_in=m.q_lora_rank, dtype=dt, stacked=stacked
        ),
        "wkv_a": dense_init(
            ks[2], (D, m.kv_lora_rank + m.qk_rope_head_dim), dtype=dt, stacked=stacked
        ),
        "kv_norm": ones_init((m.kv_lora_rank,), dt, stacked),
        "wkv_b": dense_init(
            ks[3],
            (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
            fan_in=m.kv_lora_rank,
            dtype=dt,
            stacked=stacked,
        ),
        "wo": dense_init(ks[4], (H, m.v_head_dim, D), fan_in=H * m.v_head_dim, dtype=dt, stacked=stacked),
    }
    specs = {
        "wq_a": stack_spec((FSDP, None), stacked),
        "q_norm": stack_spec((None,), stacked),
        "wq_b": stack_spec((FSDP, TP, None), stacked),
        "wkv_a": stack_spec((FSDP, None), stacked),
        "kv_norm": stack_spec((None,), stacked),
        "wkv_b": stack_spec((FSDP, TP, None), stacked),
        "wo": stack_spec((TP, None, FSDP), stacked),
    }
    return params, specs


def _mla_q(params, cfg, x, positions):
    m = cfg.mla
    cq = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(params, cfg, x, positions):
    m = cfg.mla
    kvr = x @ params["wkv_a"]  # [B, S, kv_lora + rope]
    c_kv = rms_norm(kvr[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kvr[..., m.kv_lora_rank :], positions, cfg.rope_theta)
    return c_kv, k_rope


def mla_attention(
    params,
    cfg,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    cache_positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    m = cfg.mla
    scale = 1.0 / math.sqrt(m.qk_head_dim)
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_kv_latent(params, cfg, x, positions)

    if cache_positions is None:
        # train / prefill: expand latent to per-head K,V
        kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b"])
        k_nope = kv[..., : m.qk_nope_head_dim]
        v = kv[..., m.qk_nope_head_dim :]
        H = cfg.num_heads
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_rope.shape[:2], H, m.qk_rope_head_dim))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = mha(
            q, k, v,
            q_pos=positions, k_pos=positions,
            window=0, cap=cfg.attn_logit_softcap, scale=scale, chunk=cfg.attn_chunk, unroll=cfg.unroll, repeat_kv=cfg.gqa_repeat_kv,
        )
        new_cache = None
        if cache is not None:
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice(cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, 0, 0)),
                "kr": jax.lax.dynamic_update_slice(cache["kr"], k_rope.astype(cache["kr"].dtype), (0, 0, 0)),
            }
    else:
        # absorbed decode: score/aggregate directly in the latent space.
        B = x.shape[0]
        b_idx = jnp.arange(B)
        ckv_buf = cache["ckv"].at[b_idx, cache_positions].set(c_kv[:, 0].astype(cache["ckv"].dtype))
        kr_buf = cache["kr"].at[b_idx, cache_positions].set(k_rope[:, 0].astype(cache["kr"].dtype))
        w_uk = params["wkv_b"][..., : m.qk_nope_head_dim]  # [kvl, H, nope]
        w_uv = params["wkv_b"][..., m.qk_nope_head_dim :]  # [kvl, H, v]
        q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)
        s = jnp.einsum("bqhl,bsl->bhqs", q_lat, ckv_buf, preferred_element_type=F32)
        s = s + jnp.einsum("bqhr,bsr->bhqs", q_rope, kr_buf, preferred_element_type=F32)
        s = s * scale
        S_max = ckv_buf.shape[1]
        valid = jnp.arange(S_max, dtype=jnp.int32)[None] <= cache_positions[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s.astype(F32), axis=-1).astype(ckv_buf.dtype)
        ctx_lat = jnp.einsum("bhqs,bsl->bqhl", w, ckv_buf)
        out = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, w_uv)
        new_cache = {"ckv": ckv_buf, "kr": kr_buf}

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_mla_cache(cfg, batch: int, max_seq: int):
    m = cfg.mla
    dt = jnp.dtype(cfg.kv_cache_dtype) if cfg.kv_cache_dtype else param_dtype(cfg)
    cache = {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dt),
        "kr": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dt),
    }
    # latent dim shards over TP (contraction-dim sharding -> partial sums +
    # all-reduce); sequence picks up `data` when the batch can't use it.
    specs = {
        "ckv": (("pod", "data"), ("data",), TP),
        "kr": (("pod", "data"), ("data",), TP),
    }
    return cache, specs
