"""Generic decoder stack covering all assigned architecture families.

One scan-over-layers driver serves dense / moe / vlm / audio stacks (local vs
global vs NoPE layers share parameter shapes; the per-layer pattern rides in
as scanned scalar arrays). SSM stacks scan Mamba2 blocks; the Zamba2 hybrid
scans (p mamba blocks + 1 shared attention block) groups.

API (all pure functions of (params, cfg, ...)):
  init_params(cfg, key)            -> (params, specs)
  init_cache(cfg, batch, max_seq)  -> (cache, specs)
  forward(params, cfg, batch)      -> h [B, S, d]   (training path, no cache)
  loss_fn(params, cfg, batch)      -> (loss, metrics)
  prefill(params, cfg, batch, cache)        -> (last_logits, cache)
  decode_step(params, cfg, tokens, pos, cache) -> (logits, cache)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import BATCH, FSDP, TP, constrain
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    F32,
    chunked_ce_loss,
    cross_entropy,
    dense_init,
    embed as embed_fn,
    init_embedding,
    init_mlp,
    mlp,
    ones_init,
    param_dtype,
    rms_norm,
    stack_spec,
    unembed_logits,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Per-layer pattern metadata
# ---------------------------------------------------------------------------


def layer_meta(cfg, n: int, offset: int = 0):
    """(window[n] i32, theta[n] f32, use_rope[n] bool) built from attn_pattern."""
    kinds = [cfg.attn_pattern[(offset + i) % len(cfg.attn_pattern)] for i in range(n)]
    window = np.array([cfg.window_size if k == "local" else 0 for k in kinds], np.int32)  # repro: noqa[RA101] — builds config metadata from Python scalars at trace time
    theta_local = cfg.rope_theta_local or cfg.rope_theta
    theta = np.array(  # repro: noqa[RA101] — config metadata from Python scalars at trace time
        [theta_local if k == "local" else cfg.rope_theta for k in kinds], np.float32
    )
    use_rope = np.array([k != "nope_global" for k in kinds], bool)  # repro: noqa[RA101] — config metadata from Python scalars at trace time
    return jnp.asarray(window), jnp.asarray(theta), jnp.asarray(use_rope)


def _tree_index(tree, i):
    return jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), tree)


def _checkpointed(body, cfg):
    """Remat policy: 'full' recomputes everything in backward; 'dots' saves
    matmul outputs (no attention/FFN recompute) — trades HBM for FLOPs."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(body)


# ---------------------------------------------------------------------------
# Attention-stack (dense / moe / vlm / audio)
# ---------------------------------------------------------------------------


def _init_attn_stack(key, cfg, n: int, ffn: str, d_ff: Optional[int] = None, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    dt = param_dtype(cfg)
    k_attn, k_ffn = jax.random.split(key)
    params: Params = {
        "ln1": ones_init((d,), dt, n),
        "ln2": ones_init((d,), dt, n),
    }
    specs: Params = {"ln1": (None, FSDP), "ln2": (None, FSDP)}
    if cfg.post_norms:
        params["ln1_post"] = ones_init((d,), dt, n)
        params["ln2_post"] = ones_init((d,), dt, n)
        specs["ln1_post"] = (None, FSDP)
        specs["ln2_post"] = (None, FSDP)
    if cfg.mla is not None:
        params["attn"], specs["attn"] = attn_mod.init_mla(k_attn, cfg, stacked=n)
    else:
        params["attn"], specs["attn"] = attn_mod.init_attn(k_attn, cfg, d_in=d, stacked=n)
    if ffn == "moe":
        params["ffn"], specs["ffn"] = moe_mod.init_moe(k_ffn, cfg, stacked=n)
    else:
        params["ffn"], specs["ffn"] = init_mlp(k_ffn, d, d_ff or cfg.d_ff, cfg, stacked=n, d_in=d)
    return params, specs


def _attn_block_body(cfg, lp, x, positions, win, theta, rope_flag, cache_l, cache_pos, ffn: str):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a_out, new_cache = attn_mod.mla_attention(
            lp["attn"], cfg, h, positions, cache=cache_l, cache_positions=cache_pos
        )
    else:
        a_out, new_cache = attn_mod.attention(
            lp["attn"], cfg, h, positions,
            window=win, theta=theta, use_rope=rope_flag,
            cache=cache_l, cache_positions=cache_pos,
        )
    if cfg.post_norms:
        a_out = rms_norm(a_out, lp["ln1_post"], cfg.norm_eps)
    x = x + a_out
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    metrics = {}
    if ffn == "moe":
        f_out, metrics = moe_mod.moe_ffn(lp["ffn"], cfg, h)
    else:
        f_out = mlp(lp["ffn"], h, cfg)
    if cfg.post_norms:
        f_out = rms_norm(f_out, lp["ln2_post"], cfg.norm_eps)
    x = x + f_out
    x = constrain(x, (BATCH, None, None))
    return x, new_cache, metrics


def _run_attn_stack(
    stack, cfg, x, positions, meta, *, ffn: str,
    cache=None, cache_pos=None, remat=True,
):
    window, theta, use_rope = meta

    # The cache rides in the scan CARRY (sliced/updated per layer index), not
    # as scanned xs/ys: carried buffers alias in place, halving decode-cell
    # HBM (xs + stacked ys would hold two full copies of the KV cache).
    def body(carry, xs):
        lp, win, th, rp, i = xs
        if cache is None:
            x = carry
            cl = None
        else:
            x, cache_buf = carry
            cl = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), cache_buf)
        x, new_cache, metrics = _attn_block_body(
            cfg, lp, x, positions, win, th, rp, cl, cache_pos, ffn
        )
        if cache is None:
            return x, metrics
        cache_buf = jax.tree.map(
            lambda full, nc: jax.lax.dynamic_update_index_in_dim(full, nc.astype(full.dtype), i, 0),
            cache_buf, new_cache,
        )
        return (x, cache_buf), metrics

    if remat and cfg.remat:
        body = _checkpointed(body, cfg)

    n = window.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    xs = (stack, window, theta, use_rope, idx)
    carry = x if cache is None else (x, cache)
    if cfg.unroll:
        ys_list = []
        for i in range(n):
            carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
            ys_list.append(y)
        ys = jax.tree.map(lambda *ls: jnp.stack(ls), *ys_list) if ys_list and ys_list[0] else {}
    else:
        carry, ys = jax.lax.scan(body, carry, xs)
    if cache is None:
        x, new_cache = carry, None
    else:
        x, new_cache = carry
    metrics = jax.tree.map(jnp.mean, ys) if ys else {}
    return x, new_cache, metrics


# ---------------------------------------------------------------------------
# SSM stack (mamba2) and hybrid (zamba2)
# ---------------------------------------------------------------------------


def _init_ssm_stack(key, cfg, n: int):
    dt = param_dtype(cfg)
    params = {"ln": ones_init((cfg.d_model,), dt, n)}
    specs = {"ln": (None, FSDP)}
    params["ssm"], specs["ssm"] = ssm_mod.init_ssm(key, cfg, stacked=n)
    return params, specs


def _run_ssm_stack(stack, cfg, x, *, cache=None, decode=False, remat=True):
    def body(carry, xs):
        lp, i = xs
        if cache is None:
            x = carry
            cl = None
        else:
            x, cache_buf = carry
            cl = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), cache_buf)
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        out, new_cache = ssm_mod.ssm_block(lp["ssm"], cfg, h, cache=cl, decode=decode)
        x = x + out
        x = constrain(x, (BATCH, None, None))
        if cache is None:
            return x, None
        cache_buf = jax.tree.map(
            lambda full, nc: jax.lax.dynamic_update_index_in_dim(full, nc.astype(full.dtype), i, 0),
            cache_buf, new_cache,
        )
        return (x, cache_buf), None

    if remat and cfg.remat and not decode:
        body = _checkpointed(body, cfg)
    n = jax.tree.leaves(stack)[0].shape[0]
    xs = (stack, jnp.arange(n, dtype=jnp.int32))
    carry = x if cache is None else (x, cache)
    if cfg.unroll:
        for i in range(n):
            carry, _ = body(carry, jax.tree.map(lambda a: a[i], xs))
    else:
        carry, _ = jax.lax.scan(body, carry, xs)
    if cache is None:
        return carry, None
    return carry


def _zamba_groups(cfg) -> Tuple[int, int]:
    n_groups = cfg.num_layers // cfg.hybrid_period
    rem = cfg.num_layers % cfg.hybrid_period
    return n_groups, rem


def _init_hybrid(key, cfg):
    km, ks, kd = jax.random.split(key, 3)
    params, specs = {}, {}
    params["mamba"], specs["mamba"] = _init_ssm_stack(km, cfg, cfg.num_layers)
    nsb = cfg.num_shared_blocks
    shared, shared_specs = _init_attn_stack(ks, cfg, nsb, ffn="mlp", d_in=2 * cfg.d_model)
    shared["down"] = dense_init(kd, (2 * cfg.d_model, cfg.d_model), dtype=param_dtype(cfg), stacked=nsb)
    shared_specs["down"] = (None, FSDP, None)
    params["shared"], specs["shared"] = shared, shared_specs
    return params, specs


def _shared_block_apply(cfg, sp, x, x0, positions, cache_l, cache_pos):
    """Zamba2 shared attention block at 2*d_model on concat(x, embed0)."""
    inp = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(inp, sp["ln1"], cfg.norm_eps)
    a_out, new_cache = attn_mod.attention(
        sp["attn"], cfg, h, positions,
        window=jnp.asarray(0, jnp.int32), theta=jnp.asarray(cfg.rope_theta, F32), use_rope=True,
        cache=cache_l, cache_positions=cache_pos,
    )
    r = inp + a_out
    h2 = rms_norm(r, sp["ln2"], cfg.norm_eps)
    r = r + mlp(sp["ffn"], h2, cfg)
    return x + r @ sp["down"], new_cache


def _run_hybrid(params, cfg, x, x0, positions, *, cache=None, cache_pos=None, decode=False, remat=True):
    n_groups, rem = _zamba_groups(cfg)
    p = cfg.hybrid_period
    mamba = params["mamba"]

    def slice_layers(tree, start, n):
        return jax.tree.map(lambda a: a[start : start + n], tree)

    def group_layers(tree):
        return jax.tree.map(lambda a: a[: n_groups * p].reshape(n_groups, p, *a.shape[1:]), tree)

    grouped = group_layers(mamba)
    tail = slice_layers(mamba, n_groups * p, rem) if rem else None

    m_cache = cache["mamba"] if cache is not None else None
    s_cache = cache["shared"] if cache is not None else None
    g_cache = group_layers(m_cache) if cache is not None else None
    t_cache = slice_layers(m_cache, n_groups * p, rem) if (cache is not None and rem) else None

    def group_body(carry, xs):
        x = carry
        if cache is None:
            g_idx, g_params = xs
            gc, sc = None, None
        else:
            g_idx, g_params, gc, sc = xs
        x, new_gc = _run_ssm_stack(g_params, cfg, x, cache=gc, decode=decode, remat=False)
        sel = jax.lax.rem(g_idx, cfg.num_shared_blocks)
        sp = _tree_index(params["shared"], sel)
        x, new_sc = _shared_block_apply(cfg, sp, x, x0, positions, sc, cache_pos)
        outs = (new_gc, new_sc) if cache is not None else None
        return x, outs

    if remat and cfg.remat and not decode:
        group_body = _checkpointed(group_body, cfg)

    g_idx = jnp.arange(n_groups, dtype=jnp.int32)
    xs = (g_idx, grouped) if cache is None else (g_idx, grouped, g_cache, s_cache)
    from repro.models.layers import maybe_scan

    x, outs = maybe_scan(group_body, x, xs, unroll=cfg.unroll)

    new_cache = None
    if cache is not None:
        new_gc, new_sc = outs
        new_m = jax.tree.map(lambda a: a.reshape(n_groups * p, *a.shape[2:]), new_gc)

    if rem:
        x, new_tc = _run_ssm_stack(tail, cfg, x, cache=t_cache, decode=decode, remat=remat)
        if cache is not None:
            new_m = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), new_m, new_tc)
    if cache is not None:
        new_cache = {"mamba": new_m, "shared": new_sc}
    return x, new_cache


# ---------------------------------------------------------------------------
# Top-level init
# ---------------------------------------------------------------------------


def init_params(cfg, key) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 8)
    dt = param_dtype(cfg)
    params: Params = {}
    specs: Params = {}

    # --- embeddings --------------------------------------------------------
    if cfg.modality == "audio":
        params["embed"] = {
            "table": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), fan_in=cfg.d_model,
                                dtype=dt, stacked=cfg.num_codebooks)
        }
        specs["embed"] = {"table": (None, TP, FSDP)}
        params["heads"] = dense_init(ks[1], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size),
                                     fan_in=cfg.d_model, dtype=dt)
        specs["heads"] = (None, FSDP, TP)
    else:
        params["embed"], specs["embed"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model, cfg)
        if not cfg.tie_embeddings:
            params["unembed"], specs["unembed"] = init_embedding(ks[1], cfg.vocab_size, cfg.d_model, cfg)

    if cfg.modality == "vision":
        params["projector"] = {
            "w1": dense_init(ks[2], (cfg.d_frontend, cfg.d_model), dtype=dt),
            "b1": jnp.zeros((cfg.d_model,), dt),
            "w2": dense_init(ks[3], (cfg.d_model, cfg.d_model), dtype=dt),
            "b2": jnp.zeros((cfg.d_model,), dt),
        }
        specs["projector"] = {"w1": (None, FSDP), "b1": (None,), "w2": (FSDP, None), "b2": (None,)}

    params["final_norm"] = ones_init((cfg.d_model,), dt)
    specs["final_norm"] = (FSDP,)

    # --- layer stacks (init helpers already emit layer-stacked specs) --------
    if cfg.family == "ssm":
        params["layers"], specs["layers"] = _init_ssm_stack(ks[4], cfg, cfg.num_layers)
    elif cfg.family == "hybrid":
        hp, hs = _init_hybrid(ks[4], cfg)
        params.update(hp)
        specs.update(hs)
    elif cfg.moe is not None:
        fkd = cfg.moe.first_k_dense
        if fkd:
            params["dense_layers"], specs["dense_layers"] = _init_attn_stack(
                ks[4], cfg, fkd, ffn="mlp", d_ff=cfg.moe.d_ff_dense or cfg.d_ff
            )
        params["moe_layers"], specs["moe_layers"] = _init_attn_stack(
            ks[5], cfg, cfg.num_layers - fkd, ffn="moe"
        )
        if cfg.mtp_depth:
            mtp_block, mtp_spec = _init_attn_stack(ks[6], cfg, 1, ffn="moe")
            params["mtp"] = {
                "block": mtp_block,
                "norm1": ones_init((cfg.d_model,), dt),
                "norm2": ones_init((cfg.d_model,), dt),
                "proj": dense_init(ks[7], (2 * cfg.d_model, cfg.d_model), dtype=dt),
            }
            specs["mtp"] = {
                "block": mtp_spec,
                "norm1": (FSDP,),
                "norm2": (FSDP,),
                "proj": (FSDP, None),
            }
    else:
        params["layers"], specs["layers"] = _init_attn_stack(ks[4], cfg, cfg.num_layers, ffn="mlp")

    return params, specs


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def _stack_cache(cache_and_spec, n: int):
    cache, spec = cache_and_spec
    cache = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), cache)
    spec = jax.tree.map(lambda s: (None,) + tuple(s), spec, is_leaf=lambda s: isinstance(s, tuple))
    return cache, spec


def init_cache(cfg, batch: int, max_seq: int) -> Tuple[Params, Params]:
    if cfg.family == "ssm":
        return _stack_cache(ssm_mod.init_ssm_cache(cfg, batch), cfg.num_layers)
    if cfg.family == "hybrid":
        n_groups, _ = _zamba_groups(cfg)
        mc, ms = _stack_cache(ssm_mod.init_ssm_cache(cfg, batch), cfg.num_layers)
        sc, ss = _stack_cache(attn_mod.init_attn_cache(cfg, batch, max_seq), n_groups)
        return {"mamba": mc, "shared": sc}, {"mamba": ms, "shared": ss}
    if cfg.mla is not None:
        return _stack_cache(attn_mod.init_mla_cache(cfg, batch, max_seq), cfg.num_layers)
    return _stack_cache(attn_mod.init_attn_cache(cfg, batch, max_seq), cfg.num_layers)


# ---------------------------------------------------------------------------
# Forward / loss / prefill / decode
# ---------------------------------------------------------------------------


def _embed_input(params, cfg, batch) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Returns (h [B,S,d], positions [B,S], loss_mask or None)."""
    if cfg.modality == "audio":
        tokens = batch["tokens"]  # [B, K, S]
        x = jnp.take(params["embed"]["table"][0], tokens[:, 0], axis=0)
        for k in range(1, cfg.num_codebooks):
            x = x + jnp.take(params["embed"]["table"][k], tokens[:, k], axis=0)
        B, S = tokens.shape[0], tokens.shape[-1]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return x, pos, None
    tokens = batch["tokens"]  # [B, S]
    x = embed_fn(params["embed"], tokens, cfg)
    if cfg.modality == "vision" and "vision_embeds" in batch:
        pj = params["projector"]
        v = batch["vision_embeds"].astype(x.dtype)
        v = jnp.tanh(v @ pj["w1"] + pj["b1"]) @ pj["w2"] + pj["b2"]
        x = jnp.concatenate([v, x], axis=1)
        P = v.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((x.shape[0], P), F32), jnp.ones(tokens.shape, F32)], axis=1
        )
    else:
        mask = None
    B, S = x.shape[0], x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, pos, mask


def _run_stacks(params, cfg, x, positions, *, cache=None, cache_pos=None, decode=False, remat=True):
    metrics: Dict[str, jax.Array] = {}
    new_cache = None
    if cfg.family == "ssm":
        x, new_cache = _run_ssm_stack(
            params["layers"], cfg, x, cache=cache, decode=decode, remat=remat
        )
    elif cfg.family == "hybrid":
        x0 = _hybrid_embed0(params, cfg, positions, x)
        x, new_cache = _run_hybrid(
            params, cfg, x, x0, positions, cache=cache, cache_pos=cache_pos, decode=decode, remat=remat
        )
    elif cfg.moe is not None:
        fkd = cfg.moe.first_k_dense
        meta_d = layer_meta(cfg, fkd, 0)
        meta_m = layer_meta(cfg, cfg.num_layers - fkd, fkd)
        if cache is not None:
            c_dense = jax.tree.map(lambda a: a[:fkd], cache) if fkd else None
            c_moe = jax.tree.map(lambda a: a[fkd:], cache)
        else:
            c_dense = c_moe = None
        if fkd:
            x, nc_d, _ = _run_attn_stack(
                params["dense_layers"], cfg, x, positions, meta_d, ffn="mlp",
                cache=c_dense, cache_pos=cache_pos, remat=remat,
            )
        x, nc_m, metrics = _run_attn_stack(
            params["moe_layers"], cfg, x, positions, meta_m, ffn="moe",
            cache=c_moe, cache_pos=cache_pos, remat=remat,
        )
        if cache is not None:
            new_cache = (
                jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), nc_d, nc_m) if fkd else nc_m
            )
    else:
        meta = layer_meta(cfg, cfg.num_layers, 0)
        x, new_cache, metrics = _run_attn_stack(
            params["layers"], cfg, x, positions, meta, ffn="mlp",
            cache=cache, cache_pos=cache_pos, remat=remat,
        )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, metrics


_HYBRID_EMBED0: Dict[int, jax.Array] = {}


def _hybrid_embed0(params, cfg, positions, x):
    # For zamba the shared blocks consume concat(h, original embedding);
    # the original embedding is the stack input itself.
    return x


def forward(params, cfg, batch):
    x, positions, mask = _embed_input(params, cfg, batch)
    h, _, metrics = _run_stacks(params, cfg, x, positions)
    return h, positions, mask, metrics


def _unembed_table(params, cfg):
    return params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]["table"]


def loss_fn(params, cfg, batch):
    """Next-token CE (+ MTP auxiliary loss for deepseek-v3)."""
    h, positions, mask, metrics = forward(params, cfg, batch)

    if cfg.modality == "audio":
        tokens = batch["tokens"]  # [B,K,S]
        logits = jnp.einsum("bsd,kdv->bksv", h[:, :-1].astype(F32), params["heads"].astype(F32))
        loss, _ = cross_entropy(logits, tokens[:, :, 1:])
        return loss, metrics

    tokens = batch["tokens"]
    table = _unembed_table(params, cfg)
    if cfg.modality == "vision" and "vision_embeds" in batch:
        P = batch["vision_embeds"].shape[1]
        h_pred = h[:, P - 1 : -1]  # predicts text tokens 0..S-1
        labels = tokens
        lmask = None
    else:
        h_pred = h[:, :-1]
        labels = tokens[:, 1:]
        lmask = None if mask is None else mask[:, 1:]
    loss, _ = chunked_ce_loss(table, h_pred, labels, cfg, lmask)

    if cfg.mtp_depth and "mtp" in params:
        mtp = params["mtp"]
        emb_next = embed_fn(params["embed"], tokens[:, 1:], cfg)
        h_in = jnp.concatenate(
            [rms_norm(h[:, :-1], mtp["norm1"], cfg.norm_eps),
             rms_norm(emb_next, mtp["norm2"], cfg.norm_eps)],
            axis=-1,
        ) @ mtp["proj"]
        meta = layer_meta(cfg, 1, 0)
        pos = positions[:, : h_in.shape[1]]
        h_mtp, _, _ = _run_attn_stack(mtp["block"], cfg, h_in, pos, meta, ffn="moe")
        mtp_loss, _ = chunked_ce_loss(table, h_mtp[:, :-1], tokens[:, 2:], cfg)
        loss = loss + 0.3 * mtp_loss
        metrics = dict(metrics, mtp_loss=mtp_loss)

    return loss, metrics


def prefill(params, cfg, batch, cache):
    """Run the prompt through the stack, filling `cache`; return last logits."""
    x, positions, _ = _embed_input(params, cfg, batch)
    h, new_cache, _ = _run_stacks(params, cfg, x, positions, cache=cache, remat=False)
    last = h[:, -1]
    if cfg.modality == "audio":
        logits = jnp.einsum("bd,kdv->bkv", last.astype(F32), params["heads"].astype(F32))
    else:
        logits = unembed_logits(_unembed_table(params, cfg), last, cfg)
    return logits, new_cache


def decode_step(params, cfg, tokens, pos, cache):
    """One decode step. tokens: [B,1] (audio: [B,K,1]); pos: [B] int32."""
    if cfg.modality == "audio":
        x = jnp.take(params["embed"]["table"][0], tokens[:, 0], axis=0)
        for k in range(1, cfg.num_codebooks):
            x = x + jnp.take(params["embed"]["table"][k], tokens[:, k], axis=0)
    else:
        x = embed_fn(params["embed"], tokens, cfg)
    positions = pos[:, None].astype(jnp.int32)
    h, new_cache, _ = _run_stacks(
        params, cfg, x, positions, cache=cache, cache_pos=pos.astype(jnp.int32),
        decode=True, remat=False,
    )
    last = h[:, 0]
    if cfg.modality == "audio":
        logits = jnp.einsum("bd,kdv->bkv", last.astype(F32), params["heads"].astype(F32))
    else:
        logits = unembed_logits(_unembed_table(params, cfg), last, cfg)
    return logits, new_cache
