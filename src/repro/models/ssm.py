"""Mamba2 / SSD (state-space duality) blocks.

Training/prefill uses the chunked SSD algorithm [arXiv:2405.21060]: the
sequence is processed in chunks of ``cfg.ssm.chunk_size`` via lax.scan — each
chunk computes its quadratic intra-chunk term (bounded [L, L] working set,
the TPU kernel target) and carries the inter-chunk SSM state recurrently.
All decays are exp of non-positive numbers (A < 0, dt >= 0), so the math is
stable in f32 without logsumexp gymnastics.

Decode is the O(1) recurrent update on the carried state.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import FSDP, TP
from repro.models.layers import F32, dense_init, ones_init, param_dtype, rms_norm, stack_spec


def _conv_dim(cfg) -> int:
    s = cfg.ssm
    return cfg.d_inner + 2 * s.ngroups * s.d_state


def init_ssm(key, cfg, stacked: int = 0):
    s = cfg.ssm
    d, di, H = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    cdim = _conv_dim(cfg)
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * di + 2 * s.ngroups * s.d_state + H

    # dt bias: softplus(dt_bias) uniform-ish in [1e-3, 0.1]
    u = jax.random.uniform(ks[3], ((stacked,) if stacked else ()) + (H,), F32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus

    a = jax.random.uniform(ks[4], ((stacked,) if stacked else ()) + (H,), F32, 1.0, 16.0)
    params = {
        "in_proj": dense_init(ks[0], (d, d_in_proj), dtype=dt, stacked=stacked),
        "conv_w": dense_init(ks[1], (cdim, s.d_conv), fan_in=s.d_conv, dtype=dt, stacked=stacked),
        "conv_b": jnp.zeros(((stacked,) if stacked else ()) + (cdim,), dt),
        "A_log": jnp.log(a),
        "dt_bias": dt_bias,
        "D": jnp.ones(((stacked,) if stacked else ()) + (H,), F32),
        "norm_w": ones_init((di,), dt, stacked),
        "out_proj": dense_init(ks[2], (di, d), dtype=dt, stacked=stacked),
    }
    specs = {
        "in_proj": stack_spec((FSDP, TP), stacked),
        "conv_w": stack_spec((TP, None), stacked),
        "conv_b": stack_spec((TP,), stacked),
        "A_log": stack_spec((None,), stacked),
        "dt_bias": stack_spec((None,), stacked),
        "D": stack_spec((None,), stacked),
        "norm_w": stack_spec((TP,), stacked),
        "out_proj": stack_spec((TP, FSDP), stacked),
    }
    return params, specs


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    di, H = cfg.d_inner, cfg.ssm_heads
    gn = s.ngroups * s.d_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * gn]
    dt = zxbcdt[..., 2 * di + 2 * gn :]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d. xBC [B,S,C], w [C,W], b [C]."""
    W = w.shape[-1]
    xp = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = sum(xp[:, j : j + S, :] * w[:, j] for j in range(W))
    return jax.nn.silu(out + b)


def _split_xbc(cfg, xBC):
    s = cfg.ssm
    di, H, P, G, N = cfg.d_inner, cfg.ssm_heads, s.headdim, s.ngroups, s.d_state
    B_, S_ = xBC.shape[0], xBC.shape[1]
    x = xBC[..., :di].reshape(B_, S_, H, P)
    Bm = xBC[..., di : di + G * N].reshape(B_, S_, G, N)
    Cm = xBC[..., di + G * N :].reshape(B_, S_, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)  # [B,S,H,N]
    Cm = jnp.repeat(Cm, rep, axis=2)
    return x, Bm, Cm


def _ssd_chunk_scan(x, Bm, Cm, dt, A, D, chunk: int, h0: Optional[jax.Array] = None,
                    unroll: bool = False):
    """Chunked SSD. x [B,S,H,P], Bm/Cm [B,S,H,N], dt [B,S,H] (f32, post-softplus).

    Returns y [B,S,H,P] and final state [B,H,P,N].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # zero-pad: dt=0 makes padded steps identity (no decay, no state write)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // L

    def to_chunks(t):
        return t.reshape(Bsz, nc, L, *t.shape[2:]).swapaxes(0, 1)  # [nc, B, L, ...]

    out_S = S

    xc, Bc, Cc, dtc = map(to_chunks, (x, Bm, Cm, dt))
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), F32)

    def body(h, inp):
        x_c, B_c, C_c, dt_c = inp  # [B,L,H,*]
        dA = dt_c * A  # [B,L,H], <= 0
        cs = jnp.cumsum(dA, axis=1)  # [B,L,H]
        # contribution of incoming state
        y_off = jnp.einsum("blhn,bhpn->blhp", C_c.astype(F32), h) * jnp.exp(cs)[..., None]
        # intra-chunk quadratic term
        decay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # [B, l, s, H]
        mask = jnp.tril(jnp.ones((L, L), bool))
        scores = jnp.einsum("blhn,bshn->blsh", C_c.astype(F32), B_c.astype(F32))
        scores = scores * decay * dt_c[:, None, :, :]
        scores = jnp.where(mask[None, :, :, None], scores, 0.0)
        y_diag = jnp.einsum("blsh,bshp->blhp", scores, x_c.astype(F32))
        # state update
        last = cs[:, -1, :]  # [B,H]
        sdecay = jnp.exp(last[:, None, :] - cs) * dt_c  # [B,L,H]
        h_new = h * jnp.exp(last)[:, :, None, None] + jnp.einsum(
            "blhn,blhp,blh->bhpn", B_c.astype(F32), x_c.astype(F32), sdecay
        )
        y = y_off + y_diag + D[None, None, :, None] * x_c.astype(F32)
        return h_new, y

    from repro.models.layers import maybe_scan

    h_final, ys = maybe_scan(body, h0, (xc, Bc, Cc, dtc), unroll=unroll)
    y = ys.swapaxes(0, 1).reshape(Bsz, S_pad, H, P)[:, :out_S]
    return y.astype(x.dtype), h_final


def ssm_block(
    params,
    cfg,
    xin: jax.Array,  # [B, S, d_model]
    *,
    cache: Optional[dict] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    s = cfg.ssm
    H, P = cfg.ssm_heads, s.headdim
    zxbcdt = xin @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    A = -jnp.exp(params["A_log"].astype(F32))  # [H]

    if not decode:
        xBC_raw = xBC  # pre-conv inputs; tail becomes the decode conv state
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        x, Bm, Cm = _split_xbc(cfg, xBC)
        dt = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"].astype(F32))
        y, h_final = _ssd_chunk_scan(
            x, Bm, Cm, dt, A, params["D"].astype(F32), s.chunk_size, unroll=cfg.unroll
        )
        new_cache = None
        if cache is not None:
            W = s.d_conv
            tail = xBC_raw[:, -(W - 1) :, :]
            pad = (W - 1) - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_cache = {
                "conv": tail.astype(cache["conv"].dtype),
                "ssm": h_final.astype(cache["ssm"].dtype),
            }
    else:
        # single-token recurrent update; xin [B, 1, d]
        W = s.d_conv
        xBC_new = xBC[:, 0]  # [B, cdim] pre-conv
        window = jnp.concatenate([cache["conv"].astype(xBC_new.dtype), xBC_new[:, None]], axis=1)
        conv_out = jnp.einsum("bwc,cw->bc", window.astype(F32), params["conv_w"].astype(F32))
        xBC_t = jax.nn.silu(conv_out + params["conv_b"].astype(F32)).astype(xin.dtype)
        x, Bm, Cm = _split_xbc(cfg, xBC_t[:, None])
        x, Bm, Cm = x[:, 0], Bm[:, 0], Cm[:, 0]  # [B,H,P], [B,H,N]
        dt = jax.nn.softplus(dt_raw[:, 0].astype(F32) + params["dt_bias"].astype(F32))  # [B,H]
        h = cache["ssm"].astype(F32)  # [B,H,P,N]
        dA = jnp.exp(dt * A)  # [B,H]
        h = h * dA[:, :, None, None] + jnp.einsum("bhn,bhp,bh->bhpn", Bm.astype(F32), x.astype(F32), dt)
        y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(F32), h)
        y = y + params["D"].astype(F32)[None, :, None] * x.astype(F32)
        y = y[:, None].astype(xin.dtype)  # [B,1,H,P]
        new_cache = {
            "conv": window[:, 1:].astype(cache["conv"].dtype),
            "ssm": h.astype(cache["ssm"].dtype),
        }

    Bsz, S = xin.shape[0], xin.shape[1]
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(y.dtype), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, new_cache


def init_ssm_cache(cfg, batch: int):
    s = cfg.ssm
    dt = param_dtype(cfg)
    cache = {
        "conv": jnp.zeros((batch, s.d_conv - 1, _conv_dim(cfg)), dt),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, s.headdim, s.d_state), F32),
    }
    specs = {
        "conv": (("pod", "data"), None, TP),
        "ssm": (("pod", "data"), TP, None, None),
    }
    return cache, specs
