"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Design (TPU-native, GShard-descended but without the [T, E, C] one-hot
dispatch blow-up):

  1. router logits -> top-k (expert id, gate weight) per token
  2. flatten (token, k) assignments, argsort by expert id
  3. rank-within-expert via exclusive cumulative counts (O(T*k), no [T,E])
  4. scatter tokens into an [E, C, D] buffer (slots >= capacity drop)
  5. dense per-expert GEMMs: einsum('ecd,edf->ecf') — MXU-aligned
  6. gather back, weight by gate, sum over k; add shared experts

Every step is differentiable (integer argsort/bincount paths carry no
gradient; gathers/scatters are linear; gate weights multiply outputs).

Distribution: GSPMD cannot partition a scatter whose operand is
expert-sharded while its updates are token-sharded — it falls back to
replicated [E, C, D] buffers (~10 GiB/layer for deepseek-v3). So under a
mesh, ``moe_ffn_sharded`` runs the dispatch inside shard_map: activations
are data-sharded and *replicated over the model axis*, so each (data, model)
device routes its local tokens, keeps only the assignments that hit its own
E/TP experts, dispatches into a purely-local [E_loc, C_loc, D] buffer, GEMMs
its local experts, and psums the partial token outputs over `model` (the
same all-reduce a TP FFN needs). Expert weights stay ZeRO-3-sharded over
`data`; jit all-gathers them per layer, overlapped with the previous layer
under scan.

DeepSeek-style "sigmoid_bias" routing implements aux-loss-free load
balancing: routing chooses by sigmoid score + per-expert bias (bias is
stop-gradient, updated outside the step by the trainer from drop statistics),
while gate *weights* use the unbiased scores.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import FSDP, TP, constrain
from repro.models.layers import F32, activation, dense_init, param_dtype, stack_spec, zeros_init


def init_moe(key, cfg, stacked: int = 0):
    mo = cfg.moe
    D, E, Fd = cfg.d_model, mo.num_experts, mo.d_ff_expert
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 7)
    params = {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32, stacked=stacked),
        "w_gate": dense_init(ks[1], (E, D, Fd), fan_in=D, dtype=dt, stacked=stacked),
        "w_up": dense_init(ks[2], (E, D, Fd), fan_in=D, dtype=dt, stacked=stacked),
        "w_down": dense_init(ks[3], (E, Fd, D), fan_in=Fd, dtype=dt, stacked=stacked),
    }
    specs = {
        "router": stack_spec((FSDP, None), stacked),
        "w_gate": stack_spec((TP, FSDP, None), stacked),
        "w_up": stack_spec((TP, FSDP, None), stacked),
        "w_down": stack_spec((TP, None, FSDP), stacked),
    }
    if mo.router == "sigmoid_bias":
        params["router_bias"] = zeros_init((E,), jnp.float32, stacked)
        specs["router_bias"] = stack_spec((None,), stacked)
    if mo.num_shared_experts:
        Fs = mo.d_ff_shared * mo.num_shared_experts
        params["shared_gate"] = dense_init(ks[4], (D, Fs), dtype=dt, stacked=stacked)
        params["shared_up"] = dense_init(ks[5], (D, Fs), dtype=dt, stacked=stacked)
        params["shared_down"] = dense_init(ks[6], (Fs, D), fan_in=Fs, dtype=dt, stacked=stacked)
        specs["shared_gate"] = stack_spec((FSDP, TP), stacked)
        specs["shared_up"] = stack_spec((FSDP, TP), stacked)
        specs["shared_down"] = stack_spec((TP, FSDP), stacked)
    return params, specs


def _route(params, cfg, x_flat: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Return (expert_idx [T,k] int32, gate_weights [T,k] f32)."""
    mo = cfg.moe
    logits = (x_flat.astype(F32) @ params["router"].astype(F32))  # [T, E]
    if mo.router == "sigmoid_bias":
        scores = jax.nn.sigmoid(logits)
        biased = scores + jax.lax.stop_gradient(params["router_bias"])[None, :]
        _, idx = jax.lax.top_k(biased, mo.top_k)
        gates = jnp.take_along_axis(scores, idx, axis=-1)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
        gates = gates * mo.routed_scaling
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, mo.top_k)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return idx.astype(jnp.int32), gates


def _dispatch_compute(params, cfg, x_flat, expert_idx, gates, capacity: int,
                      e_lo: int = 0, num_local_experts: int = 0):
    """Capacity dispatch + expert GEMMs over a token set.

    e_lo / num_local_experts restrict to an expert shard (shard_map path):
    assignments outside [e_lo, e_lo + n_loc) are dropped locally (they are
    served by another model-rank's copy of the same tokens).
    """
    mo = cfg.moe
    T, D = x_flat.shape
    K = mo.top_k
    E_loc = num_local_experts or mo.num_experts

    rel = expert_idx - e_lo  # [T, K]
    in_shard = (rel >= 0) & (rel < E_loc)
    flat_e = jnp.where(in_shard, rel, E_loc).reshape(-1)  # E_loc = drop bucket
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E_loc + 1)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K, dtype=jnp.int32) - offsets[sorted_e].astype(jnp.int32)
    slot = jnp.where((rank < capacity) & (sorted_e < E_loc), rank, capacity)

    token_of = (order // K).astype(jnp.int32)
    buf = jnp.zeros((E_loc, capacity, D), x_flat.dtype)
    buf = buf.at[sorted_e, slot].set(x_flat[token_of], mode="drop")

    h = activation(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]), cfg.act)
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    kept = (rank < capacity) & (sorted_e < E_loc)
    y_sorted = out_buf[jnp.minimum(sorted_e, E_loc - 1), jnp.minimum(slot, capacity - 1)]
    y_sorted = jnp.where(kept[:, None], y_sorted, 0)
    inv = jnp.argsort(order, stable=True)
    y_flat = y_sorted[inv].reshape(T, K, D)
    y = jnp.sum(y_flat.astype(F32) * gates[..., None], axis=1).astype(x_flat.dtype)

    assigned = in_shard.reshape(-1)[order]
    dropped = jnp.sum((assigned & (rank >= capacity)).astype(F32))
    total_assigned = jnp.maximum(jnp.sum(assigned.astype(F32)), 1.0)
    return y, dropped, total_assigned


def moe_ffn(params, cfg, x: jax.Array, capacity_factor: float = 0.0):
    """x: [B, S, D] -> [B, S, D] plus aux metrics dict.

    Under an active mesh with a `model` axis this runs the shard_map
    expert-parallel path; otherwise (unit tests, single device) everything
    is local.
    """
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names and cfg.moe.num_experts % mesh.shape["model"] == 0:
        return _moe_ffn_sharded(params, cfg, x, mesh, capacity_factor)

    mo = cfg.moe
    B, S, D = x.shape
    T = B * S
    cf = capacity_factor or mo.capacity_factor
    capacity = max(int(math.ceil(T * mo.top_k / mo.num_experts * cf)), min(8, T))  # repro: noqa[RA101] — shape math on Python ints at trace time

    x_flat = x.reshape(T, D)
    expert_idx, gates = _route(params, cfg, x_flat)
    y, dropped, assigned = _dispatch_compute(params, cfg, x_flat, expert_idx, gates, capacity)

    if mo.num_shared_experts:
        hs = activation(x_flat @ params["shared_gate"], cfg.act) * (x_flat @ params["shared_up"])
        y = y + hs @ params["shared_down"]

    metrics = {"moe_drop_fraction": dropped / assigned}
    return y.reshape(B, S, D), metrics


def _moe_ffn_sharded(params, cfg, x: jax.Array, mesh, capacity_factor: float = 0.0):
    """shard_map expert-parallel MoE (see module docstring)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mo = cfg.moe
    B, S, D = x.shape
    batch_axes = []
    n_batch_shards = 1
    for a in ("pod", "data"):  # keep axes while the cumulative product divides B
        if a in mesh.axis_names and B % (n_batch_shards * mesh.shape[a]) == 0:
            batch_axes.append(a)
            n_batch_shards *= mesh.shape[a]
    batch_axes = tuple(batch_axes)
    n_model = mesh.shape["model"]
    E_loc = mo.num_experts // n_model
    T_loc = (B // n_batch_shards) * S
    cf = capacity_factor or mo.capacity_factor
    capacity = max(int(math.ceil(T_loc * mo.top_k / mo.num_experts * cf)), min(8, T_loc))  # repro: noqa[RA101] — shape math on Python ints at trace time

    batch_spec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def local_fn(x_loc, rp, w_gate, w_up, w_down, shared):
        # x_loc: [B_loc, S, D] (replicated over `model`); w_*: local expert shard
        b_loc = x_loc.shape[0]
        x_flat = x_loc.reshape(b_loc * S, D)
        expert_idx, gates = _route(rp, cfg, x_flat)
        m_rank = jax.lax.axis_index("model")
        lp = {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        y, dropped, assigned = _dispatch_compute(
            lp, cfg, x_flat, expert_idx, gates, capacity,
            e_lo=m_rank * E_loc, num_local_experts=E_loc,
        )
        if mo.num_shared_experts:
            hs = activation(x_flat @ shared["gate"], cfg.act) * (x_flat @ shared["up"])
            y = y + hs @ shared["down"]
        y = jax.lax.psum(y, "model")  # partial expert (+F-sharded shared) outputs
        drop_frac = jax.lax.psum(dropped, "model") / jax.lax.psum(assigned, "model")
        if batch_axes:
            drop_frac = jax.lax.pmean(drop_frac, batch_axes)
        return y.reshape(b_loc, S, D), drop_frac

    rp = {"router": params["router"]}
    rp_specs = {"router": P(None, None)}  # routing needs the full table
    if "router_bias" in params:
        rp["router_bias"] = params["router_bias"]
        rp_specs["router_bias"] = P(None)
    shared_in = None
    shared_specs = P()
    if mo.num_shared_experts:
        shared_in = {
            "gate": params["shared_gate"],
            "up": params["shared_up"],
            "down": params["shared_down"],
        }
        # shared experts: F sharded over model -> partial sums join the psum
        shared_specs = {"gate": P(None, "model"), "up": P(None, "model"), "down": P("model", None)}
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(batch_spec, None, None),
            rp_specs,
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
            shared_specs,
        ),
        out_specs=(P(batch_spec, None, None), P()),
        check_rep=False,
    )
    y, drop_frac = fn(x, rp, params["w_gate"], params["w_up"], params["w_down"], shared_in)
    return y, {"moe_drop_fraction": drop_frac}
