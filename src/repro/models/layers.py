"""Shared model building blocks: norms, RoPE, MLPs, embeddings.

All functions are pure; parameters are plain dicts of jnp arrays. Param init
helpers return ``(params, specs)`` pairs where specs mirror the param tree
with logical-axis tuples (resolved against a concrete mesh by
repro.distributed.sharding).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import FSDP, TP

F32 = jnp.float32


def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def maybe_scan(body, init, xs, *, unroll: bool = False, length: Optional[int] = None):
    """lax.scan, or a python unroll when exact HLO cost accounting is needed
    (XLA's cost analysis counts while-loop bodies once; the dry-run's cost
    extraction lowers small unrolled configs — see launch/dryrun.py)."""
    if not unroll:
        return jax.lax.scan(body, init, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.bfloat16, stacked: int = 0):
    """Truncated-normal init with 1/sqrt(fan_in) scale; optional leading stack dim."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    full = (stacked,) + tuple(shape) if stacked else tuple(shape)
    return (jax.random.truncated_normal(key, -3.0, 3.0, full, F32) * std).astype(dtype)


def zeros_init(shape, dtype=jnp.bfloat16, stacked: int = 0):
    full = (stacked,) + tuple(shape) if stacked else tuple(shape)
    return jnp.zeros(full, dtype)


def ones_init(shape, dtype=jnp.bfloat16, stacked: int = 0):
    full = (stacked,) + tuple(shape) if stacked else tuple(shape)
    return jnp.ones(full, dtype)


def stack_spec(spec: tuple, stacked: bool) -> tuple:
    """Prepend a replicated layer axis to a spec for scan-stacked params."""
    return ((None,) + tuple(spec)) if stacked else tuple(spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(F32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-12):
    dtype = x.dtype
    x = x.astype(F32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(F32) + bias.astype(F32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """Rotary embedding.

    x: [B, S, D] or [B, S, H, D]; positions: [B, S]. theta may be a traced
    scalar (per-layer dual-theta patterns ride through the same scan body).
    """
    d = x.shape[-1]
    half = d // 2
    freq_exponents = jnp.arange(half, dtype=F32) / half
    inv_freq = jnp.asarray(theta, F32) ** -freq_exponents  # [half]
    ang = positions.astype(F32)[..., None] * inv_freq  # [B, S, half]
    if x.ndim == 4:
        ang = ang[:, :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def maybe_rope(x, positions, theta, use_rope) -> jax.Array:
    """Apply rope, selected per-layer by a (possibly traced) bool scalar."""
    roped = apply_rope(x, positions, theta)
    return jnp.where(jnp.asarray(use_rope, jnp.bool_), roped, x)


# ---------------------------------------------------------------------------
# Activations / softcap
# ---------------------------------------------------------------------------


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.asarray(cap, x.dtype) * jnp.tanh(x / jnp.asarray(cap, x.dtype))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, cfg, stacked: int = 0, d_in: Optional[int] = None):
    d_in = d_in or d_model
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        params = {
            "wi_gate": dense_init(ks[0], (d_in, d_ff), dtype=dt, stacked=stacked),
            "wi_up": dense_init(ks[1], (d_in, d_ff), dtype=dt, stacked=stacked),
            "wo": dense_init(ks[2], (d_ff, d_in), fan_in=d_ff, dtype=dt, stacked=stacked),
        }
        specs = {
            "wi_gate": stack_spec((FSDP, TP), stacked),
            "wi_up": stack_spec((FSDP, TP), stacked),
            "wo": stack_spec((TP, FSDP), stacked),
        }
    else:
        params = {
            "wi": dense_init(ks[0], (d_in, d_ff), dtype=dt, stacked=stacked),
            "wo": dense_init(ks[2], (d_ff, d_in), fan_in=d_ff, dtype=dt, stacked=stacked),
        }
        specs = {
            "wi": stack_spec((FSDP, TP), stacked),
            "wo": stack_spec((TP, FSDP), stacked),
        }
    return params, specs


def mlp(params, x, cfg):
    if "wi_gate" in params:
        h = activation(x @ params["wi_gate"], cfg.act) * (x @ params["wi_up"])
    else:
        h = activation(x @ params["wi"], cfg.act)
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, cfg, name_stacked: int = 0):
    dt = param_dtype(cfg)
    params = {
        "table": dense_init(key, (vocab, d_model), fan_in=d_model, dtype=dt, stacked=name_stacked)
    }
    specs = {"table": stack_spec((TP, FSDP), name_stacked)}
    return params, specs


def embed(params, tokens, cfg):
    x = jnp.take(params["table"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed_logits(table: jax.Array, h: jax.Array, cfg) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", h.astype(F32), table.astype(F32))
    return softcap(logits, cfg.final_logit_softcap)


def cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over masked positions. logits [..., V] f32, labels int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(F32)
    total = jnp.sum(nll * mask)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return total / denom, denom


def chunked_ce_loss(table, h, labels, cfg, mask=None):
    """Sequence-chunked unembed+CE: keeps [tokens, vocab] logits off HBM.

    h: [B, S, D]; labels [B, S]. Scans over S chunks.
    """
    B, S, D = h.shape
    chunk = cfg.loss_chunk
    if not chunk:
        logits = unembed_logits(table, h, cfg)
        return cross_entropy(logits, labels, mask)

    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(
            mask if mask is not None else jnp.ones((B, S), F32), ((0, 0), (0, pad))
        )
        S = S + pad

    n = S // chunk
    hs = h.reshape(B, n, chunk, D).swapaxes(0, 1)  # [n, B, chunk, D]
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = None if mask is None else mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        tot, den = carry
        if ms is None:
            hc, lc = xs
            mc = None
        else:
            hc, lc, mc = xs
        logits = unembed_logits(table, hc, cfg)
        loss, d = cross_entropy(logits, lc, mc)
        return (tot + loss * d, den + d), None

    xs = (hs, ls) if ms is None else (hs, ls, ms)
    (tot, den), _ = maybe_scan(
        body, (jnp.zeros((), F32), jnp.zeros((), F32)), xs, unroll=cfg.unroll
    )
    return tot / jnp.maximum(den, 1.0), den
