"""Public jit'd wrapper for decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "block_s", "interpret", "use_kernel")
)
def decode_attention(q, k, v, lengths, *, window=0, softcap=0.0, scale=None,
                     block_s=256, interpret=True, use_kernel=True):
    """q [B,H,Dh], k/v [B,S,KH,Dh], lengths [B] -> [B,H,Dh]."""
    if not use_kernel:
        return decode_attention_ref(q, k, v, lengths, window=window, softcap=softcap, scale=scale)
    return decode_attention_kernel(
        q, k, v, lengths, window=window, softcap=softcap, scale=scale,
        block_s=block_s, interpret=interpret,
    )
