"""Public wrapper for decode attention (backend auto-selected)."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.backend import resolve_interpret
from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "block_s", "interpret", "use_kernel")
)
def _decode_attention(q, k, v, lengths, *, window, softcap, scale, block_s,
                      interpret, use_kernel):
    if not use_kernel:
        return decode_attention_ref(q, k, v, lengths, window=window, softcap=softcap, scale=scale)
    return decode_attention_kernel(
        q, k, v, lengths, window=window, softcap=softcap, scale=scale,
        block_s=block_s, interpret=interpret,
    )


def decode_attention(q, k, v, lengths, *, window=0, softcap=0.0, scale=None,
                     block_s=256, interpret: Optional[bool] = None, use_kernel=True):
    """q [B,H,Dh], k/v [B,S,KH,Dh], lengths [B] -> [B,H,Dh].

    ``interpret=None`` auto-selects: interpret on CPU, compiled Pallas on
    TPU/GPU (see repro.kernels.backend).
    """
    return _decode_attention(
        q, k, v, lengths, window=window, softcap=softcap, scale=scale,
        block_s=block_s, interpret=resolve_interpret(interpret), use_kernel=use_kernel,
    )
