"""Pallas TPU kernel: split-K decode attention (FlashDecoding-style).

One new token attends over a long KV cache. Work is split over KV blocks:
grid (B, H, ns) with the KV axis innermost; partial online-softmax state
(m, l, acc) carried in VMEM scratch and normalized on the last block. On a
real v5e the ns axis would be re-mapped to parallel cores with an LSE-merge
epilogue (split-K proper); the sequential-grid form here shares the same
block math, and the cross-device variant of that merge is exercised by the
context-parallel decode path in the dry-run.

The q tile is [1, Dh] per (b, h); KV tiles [block_s, Dh] stream. Validity
comes from `lengths` (per-sequence cache fill) and the sliding window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.3e38


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_s: int, ns: int, window: int, softcap: float, scale: float,
):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    s_start = si * block_s
    live = s_start < length
    if window:
        live = jnp.logical_and(live, s_start + block_s - 1 > length - 1 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, :].astype(jnp.float32)[None, :]  # [1, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [block_s, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale  # [1, block_s]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        mask = pos < length
        if window:
            mask = jnp.logical_and(mask, pos > length - 1 - window)
        s = jnp.where(mask, s, NEG)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _writeback():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :] = (acc_scr[...] / l)[0].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "scale", "block_s", "interpret")
)
def decode_attention_kernel(
    q, k, v, lengths, *, window=0, softcap=0.0, scale=None, block_s=256, interpret=True
):
    B, H, Dh = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else Dh ** -0.5
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    ns = S // block_s

    kernel = functools.partial(
        _decode_kernel, block_s=block_s, ns=ns, window=window, softcap=softcap, scale=scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # lengths prefetch enables (future) block skipping
        grid=(B, H, ns),
        in_specs=[
            pl.BlockSpec((1, 1, Dh), lambda b, h, si, lens: (b, h, 0)),
            pl.BlockSpec((1, block_s, 1, Dh), lambda b, h, si, lens: (b, si, h // G, 0)),
            pl.BlockSpec((1, block_s, 1, Dh), lambda b, h, si, lens: (b, si, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Dh), lambda b, h, si, lens: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
