"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, *, window=0, softcap=0.0, scale=None):
    """q [B,H,Dh], k/v [B,S,KH,Dh], lengths [B] (#valid slots incl. current)
    -> [B,H,Dh]."""
    B, H, Dh = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else Dh ** -0.5
    qf = q.astype(jnp.float32).reshape(B, KH, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None]  # [1,S]
    mask = pos < lengths[:, None]
    if window:
        mask &= pos > (lengths[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)
