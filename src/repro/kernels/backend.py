"""Kernel backend selection: compiled Pallas vs interpret mode.

Every kernel package's public wrapper takes ``interpret=None`` and resolves
it here: interpret mode (the kernel body runs in Python) is only the right
default on CPU, where Mosaic/Triton lowering is unavailable — on TPU/GPU the
compiled Pallas path is selected automatically, so the kernels we wrote are
actually the ones that run in production.

Selection matrix (first match wins):

    explicit ``interpret=...`` at the call site   -> as given
    ``set_interpret_override(...)`` (config hook) -> the override
    ``REPRO_KERNEL_INTERPRET`` env var            -> truthy/falsy value
    ``jax.default_backend() == "cpu"``            -> interpret
    otherwise (tpu, gpu, ...)                     -> compiled
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_TRUTHY = ("1", "true", "yes", "on", "interpret")
_FALSY = ("0", "false", "no", "off", "compiled")

# process-wide config override (set_interpret_override); None = auto
_override: Optional[bool] = None


def set_interpret_override(value: Optional[bool]) -> None:
    """Force interpret (True), compiled (False), or auto (None) for every
    kernel call that does not pass ``interpret`` explicitly."""
    global _override
    _override = value


def get_interpret_override() -> Optional[bool]:
    return _override


def _env_override() -> Optional[bool]:
    raw = os.environ.get("REPRO_KERNEL_INTERPRET")
    if raw is None:
        return None
    v = raw.strip().lower()
    if v in _TRUTHY:
        return True
    if v in _FALSY:
        return False
    raise ValueError(
        f"REPRO_KERNEL_INTERPRET={raw!r}: expected one of {_TRUTHY + _FALSY}"
    )


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve the effective interpret flag for one kernel dispatch."""
    if interpret is not None:
        return bool(interpret)
    if _override is not None:
        return _override
    env = _env_override()
    if env is not None:
        return env
    return jax.default_backend() == "cpu"


# pinned-host staging support: None = not yet probed, else the cached verdict
_pinned_ok: Optional[bool] = None


def pinned_host_supported() -> bool:
    """Whether this backend exposes a ``pinned_host`` memory space (TPU/GPU
    runtimes do; CPU — and older runtimes — don't). Probed once per process
    with a 1-element transfer; the verdict is cached."""
    global _pinned_ok
    if _pinned_ok is None:
        try:
            import numpy as np
            from jax.sharding import SingleDeviceSharding

            dev = jax.devices()[0]
            sharding = SingleDeviceSharding(dev, memory_kind="pinned_host")
            jax.device_put(np.zeros(1, np.float32), sharding)
            _pinned_ok = True
        except Exception:
            _pinned_ok = False
    return _pinned_ok


def stage_pinned(rows):
    """Stage a host block for an upcoming device scatter through pinned host
    memory when the backend supports it (the DMA engine can then overlap the
    H2D copy with compute on TPU/GPU instead of faulting pageable pages);
    falls back to returning the pageable numpy block unchanged on CPU."""
    if not pinned_host_supported():
        return rows
    from jax.sharding import SingleDeviceSharding

    dev = jax.devices()[0]
    return jax.device_put(
        rows, SingleDeviceSharding(dev, memory_kind="pinned_host")
    )
