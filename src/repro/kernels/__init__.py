"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (handles metric/layout plumbing, merges)
  ref.py    — pure-jnp oracle used for validation and as the CPU exec path

Kernels are validated in interpret mode (the kernel body runs in Python on
CPU) against the refs over shape/dtype sweeps; see tests/test_kernels_*.
"""
