"""Public wrapper for flash attention (backend auto-selected)."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.backend import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_k",
                     "interpret", "use_kernel"),
)
def _flash_attention(q, k, v, *, causal, window, softcap, scale, block_q, block_k,
                     interpret, use_kernel):
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def flash_attention(
    q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
    block_q=128, block_k=128, interpret: Optional[bool] = None, use_kernel=True,
):
    """q [B,S,H,Dh], k/v [B,S,KH,Dh] -> [B,S,H,Dh] (GQA by head grouping).

    ``interpret=None`` auto-selects: interpret on CPU, compiled Pallas on
    TPU/GPU (see repro.kernels.backend).
    """
    return _flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=resolve_interpret(interpret), use_kernel=use_kernel,
    )
