"""Public jit'd wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_k",
                     "interpret", "use_kernel"),
)
def flash_attention(
    q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
    block_q=128, block_k=128, interpret=True, use_kernel=True,
):
    """q [B,S,H,Dh], k/v [B,S,KH,Dh] -> [B,S,H,Dh] (GQA by head grouping)."""
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale)
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
