"""Pallas TPU kernel: FlashAttention-style prefill attention.

Tiling: grid (B, H, nq, nk) with the KV axis innermost; the online-softmax
running state (m, l, acc) lives in VMEM scratch and is carried across the nk
grid steps (TPU grids iterate sequentially, so scratch persists — the
canonical Pallas flash pattern). The [block_q, Dh] query tile is read once
per (b, h, qi); [block_k, Dh] K/V tiles stream through VMEM.

GQA is free: the K/V BlockSpec index_map maps query head h to KV head
h // group_size, so grouped heads re-read the same KV tile instead of
materializing repeated KV in HBM.

Sliding-window + causal masking is applied per tile; fully-masked tiles
skip their compute via pl.when (their DMA is still scheduled — the
scalar-prefetch skip that also elides the DMA is recorded as a §Perf item).

VMEM: (block_q + 2*block_k) * Dh * 4 + block_q*block_k*4 + scratch
   = (128 + 256)*128*4 + 64 KB + ~70 KB  ≈ 0.33 MB at the default tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.3e38


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, nk: int, causal: bool, window: int,
    softcap: float, scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # tile-level reachability: causal upper bound and window lower bound
    conds = []
    if causal:
        conds.append(k_start <= q_start + block_q - 1)
    if window:
        conds.append(k_start + block_k - 1 > q_start - window)
    reachable = functools.reduce(jnp.logical_and, conds) if conds else (ki >= 0)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # [block_q, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [block_k, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]  # [block_q, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _writeback():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_kernel(
    q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
    block_q=128, block_k=128, interpret=True,
):
    B, S, H, Dh = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = scale if scale is not None else Dh ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, nk=nk,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dh), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, Dh), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum l
            pltpu.VMEM((block_q, Dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
