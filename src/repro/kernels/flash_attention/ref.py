"""Pure-jnp oracle for flash attention (prefill): causal + sliding window +
logit softcap + GQA, full score materialization (test sizes only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None):
    """q [B,S,H,Dh], k/v [B,S,KH,Dh] -> [B,S,H,Dh]."""
    B, S, H, Dh = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = scale if scale is not None else Dh ** -0.5
    qf = q.astype(jnp.float32).reshape(B, S, KH, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, Dh).astype(q.dtype)
