"""Public jit'd wrapper for the fused similarity+top-k lookup."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.similarity_topk.kernel import similarity_topk_blocks


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_n", "interpret"))
def similarity_topk(db, valid, q, *, k: int, metric: str = "cosine", block_n: int = 512,
                    interpret: bool = True):
    """db [N, D], valid [N] bool, q [Q, D] -> (scores [Q,k], idx [Q,k]).

    cosine is handled by pre-normalizing both sides (dot == cosine on unit
    vectors), keeping the kernel a pure MXU dot. N is padded to a block
    multiple with invalid entries.
    """
    db = db.astype(jnp.float32)
    q = q.astype(jnp.float32)
    if metric == "cosine":
        db = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-9)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    elif metric != "dot":
        raise ValueError(f"kernel path supports cosine/dot; got {metric!r}")

    N, D = db.shape
    bn = min(block_n, max(128, 1 << (N - 1).bit_length()))
    bn = min(bn, block_n)
    pad_n = (-N) % bn
    if pad_n:
        db = jnp.pad(db, ((0, pad_n), (0, 0)))
        valid = jnp.pad(valid, (0, pad_n))
    valid_f32 = valid.astype(jnp.float32)[:, None]

    bs, bi = similarity_topk_blocks(db, valid_f32, q, k=k, block_n=bn, interpret=interpret)
    # merge the [nb, Q, k] candidates: one tiny global top-k
    Q = q.shape[0]
    flat_s = bs.transpose(1, 0, 2).reshape(Q, -1)
    flat_i = bi.transpose(1, 0, 2).reshape(Q, -1)
    top_s, pos = jax.lax.top_k(flat_s, k)
    top_i = jnp.take_along_axis(flat_i, pos, axis=1)
    top_s = jnp.where(top_s <= jnp.float32(-1.0e38), -jnp.inf, top_s)
    return top_s, top_i
