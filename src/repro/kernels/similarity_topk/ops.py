"""Public wrappers for the fused similarity+top-k lookup.

Two entry points:

  * ``similarity_topk``        — one store: db [N, D] -> (scores, idx) [Q, k]
  * ``similarity_topk_lanes``  — a whole StoreBank: db [L, N, D] -> [Q, L, k],
    every hierarchy level / shard lane scored in ONE kernel dispatch.

``interpret=None`` (the default) auto-selects the backend via
``repro.kernels.backend``: interpret mode on CPU, the compiled Pallas kernel
on TPU/GPU. Each wrapper counts its host-level invocations so tests and
benchmarks can assert dispatch budgets (``dispatch_count`` /
``reset_dispatch_count``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.similarity_topk.kernel import (
    similarity_topk_blocks,
    similarity_topk_lanes_blocks,
)

_dispatches = 0  # host-level kernel dispatches (single + lanes)


def record_dispatch(n: int = 1) -> None:
    """Count a dispatch issued outside these wrappers (e.g. a StoreBank
    search that inlines the kernel body under its own jit)."""
    global _dispatches
    _dispatches += n


def dispatch_count() -> int:
    return _dispatches


def reset_dispatch_count() -> None:
    global _dispatches
    _dispatches = 0


def _block_for(N: int, block_n: int) -> int:
    bn = min(block_n, max(128, 1 << (N - 1).bit_length()))
    return min(bn, block_n)


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_n", "interpret"))
def _similarity_topk(db, valid, q, *, k: int, metric: str, block_n: int, interpret: bool):
    """db [N, D], valid [N] bool, q [Q, D] -> (scores [Q,k], idx [Q,k]).

    cosine is handled by pre-normalizing both sides (dot == cosine on unit
    vectors), keeping the kernel a pure MXU dot. N is padded to a block
    multiple with invalid entries.
    """
    db = db.astype(jnp.float32)
    q = q.astype(jnp.float32)
    if metric == "cosine":
        db = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-9)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    elif metric != "dot":
        raise ValueError(f"kernel path supports cosine/dot; got {metric!r}")

    N, D = db.shape
    bn = _block_for(N, block_n)
    pad_n = (-N) % bn
    if pad_n:
        db = jnp.pad(db, ((0, pad_n), (0, 0)))
        valid = jnp.pad(valid, (0, pad_n))
    valid_f32 = valid.astype(jnp.float32)[:, None]

    bs, bi = similarity_topk_blocks(db, valid_f32, q, k=k, block_n=bn, interpret=interpret)
    # merge the [nb, Q, k] candidates: one tiny global top-k
    Q = q.shape[0]
    flat_s = bs.transpose(1, 0, 2).reshape(Q, -1)
    flat_i = bi.transpose(1, 0, 2).reshape(Q, -1)
    top_s, pos = jax.lax.top_k(flat_s, k)
    top_i = jnp.take_along_axis(flat_i, pos, axis=1)
    top_s = jnp.where(top_s <= jnp.float32(-1.0e38), -jnp.inf, top_s)
    return top_s, top_i


def similarity_topk(db, valid, q, *, k: int, metric: str = "cosine", block_n: int = 512,
                    interpret: Optional[bool] = None):
    """db [N, D], valid [N] bool, q [Q, D] -> (scores [Q,k], idx [Q,k]).

    ``interpret=None`` auto-selects: interpret on CPU, compiled elsewhere.
    """
    record_dispatch()
    return _similarity_topk(
        db, valid, q, k=k, metric=metric, block_n=block_n,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_n", "interpret", "prenormalized"))
def _similarity_topk_lanes(db, valid, q, *, k: int, metric: str, block_n: int,
                           interpret: bool, prenormalized: bool):
    """db [L, N, D], valid [L, N] bool, q [Q, D] -> ([Q, L, k], [Q, L, k]).

    Lane indices are lane-local (0..N), matching what L separate
    ``similarity_topk`` calls would return — candidates are never merged
    across lanes; the caller (the hierarchy / bank) owns cross-lane policy.
    ``prenormalized=True`` skips the db normalization (StoreBank keeps unit
    rows for cosine lanes).
    """
    db = db.astype(jnp.float32)
    q = q.astype(jnp.float32)
    if metric == "cosine":
        if not prenormalized:
            db = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-9)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    elif metric != "dot":
        raise ValueError(f"kernel path supports cosine/dot; got {metric!r}")

    L, N, D = db.shape
    bn = _block_for(N, block_n)
    pad_n = (-N) % bn
    if pad_n:
        db = jnp.pad(db, ((0, 0), (0, pad_n), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad_n)))
    valid_f32 = valid.astype(jnp.float32)[..., None]

    bs, bi = similarity_topk_lanes_blocks(db, valid_f32, q, k=k, block_n=bn,
                                          interpret=interpret)
    # merge per lane: [L, nb, Q, k] -> [L, Q, nb*k] -> top-k -> [Q, L, k]
    Q = q.shape[0]
    flat_s = bs.transpose(0, 2, 1, 3).reshape(L, Q, -1)
    flat_i = bi.transpose(0, 2, 1, 3).reshape(L, Q, -1)
    top_s, pos = jax.lax.top_k(flat_s, k)
    top_i = jnp.take_along_axis(flat_i, pos, axis=2)
    top_s = jnp.where(top_s <= jnp.float32(-1.0e38), -jnp.inf, top_s)
    return top_s.transpose(1, 0, 2), top_i.transpose(1, 0, 2)


def similarity_topk_lanes(db, valid, q, *, k: int, metric: str = "cosine",
                          block_n: int = 512, interpret: Optional[bool] = None,
                          prenormalized: bool = False):
    """Fused multi-lane lookup: db [L, N, D], valid [L, N], q [Q, D] ->
    (scores [Q, L, k], lane-local idx [Q, L, k]) in ONE kernel dispatch."""
    record_dispatch()
    return _similarity_topk_lanes(
        db, valid, q, k=k, metric=metric, block_n=block_n,
        interpret=resolve_interpret(interpret), prenormalized=prenormalized,
    )
