"""Public wrappers for the fused similarity+top-k lookup.

Two entry points:

  * ``similarity_topk``        — one store: db [N, D] -> (scores, idx) [Q, k]
  * ``similarity_topk_lanes``  — a whole StoreBank: db [L, N, D] -> [Q, L, k],
    every hierarchy level / shard lane scored in ONE kernel dispatch.

``interpret=None`` (the default) auto-selects the backend via
``repro.kernels.backend``: interpret mode on CPU, the compiled Pallas kernel
on TPU/GPU. ``block_n=None`` resolves to the ``REPRO_TOPK_BLOCK_N`` env
override (else 512 — a CPU-friendly default; sweep ``benchmarks/tune_topk.py``
on real TPU/GPU hardware and export the winner). ``grid_order`` likewise
honors ``REPRO_TOPK_GRID_ORDER`` (``lanes_outer`` | ``blocks_outer``).

The lanes entry point accepts a per-lane *metric tag* tuple (mixed
cosine/dot hierarchies): scores are computed as raw dots against
unit-normalized cosine rows, then cosine lanes are rescaled by 1/|q| — a
positive per-query scale, so per-lane rankings (and therefore the top-k
indices) are exact, and the returned scores are true cosines.

Each wrapper counts its host-level invocations so tests and benchmarks can
assert dispatch budgets (``dispatch_count`` / ``reset_dispatch_count``).
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.similarity_topk.kernel import (
    similarity_topk_blocks,
    similarity_topk_lanes_blocks,
)

_dispatches = 0  # host-level kernel dispatches (single + lanes)

_GRID_ORDERS = ("lanes_outer", "blocks_outer")


def record_dispatch(n: int = 1) -> None:
    """Count a dispatch issued outside these wrappers (e.g. a StoreBank
    search that inlines the kernel body under its own jit)."""
    global _dispatches
    _dispatches += n


def dispatch_count() -> int:
    return _dispatches


def reset_dispatch_count() -> None:
    global _dispatches
    _dispatches = 0


def default_block_n() -> int:
    """The lanes/blocks tile size: ``REPRO_TOPK_BLOCK_N`` env override, else
    512 (the CPU-interpret default). Must be a multiple of 128 (MXU lanes)."""
    raw = os.environ.get("REPRO_TOPK_BLOCK_N")
    if raw is None:
        return 512
    v = int(raw)  # repro: noqa[RA101] — env string at trace time, not a traced value
    if v <= 0 or v % 128:
        raise ValueError(
            f"REPRO_TOPK_BLOCK_N={raw!r}: expected a positive multiple of 128"
        )
    return v


def default_grid_order() -> str:
    raw = os.environ.get("REPRO_TOPK_GRID_ORDER")
    if raw is None:
        return "lanes_outer"
    v = raw.strip().lower()
    if v not in _GRID_ORDERS:
        raise ValueError(
            f"REPRO_TOPK_GRID_ORDER={raw!r}: expected one of {_GRID_ORDERS}"
        )
    return v


def apply_topk_tuning(
    block_n: "int | None" = None, grid_order: "str | None" = None
) -> None:
    """Install config-level tuning defaults for the top-k kernels.

    The launch configs bake the winners of the ``benchmarks/tune_topk.py``
    sweep here (``ModelConfig.topk_block_n`` / ``topk_grid_order``). Values
    land via ``os.environ.setdefault``, so an explicit
    ``REPRO_TOPK_BLOCK_N`` / ``REPRO_TOPK_GRID_ORDER`` in the environment
    always wins over the config. Invalid values fail fast here rather than
    at first kernel trace."""
    if block_n is not None:
        if block_n <= 0 or block_n % 128:
            raise ValueError(
                f"topk_block_n={block_n!r}: expected a positive multiple of 128"
            )
        os.environ.setdefault("REPRO_TOPK_BLOCK_N", str(block_n))
    if grid_order is not None:
        if grid_order not in _GRID_ORDERS:
            raise ValueError(
                f"topk_grid_order={grid_order!r}: expected one of {_GRID_ORDERS}"
            )
        os.environ.setdefault("REPRO_TOPK_GRID_ORDER", grid_order)


def _block_for(N: int, block_n: int) -> int:
    bn = min(block_n, max(128, 1 << (N - 1).bit_length()))
    return min(bn, block_n)


@functools.partial(jax.jit, static_argnames=("k", "metric", "block_n", "interpret"))
def _similarity_topk(db, valid, q, *, k: int, metric: str, block_n: int, interpret: bool):
    """db [N, D], valid [N] bool, q [Q, D] -> (scores [Q,k], idx [Q,k]).

    cosine is handled by pre-normalizing both sides (dot == cosine on unit
    vectors), keeping the kernel a pure MXU dot. N is padded to a block
    multiple with invalid entries.
    """
    db = db.astype(jnp.float32)
    q = q.astype(jnp.float32)
    if metric == "cosine":
        db = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-9)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    elif metric != "dot":
        raise ValueError(f"kernel path supports cosine/dot; got {metric!r}")

    N, D = db.shape
    bn = _block_for(N, block_n)
    pad_n = (-N) % bn
    if pad_n:
        db = jnp.pad(db, ((0, pad_n), (0, 0)))
        valid = jnp.pad(valid, (0, pad_n))
    valid_f32 = valid.astype(jnp.float32)[:, None]

    bs, bi = similarity_topk_blocks(db, valid_f32, q, k=k, block_n=bn, interpret=interpret)
    # merge the [nb, Q, k] candidates: one tiny global top-k
    Q = q.shape[0]
    flat_s = bs.transpose(1, 0, 2).reshape(Q, -1)
    flat_i = bi.transpose(1, 0, 2).reshape(Q, -1)
    top_s, pos = jax.lax.top_k(flat_s, k)
    top_i = jnp.take_along_axis(flat_i, pos, axis=1)
    top_s = jnp.where(top_s <= jnp.float32(-1.0e38), -jnp.inf, top_s)
    return top_s, top_i


def similarity_topk(db, valid, q, *, k: int, metric: str = "cosine",
                    block_n: Optional[int] = None, interpret: Optional[bool] = None):
    """db [N, D], valid [N] bool, q [Q, D] -> (scores [Q,k], idx [Q,k]).

    ``interpret=None`` auto-selects: interpret on CPU, compiled elsewhere.
    ``block_n=None`` resolves the ``REPRO_TOPK_BLOCK_N`` override.
    """
    record_dispatch()
    return _similarity_topk(
        db, valid, q, k=k, metric=metric,
        block_n=default_block_n() if block_n is None else block_n,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=(
    "k", "metric", "block_n", "interpret", "prenormalized", "grid_order"))
def _similarity_topk_lanes(db, valid, q, *, k: int, metric: Tuple[str, ...],
                           block_n: Optional[int], interpret: bool,
                           prenormalized: bool, grid_order: Optional[str] = None):
    """db [L, N, D], valid [L, N] bool, q [Q, D] -> ([Q, L, k], [Q, L, k]).

    Lane indices are lane-local (0..N), matching what L separate
    ``similarity_topk`` calls would return — candidates are never merged
    across lanes; the caller (the hierarchy / bank) owns cross-lane policy.
    ``metric`` is a per-lane tuple (a 1-tuple broadcasts to every lane);
    uniform-cosine banks pre-normalize q once, while mixed cosine/dot banks
    require ``prenormalized=True`` (unit cosine rows — StoreBank's insert
    invariant) and rescale cosine lanes' dot scores by 1/|q| after the
    kernel, which preserves per-lane rankings exactly.
    """
    L = db.shape[0]
    metrics = tuple(metric) if len(metric) > 1 else tuple(metric) * L
    bad = [m for m in metrics if m not in ("cosine", "dot")]
    if bad:
        raise ValueError(f"kernel path supports cosine/dot; got {bad!r}")
    mixed = len(set(metrics)) > 1

    db = db.astype(jnp.float32)
    q = q.astype(jnp.float32)
    cos_scale = None
    if mixed:
        if not prenormalized:
            raise ValueError(
                "mixed-metric lanes require prenormalized (unit) cosine rows"
            )
        # raw q against unit cosine rows: dot / |q| == cosine; dot lanes raw
        cos_scale = 1.0 / jnp.maximum(jnp.linalg.norm(q, axis=-1), 1e-9)  # [Q]
    elif metrics[0] == "cosine":
        if not prenormalized:
            db = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-9)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)

    _, N, D = db.shape
    block_n = default_block_n() if block_n is None else block_n
    bn = _block_for(N, block_n)
    pad_n = (-N) % bn
    if pad_n:
        db = jnp.pad(db, ((0, 0), (0, pad_n), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad_n)))
    valid_f32 = valid.astype(jnp.float32)[..., None]

    bs, bi = similarity_topk_lanes_blocks(
        db, valid_f32, q, k=k, block_n=bn, interpret=interpret,
        grid_order=default_grid_order() if grid_order is None else grid_order,
    )
    # merge per lane: [L, nb, Q, k] -> [L, Q, nb*k] -> top-k -> [Q, L, k]
    Q = q.shape[0]
    flat_s = bs.transpose(0, 2, 1, 3).reshape(L, Q, -1)
    flat_i = bi.transpose(0, 2, 1, 3).reshape(L, Q, -1)
    top_s, pos = jax.lax.top_k(flat_s, k)
    top_i = jnp.take_along_axis(flat_i, pos, axis=2)
    top_s = jnp.where(top_s <= jnp.float32(-1.0e38), -jnp.inf, top_s)
    top_s = top_s.transpose(1, 0, 2)  # [Q, L, k]
    top_i = top_i.transpose(1, 0, 2)
    if cos_scale is not None:
        is_cos = jnp.asarray([m == "cosine" for m in metrics])  # [L]
        top_s = jnp.where(
            is_cos[None, :, None], top_s * cos_scale[:, None, None], top_s
        )
    return top_s, top_i


def similarity_topk_lanes(db, valid, q, *, k: int,
                          metric: Union[str, Tuple[str, ...]] = "cosine",
                          block_n: Optional[int] = None,
                          interpret: Optional[bool] = None,
                          prenormalized: bool = False,
                          grid_order: Optional[str] = None):
    """Fused multi-lane lookup: db [L, N, D], valid [L, N], q [Q, D] ->
    (scores [Q, L, k], lane-local idx [Q, L, k]) in ONE kernel dispatch.
    ``metric`` may be one name for every lane or a per-lane tuple."""
    record_dispatch()
    metrics = (metric,) if isinstance(metric, str) else tuple(metric)
    return _similarity_topk_lanes(
        db, valid, q, k=k, metric=metrics,
        block_n=default_block_n() if block_n is None else block_n,
        interpret=resolve_interpret(interpret), prenormalized=prenormalized,
        grid_order=default_grid_order() if grid_order is None else grid_order,
    )
