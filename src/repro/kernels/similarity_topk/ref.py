"""Pure-jnp oracle for the fused similarity + top-k cache lookup."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def similarity_topk_ref(db, valid, q, k: int, metric: str = "cosine"):
    """db [N, D], valid [N] bool, q [Q, D] -> (scores [Q, k], idx [Q, k])."""
    db = db.astype(jnp.float32)
    q = q.astype(jnp.float32)
    if metric == "cosine":
        db = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-9)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    elif metric != "dot":
        raise ValueError(f"unsupported metric {metric!r}")
    s = q @ db.T
    s = jnp.where(valid[None, :], s, -jnp.inf)
    return jax.lax.top_k(s, k)


def similarity_topk_lanes_ref(db, valid, q, k: int, metric: str = "cosine"):
    """db [L, N, D], valid [L, N], q [Q, D] -> ([Q, L, k], [Q, L, k]):
    L independent single-lane lookups, stacked along axis 1."""
    outs = [similarity_topk_ref(db[l], valid[l], q, k, metric) for l in range(db.shape[0])]
    s = jnp.stack([o[0] for o in outs], axis=1)
    i = jnp.stack([o[1] for o in outs], axis=1)
    return s, i
