from repro.kernels.similarity_topk.ops import similarity_topk  # noqa: F401
