"""Pallas TPU kernel: fused similarity scoring + per-block top-k.

The cache database [N, D] streams through VMEM in [block_n, D] tiles; the
query block [Q, D] stays resident. Each grid step computes the [Q, block_n]
score tile on the MXU and extracts its top-k by k rounds of masked max
(k is small — 4..16 — so this beats a sort and needs no sort primitive,
which Mosaic does not provide). The tiny [nb, Q, k] candidate tensor is
merged by ops.py.

VMEM budget per step: block_n*D*4 + Q*D*4 + Q*block_n*4 bytes;
block_n=512, D=1024, Q<=16 => ~2.1 MB + 64 KB + 32 KB — comfortably resident,
and block_n is a lane-aligned multiple of 128 for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.0e38  # python literal: jnp constants would be captured consts in the kernel


def _topk_block_kernel(db_ref, valid_ref, q_ref, out_s_ref, out_i_ref, *, k: int, block_n: int):
    j = pl.program_id(0)
    db = db_ref[...]  # [block_n, D]
    q = q_ref[...]  # [Q, D]
    valid = valid_ref[...]  # [block_n, 1] f32 (1.0 = valid)

    s = jax.lax.dot_general(
        q.astype(jnp.float32),
        db.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, block_n]
    s = jnp.where(valid[:, 0][None, :] > 0.5, s, NEG)

    Q = s.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, block_n), 1)
    base = j * block_n
    for t in range(k):  # static unroll: k rounds of masked max-extract
        m = jnp.max(s, axis=1)  # [Q]
        hit = s >= m[:, None]
        idx = jnp.min(jnp.where(hit, col, jnp.int32(2**30)), axis=1)  # first argmax
        out_s_ref[0, :, t] = m
        out_i_ref[0, :, t] = idx + base
        s = jnp.where(col == idx[:, None], NEG, s)


def _topk_lanes_kernel(db_ref, valid_ref, q_ref, out_s_ref, out_i_ref, *, k: int,
                       block_n: int, block_axis: int = 1):
    """Batched-lanes variant: grid (L, nb) — one lane (hierarchy level or DB
    shard) per row of the grid, so L levels x nb blocks stream through VMEM
    in ONE pallas dispatch instead of L sequential kernel launches.
    ``block_axis`` names which grid axis walks the blocks (1 for the default
    lanes-outer order, 0 for blocks-outer)."""
    j = pl.program_id(block_axis)  # block within the lane
    db = db_ref[0]  # [block_n, D] (lane-sliced by the BlockSpec)
    q = q_ref[...]  # [Q, D]
    valid = valid_ref[0]  # [block_n, 1] f32 (1.0 = valid)

    s = jax.lax.dot_general(
        q.astype(jnp.float32),
        db.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, block_n]
    s = jnp.where(valid[:, 0][None, :] > 0.5, s, NEG)

    Q = s.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, block_n), 1)
    base = j * block_n  # indices stay lane-local; ops.py keeps lanes separate
    for t in range(k):
        m = jnp.max(s, axis=1)
        hit = s >= m[:, None]
        idx = jnp.min(jnp.where(hit, col, jnp.int32(2**30)), axis=1)
        out_s_ref[0, 0, :, t] = m
        out_i_ref[0, 0, :, t] = idx + base
        s = jnp.where(col == idx[:, None], NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret", "grid_order"))
def similarity_topk_lanes_blocks(db, valid_f32, q, *, k: int, block_n: int = 512,
                                 interpret: bool = True,
                                 grid_order: str = "lanes_outer"):
    """db [L, N, D], valid_f32 [L, N, 1], q [Q, D] -> per-lane per-block
    candidates (scores [L, nb, Q, k], lane-local idx [L, nb, Q, k]).

    ``grid_order`` picks the grid iteration layout: ``lanes_outer`` walks
    (L, nb) — all of a lane's blocks stream consecutively — while
    ``blocks_outer`` walks (nb, L) — block j of every lane before block
    j+1, which can pipeline better when lanes are few and blocks are many.
    Sweep both with ``benchmarks/tune_topk.py`` on real hardware; results
    are identical either way."""
    L, N, D = db.shape
    Q = q.shape[0]
    assert N % block_n == 0, f"N={N} must be a multiple of block_n={block_n}"
    nb = N // block_n

    out_shape = (
        jax.ShapeDtypeStruct((L, nb, Q, k), jnp.float32),
        jax.ShapeDtypeStruct((L, nb, Q, k), jnp.int32),
    )
    if grid_order == "lanes_outer":
        grid = (L, nb)
        block_axis = 1
        lane_map = lambda l, j: (l, j, 0)  # noqa: E731
        out_map = lambda l, j: (l, j, 0, 0)  # noqa: E731
        q_map = lambda l, j: (0, 0)  # noqa: E731
    elif grid_order == "blocks_outer":
        grid = (nb, L)
        block_axis = 0
        lane_map = lambda j, l: (l, j, 0)  # noqa: E731
        out_map = lambda j, l: (l, j, 0, 0)  # noqa: E731
        q_map = lambda j, l: (0, 0)  # noqa: E731
    else:
        raise ValueError(f"unknown grid_order {grid_order!r}")

    kernel = functools.partial(
        _topk_lanes_kernel, k=k, block_n=block_n, block_axis=block_axis
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, D), lane_map),  # lane tile streams
            pl.BlockSpec((1, block_n, 1), lane_map),  # validity tile
            pl.BlockSpec((Q, D), q_map),  # queries resident
        ],
        out_specs=(
            pl.BlockSpec((1, 1, Q, k), out_map),
            pl.BlockSpec((1, 1, Q, k), out_map),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(db, valid_f32, q)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def similarity_topk_blocks(db, valid_f32, q, *, k: int, block_n: int = 512, interpret: bool = True):
    """Returns per-block candidates (scores [nb, Q, k], idx [nb, Q, k])."""
    N, D = db.shape
    Q = q.shape[0]
    assert N % block_n == 0, f"N={N} must be a multiple of block_n={block_n}"
    nb = N // block_n

    kernel = functools.partial(_topk_block_kernel, k=k, block_n=block_n)
    out_shape = (
        jax.ShapeDtypeStruct((nb, Q, k), jnp.float32),
        jax.ShapeDtypeStruct((nb, Q, k), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, D), lambda j: (j, 0)),  # db tile streams
            pl.BlockSpec((block_n, 1), lambda j: (j, 0)),  # validity tile
            pl.BlockSpec((Q, D), lambda j: (0, 0)),  # queries resident
        ],
        out_specs=(
            pl.BlockSpec((1, Q, k), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, Q, k), lambda j: (j, 0, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(db, valid_f32, q)
