"""Public wrapper for the SSD chunk scan (backend auto-selected)."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.backend import resolve_interpret
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def _ssd_scan(x, Bm, Cm, dt, A, D, *, chunk, interpret, use_kernel):
    if not use_kernel:
        return ssd_scan_ref(x, Bm, Cm, dt, A, D, chunk)
    return ssd_scan_kernel(x, Bm, Cm, dt, A, D, chunk=chunk, interpret=interpret)


def ssd_scan(x, Bm, Cm, dt, A, D, *, chunk: int = 128,
             interpret: Optional[bool] = None, use_kernel: bool = True):
    """x [B,S,H,P], Bm/Cm [B,S,H,N], dt [B,S,H], A/D [H] -> (y, final_state).

    ``interpret=None`` auto-selects: interpret on CPU, compiled Pallas on
    TPU/GPU (see repro.kernels.backend).
    """
    return _ssd_scan(x, Bm, Cm, dt, A, D, chunk=chunk,
                     interpret=resolve_interpret(interpret), use_kernel=use_kernel)
