"""Public jit'd wrapper for the SSD chunk scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd_scan(x, Bm, Cm, dt, A, D, *, chunk: int = 128, interpret: bool = True,
             use_kernel: bool = True):
    """x [B,S,H,P], Bm/Cm [B,S,H,N], dt [B,S,H], A/D [H] -> (y, final_state)."""
    if not use_kernel:
        return ssd_scan_ref(x, Bm, Cm, dt, A, D, chunk)
    return ssd_scan_kernel(x, Bm, Cm, dt, A, D, chunk=chunk, interpret=interpret)
