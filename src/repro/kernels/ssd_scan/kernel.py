"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid (B, nc) with the chunk axis innermost: the inter-chunk SSM state
[H, P, N] lives in VMEM scratch and is carried across sequential grid steps
(reset at chunk 0 of each sequence). Per chunk the kernel computes the
quadratic intra-chunk term — an [L, L] decay-masked score matrix per head —
and the state contribution, all in f32.

Head-level work is expressed as 2-D dot_generals per head (a static unroll):
Mosaic's MXU path wants plain 2-D dots, and L, N, P are 64..256 so each dot
is already hardware-shaped. VMEM per step: x/B/C tiles L*(H/unit)*(P|N)*4
plus the [H, P, N] state — with L=128, H=8-per-call, P=64, N=128 that is
~0.8 MB (models with larger H shard heads over the TP axis first; the
kernel is invoked per head shard).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, d_ref, y_ref, st_out_ref, state_scr,
                *, L: int, H: int, P: int, N: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    tril = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (L, L), 1
    )

    for h in range(H):  # static unroll: per-head 2-D dots (MXU-shaped)
        x = x_ref[0, :, h, :].astype(jnp.float32)  # [L, P]
        Bm = b_ref[0, :, h, :].astype(jnp.float32)  # [L, N]
        Cm = c_ref[0, :, h, :].astype(jnp.float32)  # [L, N]
        dt = dt_ref[0, :, h].astype(jnp.float32)  # [L]
        A = a_ref[h]
        dA = dt * A  # <= 0
        cs = jnp.cumsum(dA)  # [L]

        state = state_scr[h]  # [P, N]
        # inter-chunk contribution
        y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)  # [L, P]
        y_off = y_off * jnp.exp(cs)[:, None]
        # intra-chunk quadratic term
        decay = jnp.exp(cs[:, None] - cs[None, :])
        scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)  # [L, L]
        scores = scores * decay * dt[None, :]
        scores = jnp.where(tril, scores, 0.0)
        y_diag = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        # state update
        last = cs[L - 1]
        w = jnp.exp(last - cs) * dt  # [L]
        state_new = state * jnp.exp(last) + jax.lax.dot_general(
            x * w[:, None], Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # [P, N]
        state_scr[h] = state_new
        y_ref[0, :, h, :] = (y_diag + y_off + d_ref[h] * x).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_out_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x, Bm, Cm, dt, A, D, *, chunk: int = 128, interpret: bool = True):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    kernel = functools.partial(_ssd_kernel, L=L, H=H, P=P, N=N, nc=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, L, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, L, H, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, L, H, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, L, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((1, L, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, Bm, Cm, dt.astype(jnp.float32), A.astype(jnp.float32), D.astype(jnp.float32))
    return y, st
