"""Pure-jnp oracle for the Mamba2 SSD chunk scan.

This is the same math as repro.models.ssm._ssd_chunk_scan, exposed on raw
tensors so the kernel sweep can drive it directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import _ssd_chunk_scan


def ssd_scan_ref(x, Bm, Cm, dt, A, D, chunk: int):
    """x [B,S,H,P], Bm/Cm [B,S,H,N], dt [B,S,H] f32, A [H] (<0), D [H].

    Returns (y [B,S,H,P], final_state [B,H,P,N] f32).
    """
    return _ssd_chunk_scan(x, Bm, Cm, dt.astype(jnp.float32), A.astype(jnp.float32),
                           D.astype(jnp.float32), chunk)
