"""Continuous-batching serving engine.

A fixed decode batch of `max_batch` slots runs one jitted decode_step per
tick; requests are admitted into free slots as they arrive (prefill writes
the slot's rows of the stacked KV cache), finished sequences free their slot
immediately — the vLLM-style continuous batching loop, with the semantic
cache sitting in front via ModelBackend/EnhancedClient.

Engine-level integration with the paper's cache: ModelBackend exposes any
zoo model as an LLMBackend, so the EnhancedClient can front real JAX models
with GenerativeCache — embed -> lookup -> miss -> engine.generate -> insert.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import LLMBackend, LLMResponse
from repro.models import transformer as T
from repro.serving.kv_cache import SlotManager
from repro.serving.sampler import sample_tokens


@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt token ids [S]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # absolute time.perf_counter() stamp; the engine cancels the request
    # (freeing its decode slot) once this passes — even mid-generation
    deadline_t: Optional[float] = None
    expired: bool = False  # canceled by deadline; out_tokens hold the partial


class ServingEngine:
    def __init__(self, cfg, params=None, *, max_batch: int = 4, max_seq: int = 256,
                 eos_id: Optional[int] = None, seed: int = 0):
        self.cfg = cfg
        if params is None:
            params, _ = T.init_params(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.slots = SlotManager(max_batch)
        self.cache, _ = T.init_cache(cfg, max_batch, max_seq)
        # the submission queue is the engine's only cross-thread surface:
        # CacheService's miss dispatcher and sync callers may submit while
        # another thread drives run() (see `# guarded-by:` convention in
        # repro.serving.service)
        self.pending: List[Request] = []  # guarded-by: _lock
        self._next_rid = 0  # guarded-by: _lock
        self._lock = threading.Lock()
        # decode state (active/slots/cache/_key) is single-driver by design:
        # whoever calls run() owns it (ModelBackend serializes drivers)
        self.active: Dict[int, Request] = {}
        self._key = jax.random.PRNGKey(seed + 1)
        self.metrics = {"prefill_tokens": 0, "decode_steps": 0, "requests": 0}

        self._decode = jax.jit(lambda p, t, pos, c: T.decode_step(p, cfg, t, pos, c))
        self._prefill_cache: Dict[int, object] = {}

    # -- jit helpers --------------------------------------------------------

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            cfg = self.cfg

            def fn(params, tokens, cache_slot):
                logits, new_cache = T.prefill(params, cfg, {"tokens": tokens}, cache_slot)
                return logits, new_cache

            self._prefill_cache[length] = jax.jit(fn)
        return self._prefill_cache[length]

    @staticmethod
    def _bucket(n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return b

    # -- API --------------------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int = 32, temperature: float = 0.0,
               deadline_t: Optional[float] = None) -> int:
        return self._submit_req(tokens, max_new_tokens, temperature, deadline_t).rid

    def _submit_req(self, tokens, max_new_tokens: int = 32, temperature: float = 0.0,
                    deadline_t: Optional[float] = None) -> Request:
        req = Request(0, np.asarray(tokens, np.int32), max_new_tokens, temperature,
                      submitted_at=time.perf_counter(), deadline_t=deadline_t)
        with self._lock:
            req.rid = self._next_rid
            self._next_rid += 1
            self.pending.append(req)
        self.metrics["requests"] += 1
        return req

    def _pop_pending(self) -> Optional[Request]:
        with self._lock:
            return self.pending.pop(0) if self.pending else None

    def _has_pending(self) -> bool:
        with self._lock:
            return bool(self.pending)

    def _expire(self, req: Request) -> None:
        req.done = True
        req.expired = True
        req.finished_at = time.perf_counter()
        self.metrics["deadline_cancels"] = self.metrics.get("deadline_cancels", 0) + 1

    def _admit(self) -> None:
        while self.slots.free:
            req = self._pop_pending()
            if req is None:
                return
            if req.deadline_t is not None and time.perf_counter() > req.deadline_t:
                self._expire(req)  # expired in queue: never claims a slot
                continue
            slot = self.slots.alloc()
            req.slot = slot
            S = len(req.tokens)
            # exact-length prefill (jit cached per length): right-padding would
            # corrupt SSM/hybrid recurrent state, so none is used.
            slot_cache, _ = T.init_cache(self.cfg, 1, self.max_seq)
            logits, filled = self._prefill_fn(S)(
                self.params, jnp.asarray(req.tokens[None]), slot_cache
            )
            self.cache = jax.tree.map(
                lambda big, one: big.at[:, slot].set(one[:, 0]), self.cache, filled
            )
            # sample the first generated token directly from prefill logits
            self._key, sub = jax.random.split(self._key)
            tok = int(np.asarray(sample_tokens(logits, sub, temperature=req.temperature))[0])
            req.out_tokens.append(tok)
            req.first_token_at = time.perf_counter()
            self.slots.lengths[slot] = S  # tokens whose KV/state is in the cache
            self.metrics["prefill_tokens"] += S
            self.active[req.rid] = req

    def _tick_decode(self) -> None:
        # deadline cancellation: a request whose deadline passed mid-
        # generation stops decoding NOW and frees its slot for the next
        # pending request (capacity is returned to the continuous batch)
        now = time.perf_counter()
        expired = [
            r for r in self.active.values()
            if r.deadline_t is not None and now > r.deadline_t
        ]
        for req in expired:
            self._expire(req)
            self.slots.release(req.slot)
            del self.active[req.rid]
        if not self.active:
            return
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        for req in self.active.values():
            s = req.slot
            tokens[s, 0] = req.out_tokens[-1]  # newest generated token
            pos[s] = self.slots.lengths[s]  # position the new token occupies
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), jnp.asarray(pos), self.cache
        )
        self.metrics["decode_steps"] += 1
        self._key, sub = jax.random.split(self._key)
        temps = {req.rid: req.temperature for req in self.active.values()}
        any_temp = any(t > 0 for t in temps.values())
        sampled = np.asarray(
            sample_tokens(logits, sub, temperature=1.0 if any_temp else 0.0)
        )
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for req in self.active.values():
            s = req.slot
            tok = sampled[s] if req.temperature > 0 else greedy[s]
            tok = int(tok if np.ndim(tok) == 0 else tok.flat[0])
            req.out_tokens.append(tok)
            self.slots.lengths[s] += 1
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id)
                or self.slots.lengths[s] >= self.max_seq - 1
            ):
                req.done = True
                req.finished_at = time.perf_counter()
                finished.append(req.rid)
        for rid in finished:
            self.slots.release(self.active[rid].slot)
            del self.active[rid]

    def run(self) -> None:
        """Drive until all submitted work completes (continuous batching)."""
        while self._has_pending() or self.active:
            self._admit()
            self._tick_decode()

    def generate_ex(self, prompts: List[np.ndarray], max_new_tokens: int = 32,
                    temperature: float = 0.0,
                    deadlines: Optional[List[Optional[float]]] = None) -> List[Request]:
        """Continuous-batching generation returning the Request records
        (tokens + expiry state). ``deadlines`` are absolute perf_counter
        stamps; a request that outlives its deadline mid-generation is
        canceled — its slot frees immediately for the next pending request
        and it comes back with ``expired=True`` and the partial tokens."""
        deadlines = deadlines if deadlines is not None else [None] * len(prompts)
        # hold the Request records directly — another thread's run() may admit
        # (and drop from `pending`) anything we enqueue before we snapshot
        reqs = [
            self._submit_req(p, max_new_tokens, temperature, deadline_t=d)
            for p, d in zip(prompts, deadlines)
        ]
        self.run()
        return reqs

    def generate(self, prompts: List[np.ndarray], max_new_tokens: int = 32,
                 temperature: float = 0.0) -> List[List[int]]:
        return [
            r.out_tokens
            for r in self.generate_ex(prompts, max_new_tokens, temperature)
        ]


class ModelBackend(LLMBackend):
    """Adapts a ServingEngine to the EnhancedClient LLMBackend interface.

    Prompts are hashed to token ids (offline-deterministic); outputs are
    rendered as token-id text — deterministic, cacheable content."""

    def __init__(self, name: str, engine: ServingEngine, max_prompt_tokens: int = 32):
        self.name = name
        # the engine's slot/cache state is not reentrant: the CacheService
        # dispatcher and any sync caller must serialize their batches
        self.engine = engine  # guarded-by: _lock
        self.max_prompt_tokens = max_prompt_tokens
        # immutable config captured up front so the lock-free tokenize/guard
        # paths never reach through the guarded engine reference
        self._vocab_size = engine.cfg.vocab_size
        self._modality = engine.cfg.modality
        self._lock = threading.Lock()

    def _tokenize(self, prompt: str) -> np.ndarray:
        import hashlib

        words = prompt.split()[: self.max_prompt_tokens] or ["empty"]
        V = self._vocab_size
        ids = [
            int.from_bytes(hashlib.blake2b(w.encode(), digest_size=4).digest(), "little") % V
            for w in words
        ]
        # pad deterministically to a FIXED length: one prefill compile for all
        # prompts (SSM state stays exact — pads are real tokens at the front
        # of the prompt, not maskable right-padding)
        while len(ids) < self.max_prompt_tokens:
            ids.insert(0, 7)  # deterministic BOS-ish filler
        return np.asarray(ids, np.int32)

    def generate(self, prompt: str, max_tokens: int = 32, temperature: float = 0.0) -> LLMResponse:
        return self.generate_batch([prompt], max_tokens, temperature)[0]

    def generate_batch(
        self, prompts: List[str], max_tokens: int = 32, temperature: float = 0.0,
        deadlines: Optional[List[Optional[float]]] = None,
    ) -> List[LLMResponse]:
        """Serve the whole miss batch in ONE continuous-batching pass: all
        prompts are submitted up front, so the engine keeps its decode slots
        full instead of draining one request at a time. ``deadlines``
        (absolute perf_counter stamps) propagate into the engine: a request
        whose deadline passes mid-generation is canceled, frees its decode
        slot, and resolves with ``expired=True`` (the service maps it to a
        typed ``deadline_exceeded`` response)."""
        t0 = time.perf_counter()
        if self._modality == "audio":
            raise NotImplementedError("audio backends serve token streams, not text prompts")
        toks = [self._tokenize(p) for p in prompts]
        with self._lock:
            reqs = self.engine.generate_ex(
                toks, max_new_tokens=max_tokens, temperature=temperature,
                deadlines=deadlines,
            )
        latency = time.perf_counter() - t0
        return [
            LLMResponse(" ".join(f"t{t}" for t in r.out_tokens), self.name,
                        tokens_in=len(tk), tokens_out=len(r.out_tokens),
                        latency_s=latency, expired=r.expired)
            for tk, r in zip(toks, reqs)
        ]
