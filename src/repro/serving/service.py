"""Async-first cache serving layer — the paper's latency story as an API.

The paper's headline numbers are a latency *gap*: hits answer in
milliseconds while misses wait seconds-to-minutes on a backend. A blocking
batch call erases the gap — a hit sharing a batch with one slow miss
returns at miss latency. ``CacheService`` keeps it:

    service.submit(CacheRequest(...)) -> concurrent.futures.Future[CacheResponse]

A priority-aware front scheduler micro-batches submissions through the
batched embed -> search -> decide stage (one embed forward + one search
dispatch per admitted batch, exactly like ``complete_batch``); hit and
generative-hit futures resolve right there. The miss residue is forwarded
— original future, priority, and deadline intact — to a background
dispatcher that coalesces misses by priority, resolves deadline-expired
ones with a typed ``DEADLINE_EXCEEDED`` response instead of generating,
dedups near-identical queued misses (embedding cosine above the hit
threshold — a cold paraphrase burst generates ONCE, the follower futures
resolve from the leader's result), and fans each (model, max_tokens,
temperature) group to the backend in one ``generate_batch``, backfilling
the cache with one scatter per level. The lookup stage itself rides the
banked hierarchy path: the levels' stores are prewarmed into one stacked
``StoreBank`` at service construction, so the embed -> search stage costs
ONE fused top-k dispatch for the whole hierarchy per admitted batch.

Backpressure is explicit: ``submit`` fast-fails with ``AdmissionRejected``
once ``max_inflight`` futures are unresolved, and raises ``ServiceClosed``
after ``close()`` (which drains both schedulers so every accepted future
resolves).

``complete(requests)`` runs the same two phases inline in the caller's
thread — the compatibility path behind ``EnhancedClient.query`` /
``complete_batch``, which are now thin sync wrappers. ``asubmit`` /
``acomplete`` wrap the futures for asyncio callers.

Lock discipline (`# guarded-by:` convention)
--------------------------------------------
The serving layer's mutable cross-thread state declares its lock with a
trailing comment on the ``__init__`` assignment::

    self._inflight = 0  # guarded-by: _lock

The contract — enforced at lint time by ``python -m repro.analysis``
(checker RA301) — is that every later ``self.<attr>`` access sits inside a
``with self._lock:`` block. Condition variables built over a lock
(``threading.Condition(self._lock)``) count as aliases of that lock; a
method documented to be *called* with the lock held may declare
``# repro: holds[_lock]`` on its ``def`` line instead. The same convention
covers ``BatchCoalescer`` (``_cv``), ``ServingEngine``/``ModelBackend``
(``_lock``), and ``EnhancedClient`` (``_state_lock``).
"""
from __future__ import annotations

import asyncio
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.client import ClientResult, EnhancedClient, LLMResponse
from repro.core.request import (
    DEADLINE_EXCEEDED,
    GENERATED,
    HIT,
    STALE,
    CacheChunk,
    CacheRequest,
    CacheResponse,
    split_stream_tokens,
)
from repro.resilience.errors import AllBackendsFailed
from repro.serving.coalescer import (  # noqa: F401 — re-exported service errors
    AdmissionRejected,
    BatchCoalescer,
    DeadlineExceeded,
    ServiceClosed,
)


def _accepts_return_vecs(target) -> bool:
    """A cache/hierarchy subclass overriding ``lookup_batch`` with the
    pre-fused signature (no ``return_vecs``) must keep working behind the
    service — probe the override's own signature once per class."""
    from repro.core.client import accepts_kwarg

    return accepts_kwarg(type(target), "lookup_batch", "return_vecs")


@dataclass
class _Pending:
    """A submitted request in flight through the service."""

    request: CacheRequest
    rid: int
    chosen: str  # backend resolved at submit (escalation ladder state then)
    t_submit: float
    deadline_t: Optional[float]  # absolute perf_counter stamp, None = no deadline
    vec: Optional[np.ndarray] = None  # set by the lookup stage, reused at backfill


@dataclass
class ServiceStats:
    submitted: int = 0
    hits: int = 0
    generated: int = 0
    expired: int = 0
    rejected: int = 0
    deduped: int = 0  # queued misses resolved from another miss's generation
    stale_served: int = 0  # expired entries served stale-if-error (backends down)
    backend_unavailable: int = 0  # misses that hit AllBackendsFailed with no stale answer


class CacheService:
    def __init__(
        self,
        client: EnhancedClient,
        *,
        max_batch: int = 8,
        max_wait_ms: float = 2.0,
        dispatch_batch: Optional[int] = None,
        dispatch_wait_ms: Optional[float] = None,
        max_inflight: int = 1024,
        dedup_misses: bool = True,
        dedup_threshold: Optional[float] = None,
    ):
        self.client = client
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.dispatch_batch = dispatch_batch if dispatch_batch is not None else max_batch
        self.dispatch_wait_ms = (
            dispatch_wait_ms if dispatch_wait_ms is not None else max_wait_ms
        )
        self.max_inflight = max_inflight
        # in-flight miss dedup (async dispatcher only): a cold paraphrase
        # burst looks itself up against one snapshot before any backfill
        # lands, so N near-identical queued misses would all generate —
        # coalesce them onto one backend call instead (cosine >= the hit
        # threshold; dedup_threshold overrides the per-request policy value)
        self.dedup_misses = dedup_misses
        self.dedup_threshold = dedup_threshold
        self.stats = ServiceStats()
        self._inflight = 0  # guarded-by: _lock
        self._lock = threading.Lock()  # service counters + lifecycle
        self._capacity = threading.Condition(self._lock)  # blocking-submit waits
        # client-owned: every service sharing this client serializes its store
        # lookups against backfill scatters through the same lock
        self._cache_lock = client._cache_lock
        self._closed = False  # guarded-by: _lock
        # schedulers start lazily: the sync complete() path never spawns threads
        self._lookup_sched: Optional[BatchCoalescer] = None
        self._miss_sched: Optional[BatchCoalescer] = None
        # prewarm the fused hierarchy bank so the first admitted batch pays
        # the banked one-dispatch lookup, not the adoption copy
        if client.hierarchy is not None:
            with self._cache_lock:
                h = client.hierarchy
                # sharded tier first (mirrors lookup_batch's tier order);
                # an all-replicated hierarchy falls through to the bank
                if getattr(h, "ensure_sharded_bank", lambda: None)() is None:
                    getattr(h, "ensure_bank", lambda: None)()

    # -- async API -------------------------------------------------------------

    def submit(self, request: CacheRequest, *, block: bool = False) -> "Future[CacheResponse]":
        """Admit one request; the returned future resolves with a typed
        ``CacheResponse`` (hit in milliseconds, generated at backend pace,
        or ``DEADLINE_EXCEEDED``). Raises ``AdmissionRejected`` when the
        in-flight budget is spent (``block=True`` waits for capacity
        instead), ``ServiceClosed`` after ``close``."""
        client = self.client
        with self._lock:
            while block and self._inflight >= self.max_inflight and not self._closed:
                self._capacity.wait()
            if self._closed:
                raise ServiceClosed("service is closed")
            if self._inflight >= self.max_inflight:
                self.stats.rejected += 1
                raise AdmissionRejected(
                    f"in-flight budget exhausted ({self.max_inflight} requests)"
                )
            self._inflight += 1
            self.stats.submitted += 1
            # under the same lock as the closed-check: close() cannot slip in
            # between admission and scheduler startup and strand the request
            self._ensure_started()
        with client._state_lock:
            client.stats.requests += 1
            rid = client._next_id
            client._next_id += 1
        pending = self._pending(request, rid, time.perf_counter())
        try:
            fut = self._lookup_sched.submit(pending, priority=request.priority)
        except BaseException:
            self._release(None)
            raise
        fut.add_done_callback(self._release)
        return fut

    def submit_many(self, requests: Sequence[CacheRequest]) -> List["Future[CacheResponse]"]:
        """Bulk submit that blocks for capacity instead of shedding — the
        sync helpers (``query_many``/``broadcast``) must never abandon
        futures they already hold. ``ServiceClosed`` still propagates."""
        return [self.submit(r, block=True) for r in requests]

    def asubmit(self, request: CacheRequest) -> "asyncio.Future[CacheResponse]":
        """Awaitable ``submit`` for asyncio callers (needs a running loop)."""
        return asyncio.wrap_future(self.submit(request))

    async def acomplete(
        self, request: Union[CacheRequest, str], **hints
    ) -> CacheResponse:
        """One-shot asyncio facade: ``await service.acomplete("prompt")``."""
        if not isinstance(request, CacheRequest):
            request = CacheRequest(request, **hints)
        return await self.asubmit(request)

    async def astream(
        self,
        request: CacheRequest,
        *,
        pace_s: float = 0.0,
        chunk_tokens: int = 1,
    ):
        """Streamed delivery: resolve ``request`` through the normal
        submit path, then replay the answer as ``CacheChunk``s whose
        concatenated text is byte-identical to the non-streamed response.

        Cache hits resolve in milliseconds but replay through the SAME
        chunked surface as generated misses — with ``pace_s`` > 0 sleeping
        between chunks, a client watching the stream cannot tell a replayed
        hit from a live generation (the paper's drop-in-proxy story; the
        gateway surfaces the truth in its ``X-Cache`` header instead).
        ``chunk_tokens`` groups several tokens per chunk for long answers.
        Typed failures (deadline expiry) still yield exactly one final
        chunk carrying the typed response, so every stream terminates.
        Submission errors (``AdmissionRejected``/``ServiceClosed``) raise
        before the first chunk — nothing has streamed yet, so the caller
        can still map them to a clean error response."""
        resp = await self.asubmit(request)
        tokens = split_stream_tokens(resp.text or "")
        if chunk_tokens > 1:
            tokens = [
                "".join(tokens[i : i + chunk_tokens])
                for i in range(0, len(tokens), chunk_tokens)
            ]
        if not tokens:
            yield CacheChunk("", 0, True, resp)
            return
        last = len(tokens) - 1
        for i, tok in enumerate(tokens):
            yield CacheChunk(tok, i, i == last, resp)
            if pace_s > 0.0 and i != last:
                await asyncio.sleep(pace_s)

    # -- sync compatibility path ------------------------------------------------

    def complete(self, requests: Sequence[CacheRequest]) -> List[CacheResponse]:
        """Serve a batch inline in the caller's thread (no scheduler hop):
        the same lookup + dispatch phases, resolved before returning. This
        is the path behind ``EnhancedClient.query`` / ``complete_batch``.

        Misses dispatch in (model, max_tokens, temperature) groups; if one
        group's generation fails on every backend, its error raises after
        earlier groups already generated and backfilled (their results are
        dropped — the stats and the cache keep them, matching what a retry
        would then hit)."""
        reqs = list(requests)
        n = len(reqs)
        if n == 0:
            return []
        client = self.client
        t0 = time.perf_counter()
        with self._lock:
            if self._closed:
                raise ServiceClosed("service is closed")
            self.stats.submitted += n
        with client._state_lock:
            rid0 = client._next_id
            client._next_id += n
            client.stats.requests += n
        pendings = [self._pending(r, rid0 + i, t0) for i, r in enumerate(reqs)]
        with self._cache_lock:
            responses = self._lookup_phase(pendings)
        miss = [i for i in range(n) if responses[i] is None]
        if miss:
            outcomes = self._dispatch_phase([pendings[i] for i in miss])
            for i, out in zip(miss, outcomes):
                if isinstance(out, Exception):
                    raise out
                responses[i] = out
        return responses  # type: ignore[return-value]

    # -- lifecycle -------------------------------------------------------------

    def clear(self, older_than: Optional[float] = None) -> int:
        """Prune the cache behind the service: everything, or — with
        ``older_than`` (seconds) — entries created more than that long ago
        plus anything already expired. Serialized against in-flight lookups
        and backfills through the shared cache lock; cascades through every
        hierarchy level and its host-RAM tier. Returns entries dropped."""
        client = self.client
        target = client.hierarchy if client.hierarchy is not None else client.cache
        clear = getattr(target, "clear", None)
        if clear is None:
            return 0
        with self._cache_lock:
            return int(clear(older_than=older_than))

    def close(self, timeout: Optional[float] = 10.0) -> None:
        """Stop admissions and drain: lookup first (misses forward to the
        dispatcher), then the dispatcher — every accepted future resolves."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._capacity.notify_all()  # wake blocking submitters -> ServiceClosed
        if self._lookup_sched is not None:
            self._lookup_sched.close(timeout=timeout)
        if self._miss_sched is not None:
            self._miss_sched.close(timeout=timeout)

    def __enter__(self) -> "CacheService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def inflight(self) -> int:
        """Accepted-but-unresolved futures right now — the gateway's
        graceful drain watches this reach zero before closing the service."""
        with self._lock:
            return self._inflight

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    @property
    def scheduler_stats(self) -> Tuple:
        """(lookup, dispatch) CoalescerStats, None before the first submit."""
        return (
            self._lookup_sched.stats if self._lookup_sched else None,
            self._miss_sched.stats if self._miss_sched else None,
        )

    # -- internals --------------------------------------------------------------

    def _pending(self, request: CacheRequest, rid: int, t_submit: float) -> _Pending:
        deadline_t = (
            None if request.deadline_s is None else t_submit + request.deadline_s
        )
        return _Pending(
            request, rid, self.client._select_model(request.model), t_submit, deadline_t
        )

    def _release(self, _fut: Optional[Future]) -> None:
        with self._lock:
            self._inflight -= 1
            self._capacity.notify_all()

    def _ensure_started(self) -> None:
        """Start the schedulers on first use (caller holds ``self._lock``).
        The sync ``complete`` path never calls this, so it spawns no threads."""
        if self._lookup_sched is not None:
            return
        self._miss_sched = BatchCoalescer(
            self._run_dispatch,
            max_batch=self.dispatch_batch,
            max_wait_ms=self.dispatch_wait_ms,
            max_queue=0,  # max_inflight already bounds admissions
            owns_futures=True,
            on_expired=self._expire,
        )
        self._lookup_sched = BatchCoalescer(
            self._run_lookup,
            max_batch=self.max_batch,
            max_wait_ms=self.max_wait_ms,
            max_queue=0,
            owns_futures=True,
        )

    def _expire(self, pending: _Pending, fut: Future) -> None:
        """Scheduler hook: a queued miss outlived its deadline — resolve the
        future with the typed response; the backend is never called."""
        with self._lock:
            self.stats.expired += 1
        resp = CacheResponse(
            None, DEADLINE_EXCEEDED, False, None, None, pending.chosen, 0.0,
            time.perf_counter() - pending.t_submit, pending.rid,
        )
        if not fut.done():
            fut.set_result(resp)

    # -- phase A: batched embed -> search -> decide ------------------------------

    def _run_lookup(self, pendings: List[_Pending], futs: List[Future]) -> None:
        with self._cache_lock:
            responses = self._lookup_phase(pendings)
        for pending, fut, resp in zip(pendings, futs, responses):
            if resp is not None:  # hit/generative hit: resolve NOW
                if not fut.done():
                    fut.set_result(resp)
            else:  # miss residue: original future rides to the dispatcher
                self._miss_sched.submit(
                    pending,
                    priority=pending.request.priority,
                    deadline_t=pending.deadline_t,
                    future=fut,
                )

    def _lookup_phase(
        self, pendings: List[_Pending]
    ) -> List[Optional[CacheResponse]]:
        """One fused read program for the admitted batch (embed -> search ->
        decide -> touch in a single device dispatch — repro.core.read_path);
        returns a response per hit and None for each miss. The embeddings
        come back with the decision tensors and are stashed on the pendings
        for the dedup/backfill stages — no second forward."""
        client = self.client
        n = len(pendings)
        responses: List[Optional[CacheResponse]] = [None] * n
        target = client.hierarchy if client.hierarchy is not None else client.cache
        if target is None:
            return responses
        owner = client.hierarchy.l1 if client.hierarchy is not None else client.cache
        embed_idx = [i for i, p in enumerate(pendings) if p.request.use_cache]
        if not embed_idx:
            return responses
        lk = [i for i in embed_idx if not pendings[i].request.force_fresh]
        ff = [i for i in embed_idx if pendings[i].request.force_fresh]
        if ff:
            # force_fresh skips the lookup but still needs embeddings for
            # dedup + backfill: a separate forward for the (rare) residue
            vecs_ff = np.asarray(
                owner.embed_batch([pendings[i].request.prompt for i in ff])
            )
            for j, i in enumerate(ff):
                pendings[i].vec = vecs_ff[j]
        if not lk:
            return responses
        prompts = [pendings[i].request.prompt for i in lk]
        contexts = [
            client._context_for(pendings[i].request, pendings[i].chosen) for i in lk
        ]
        if _accepts_return_vecs(target):
            cache_results, vecs = target.lookup_batch(
                prompts, contexts, return_vecs=True
            )
        else:
            # a cache subclass overriding lookup_batch with the pre-fused
            # signature: embed here (its own forward) and call it compatibly
            vecs = np.asarray(owner.embed_batch(prompts))
            cache_results = target.lookup_batch(prompts, contexts, vecs=vecs)
        for j, i in enumerate(lk):
            pendings[i].vec = np.asarray(vecs[j])
        now = time.perf_counter()
        for i, cr in zip(lk, cache_results):
            if not cr.hit:
                continue
            p = pendings[i]
            resp = CacheResponse(
                cr.response, HIT, True, cr, None, "cache", 0.0, now - p.t_submit, p.rid
            )
            with self._lock:
                self.stats.hits += 1
            with client._state_lock:
                client.stats.cache_hits += 1
                client._results[p.rid] = client._to_client_result(resp)
                if client.cost_ctl:
                    client.cost_ctl.record(0.0, True)
            responses[i] = resp
        return responses

    # -- phase B: miss dispatch + backfill ---------------------------------------

    def _run_dispatch(self, pendings: List[_Pending], futs: List[Future]) -> None:
        # the async dispatcher dedups near-identical queued misses; the sync
        # complete() path does not (it must match B sequential lookups)
        outcomes = self._dispatch_phase(pendings, dedup=self.dedup_misses)
        for fut, out in zip(futs, outcomes):
            if fut.done():
                continue
            if isinstance(out, Exception):
                fut.set_exception(out)
            else:
                fut.set_result(out)

    def _dedup_misses(
        self, pendings: List[_Pending], live: List[int]
    ) -> Dict[int, int]:
        """Coalesce near-identical queued misses onto one generation.

        Returns follower index -> leader index. Two misses coalesce when
        they would dispatch identically ((model, max_tokens, temperature)
        group) and their embeddings' cosine clears the follower's hit
        threshold — i.e. had the leader's answer already been backfilled,
        the follower's lookup would have HIT it. First-submitted wins
        leadership; ``force_fresh`` requests never coalesce either way."""
        client = self.client
        owner = client.hierarchy.l1 if client.hierarchy is not None else client.cache
        if owner is None:
            return {}
        # the dedup criterion is cosine-vs-threshold; on a euclidean/dot cache
        # the threshold lives in a different score space and would mis-coalesce
        if getattr(getattr(owner, "store", None), "metric", None) != "cosine":
            return {}
        by_group: Dict[tuple, List[int]] = {}
        for i in live:
            p = pendings[i]
            if not p.request.use_cache or p.request.force_fresh or p.vec is None:
                continue
            key = (p.chosen, p.request.max_tokens, p.request.temperature)
            by_group.setdefault(key, []).append(i)
        leader_of: Dict[int, int] = {}
        for idxs in by_group.values():
            leaders: List[Tuple[int, np.ndarray, float]] = []  # (idx, vec, norm)
            for i in idxs:
                p = pendings[i]
                v = np.asarray(p.vec, np.float64).ravel()
                nv = float(np.linalg.norm(v)) or 1.0
                thr = (
                    self.dedup_threshold
                    if self.dedup_threshold is not None
                    else owner.effective_threshold(
                        p.request.prompt, client._context_for(p.request, p.chosen)
                    )
                )
                best, best_j = -1.0, None
                for j, w, nw in leaders:
                    cos = float(v @ w) / (nv * nw)
                    if cos > best:
                        best, best_j = cos, j
                if best_j is not None and best > thr:
                    leader_of[i] = best_j
                else:
                    leaders.append((i, v, nv))
        if leader_of:
            with self._lock:
                self.stats.deduped += len(leader_of)
        return leader_of

    def _dispatch_phase(
        self, pendings: List[_Pending], dedup: bool = False,
        _regen_depth: int = 0,
    ) -> List[Union[CacheResponse, Exception]]:
        """Generate the miss residue: expired misses resolve typed (no
        backend call), near-identical misses coalesce onto one generation
        (``dedup=True``, the async dispatcher), the rest group by
        (model, max_tokens, temperature) into one ``generate_batch`` each,
        then backfill the cache with one scatter per destination level
        before the futures resolve. A deduped follower whose leader expired
        mid-generation re-dispatches (``_regen_depth`` bounds the recursion)
        when the follower itself still has deadline headroom."""
        client = self.client
        n = len(pendings)
        outcomes: List[Optional[Union[CacheResponse, Exception]]] = [None] * n
        llm_resps: List[Optional[LLMResponse]] = [None] * n
        now = time.perf_counter()
        live: List[int] = []
        for i, p in enumerate(pendings):
            if p.deadline_t is not None and now > p.deadline_t:
                with self._lock:
                    self.stats.expired += 1
                outcomes[i] = CacheResponse(
                    None, DEADLINE_EXCEEDED, False, None, None, p.chosen, 0.0,
                    now - p.t_submit, p.rid,
                )
            else:
                live.append(i)

        leader_of = self._dedup_misses(pendings, live) if dedup else {}
        groups: Dict[tuple, List[int]] = {}
        for i in live:
            if i in leader_of:
                continue  # rides its leader's generation
            p = pendings[i]
            key = (p.chosen, p.request.max_tokens, p.request.temperature)
            groups.setdefault(key, []).append(i)
        for (model, max_tokens, temperature), idxs in groups.items():
            prompts = [pendings[i].request.prompt for i in idxs]
            ddls = [pendings[i].deadline_t for i in idxs]
            try:
                resps = client._generate_batch_with_failover(
                    model, prompts, max_tokens, temperature,
                    deadlines=ddls if any(d is not None for d in ddls) else None,
                )
                if len(resps) != len(idxs):  # fail fast on a short batch
                    raise RuntimeError(
                        f"backend returned {len(resps)} responses for {len(idxs)} prompts"
                    )
            except AllBackendsFailed as e:
                # degradation ladder: every backend open/down -> rows that
                # opted in (allow_stale) try the expired-inventory lookup
                # before the typed backend_unavailable error reaches a future
                served = self._serve_stale([pendings[i] for i in idxs])
                for j, i in enumerate(idxs):
                    stale = served.get(j)
                    if stale is not None:
                        outcomes[i] = stale
                    else:
                        with self._lock:
                            self.stats.backend_unavailable += 1
                        outcomes[i] = e
                continue
            except Exception as e:  # noqa: BLE001 — the group's futures carry it
                for i in idxs:
                    outcomes[i] = e
                continue
            for i, resp in zip(idxs, resps):
                if getattr(resp, "expired", False):
                    # deadline passed MID-generation: the deadline-aware
                    # backend canceled the slot; resolve typed, cache nothing
                    p = pendings[i]
                    with self._lock:
                        self.stats.expired += 1
                    outcomes[i] = CacheResponse(
                        None, DEADLINE_EXCEEDED, False, None, None, p.chosen, 0.0,
                        time.perf_counter() - p.t_submit, p.rid,
                    )
                    continue
                cost = client._cost_of(resp.model, resp)
                resp.cost_usd = cost
                with self._lock:
                    self.stats.generated += 1
                with client._state_lock:
                    client.stats.llm_calls += 1
                    client.stats.total_cost_usd += cost
                    if client.cost_ctl:
                        client.cost_ctl.record(cost, False)
                llm_resps[i] = resp

        generated = [i for i in live if llm_resps[i] is not None]
        self._backfill(
            [pendings[i] for i in generated], [llm_resps[i] for i in generated]
        )
        done = time.perf_counter()
        for i in generated:
            p, resp = pendings[i], llm_resps[i]
            out = CacheResponse(
                resp.text, GENERATED, False, None, resp, resp.model, resp.cost_usd,
                done - p.t_submit, p.rid,
            )
            with client._state_lock:
                client.stats.total_latency_s += out.latency_s
                client._results[p.rid] = client._to_client_result(out)
            outcomes[i] = out
        # deduped followers resolve from their leader's single generation:
        # same text, zero marginal cost, no second backfill scatter
        regen: List[int] = []
        for i, j in leader_of.items():
            p, resp = pendings[i], llm_resps[j]
            if resp is None:
                lead_out = outcomes[j]
                if not isinstance(lead_out, CacheResponse):
                    outcomes[i] = lead_out  # group failure — carry its error
                    continue
                # the leader expired mid-generation; its deadline is NOT the
                # follower's. A follower with headroom re-dispatches (its own
                # deadline still applies there); one without resolves with
                # its OWN typed response, never the leader's (own rid/latency)
                if (
                    p.deadline_t is None or time.perf_counter() <= p.deadline_t
                ) and _regen_depth < 2:
                    regen.append(i)
                    continue
                with self._lock:
                    self.stats.expired += 1
                outcomes[i] = CacheResponse(
                    None, DEADLINE_EXCEEDED, False, None, None, p.chosen, 0.0,
                    time.perf_counter() - p.t_submit, p.rid,
                )
                continue
            out = CacheResponse(
                resp.text, GENERATED, False, None, resp, resp.model, 0.0,
                done - p.t_submit, p.rid,
            )
            with client._state_lock:
                client.stats.total_latency_s += out.latency_s
                client._results[p.rid] = client._to_client_result(out)
            outcomes[i] = out
        if regen:
            redo = self._dispatch_phase(
                [pendings[i] for i in regen], dedup=dedup,
                _regen_depth=_regen_depth + 1,
            )
            for i, out in zip(regen, redo):
                outcomes[i] = out
        return outcomes  # type: ignore[return-value]

    def _serve_stale(self, pendings: List[_Pending]) -> Dict[int, CacheResponse]:
        """Stale-if-error: after ``AllBackendsFailed``, rows that opted in
        (``allow_stale`` + ``use_cache``) consult the expired inventory
        (tier-0 entry table + tier-1 ring, via the hierarchy walk when one
        is mounted). Returns local index -> STALE CacheResponse for the rows
        a stale entry answered; the rest keep the typed error."""
        client = self.client
        target = client.hierarchy if client.hierarchy is not None else client.cache
        if target is None:
            return {}
        elig = [
            j
            for j, p in enumerate(pendings)
            if p.request.allow_stale and p.request.use_cache and p.vec is not None
        ]
        if not elig:
            return {}
        queries = [pendings[j].request.prompt for j in elig]
        vecs = np.stack([np.asarray(pendings[j].vec, np.float32) for j in elig])
        contexts = [
            client._context_for(pendings[j].request, pendings[j].chosen) for j in elig
        ]
        stales = [pendings[j].request.max_stale_s for j in elig]
        with self._cache_lock:
            if client.hierarchy is not None:
                found = client.hierarchy.lookup_stale(
                    queries, vecs, contexts, max_stale_s=stales,
                    l2_ok=[pendings[j].request.cache_l2 for j in elig],
                )
            else:
                thr = [
                    client.cache.effective_threshold(q, c)
                    for q, c in zip(queries, contexts)
                ]
                found = client.cache.lookup_stale(
                    queries, vecs, thr, max_stale_s=stales
                )
        out: Dict[int, CacheResponse] = {}
        now = time.perf_counter()
        for k, res in found.items():
            j = elig[k]
            p = pendings[j]
            resp = CacheResponse(
                res.response, STALE, True, res, None, "cache", 0.0,
                now - p.t_submit, p.rid,
            )
            with self._lock:
                self.stats.stale_served += 1
            with client._state_lock:
                client._results[p.rid] = client._to_client_result(resp)
            out[j] = resp
        return out

    def _backfill(
        self, pendings: List[_Pending], resps: List[LLMResponse]
    ) -> None:
        """Insert generated answers: per-request privacy hints group into at
        most one ``insert_batch`` scatter per (cache_l1, cache_l2) class."""
        client = self.client
        eligible = [
            (p, r)
            for p, r in zip(pendings, resps)
            if p.request.use_cache and p.vec is not None
        ]
        if not eligible:
            return
        groups: Dict[tuple, List[tuple]] = {}
        for p, r in eligible:
            groups.setdefault((p.request.cache_l1, p.request.cache_l2), []).append((p, r))
        from repro.core.client import accepts_kwarg

        with self._cache_lock:
            for (l1_ok, l2_ok), members in groups.items():
                prompts = [p.request.prompt for p, _ in members]
                texts = [r.text for _, r in members]
                vecs = np.stack([p.vec for p, _ in members])
                ttls = [p.request.ttl_s for p, _ in members]
                target = client.hierarchy if client.hierarchy is not None else client.cache
                kw = {}
                if any(t is not None for t in ttls) and accepts_kwarg(
                    type(target), "insert_batch", "ttls"
                ):
                    kw["ttls"] = ttls
                if client.hierarchy is not None:
                    if l1_ok or l2_ok:
                        client.hierarchy.insert_batch(
                            prompts, texts, cache_l1=l1_ok, cache_l2=l2_ok,
                            vecs=vecs, **kw,
                        )
                elif l1_ok:
                    client.cache.insert_batch(
                        prompts,
                        texts,
                        metas=[{"model": r.model} for _, r in members],
                        vecs=vecs,
                        **kw,
                    )
