"""Priority-aware micro-batching scheduler (serving front door for the cache).

Concurrent callers submit items; a collector thread drains the bounded
priority heap into batches of up to ``max_batch``, waiting at most
``max_wait_ms`` after the first arrival so a lone request is never stalled
behind an empty batch. Each batch is handed to one ``handler`` call, which
amortizes the embed forward, the device search dispatch, and the backend
fan-out across every rider — the SCALM/MeanCache observation that
semantic-cache wins only materialize when lookup overhead is shared across
concurrent users.

This is also the ``CacheService`` scheduler, so batches are not FIFO:

  * items drain highest ``priority`` first (earliest deadline, then arrival
    order, break ties within a priority class);
  * items carrying a deadline that expired while queued are never handed to
    the handler — ``on_expired`` resolves their future (default: a typed
    ``DeadlineExceeded`` error);
  * admission is bounded: past ``max_queue`` pending items ``submit`` raises
    ``AdmissionRejected`` (a ``queue.Full`` subclass — typed fast-fail, not a
    surprise from a hidden queue);
  * ``submit`` after ``close`` raises ``ServiceClosed`` (a ``RuntimeError``
    subclass) instead of an opaque dead-worker error, and ``close`` drains
    the heap first so every accepted future resolves.

Futures-based: ``submit`` returns a ``concurrent.futures.Future`` resolved
with that item's element of the handler's returned list (or its exception).
With ``owns_futures=True`` the handler is called as ``handler(items,
futures)`` and resolves them itself — the ``CacheService`` mode, where hit
futures resolve mid-handler while misses are forwarded to another scheduler.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence, Tuple


class ServiceClosed(RuntimeError):
    """``submit`` after ``close``: the scheduler no longer accepts work."""


class AdmissionRejected(queue.Full):
    """Typed load-shed: the queue bound / in-flight budget is exhausted."""


class DeadlineExceeded(TimeoutError):
    """The item's deadline passed while it waited in queue."""


@dataclass
class CoalescerStats:
    submitted: int = 0
    batches: int = 0
    batched_items: int = 0
    rejected: int = 0  # admission rejections (bounded queue)
    expired: int = 0  # deadline expiries resolved without a handler call
    batch_sizes: List[int] = field(default_factory=list)

    @property
    def avg_batch(self) -> float:
        return self.batched_items / self.batches if self.batches else 0.0


class BatchCoalescer:
    """Bounded priority-heap micro-batcher in front of a batch handler.

    Knobs:
      max_batch    — largest batch handed to the handler in one call
      max_wait_ms  — how long the collector holds an open batch for riders
      max_queue    — admission bound (0 = unbounded); ``submit`` raises
                     ``AdmissionRejected`` beyond it
      owns_futures — handler is called as ``handler(items, futures)`` and
                     resolves the futures itself (the CacheService mode)
      on_expired   — ``fn(item, future)`` for deadline-expired items; the
                     default resolves the future with ``DeadlineExceeded``
    """

    def __init__(
        self,
        handler: Callable[..., Optional[Sequence[Any]]],
        *,
        max_batch: int = 8,
        max_wait_ms: float = 2.0,
        max_queue: int = 1024,
        owns_futures: bool = False,
        on_expired: Optional[Callable[[Any, Future], None]] = None,
    ):
        assert max_batch >= 1
        self.handler = handler
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.max_queue = max_queue
        self.owns_futures = owns_futures
        self.on_expired = on_expired
        self.stats = CoalescerStats()
        # entries: (-priority, deadline_key, seq, item, future) — seq is unique,
        # so comparisons never reach the (unorderable) item
        self._heap: List[tuple] = []  # guarded-by: _cv
        self._seq = 0  # guarded-by: _cv
        self._cv = threading.Condition()
        self._closed = False  # guarded-by: _cv
        self._thread = threading.Thread(target=self._collect, daemon=True)
        self._thread.start()

    # -- client side -----------------------------------------------------------

    def submit(
        self,
        item: Any,
        *,
        priority: int = 0,
        deadline_s: Optional[float] = None,
        deadline_t: Optional[float] = None,
        future: Optional[Future] = None,
    ) -> "Future":
        """Enqueue one item; returns the future its result will resolve.

        ``deadline_s`` is relative to now, ``deadline_t`` an absolute
        ``time.perf_counter()`` stamp (the CacheService forwards a miss with
        the deadline its original submit established). ``future`` lets a
        caller thread an existing future through a second scheduler hop.
        """
        if deadline_t is None and deadline_s is not None:
            deadline_t = time.perf_counter() + deadline_s
        dl_key = deadline_t if deadline_t is not None else float("inf")
        with self._cv:
            if self._closed:
                raise ServiceClosed("coalescer is closed")
            if self.max_queue and len(self._heap) >= self.max_queue:
                self.stats.rejected += 1
                raise AdmissionRejected(f"coalescer queue full ({self.max_queue})")
            fut = future if future is not None else Future()
            heapq.heappush(self._heap, (-priority, dl_key, self._seq, item, fut))
            self._seq += 1
            self.stats.submitted += 1
            self._cv.notify()
            return fut

    def __call__(self, item: Any, **kwargs) -> Any:
        """Blocking convenience wrapper: submit and wait for the answer."""
        return self.submit(item, **kwargs).result()

    # -- collector -------------------------------------------------------------

    def _pop_batch(self) -> Tuple[List[tuple], List[tuple]]:
        """Block for the first item, then ride out max_wait_ms / max_batch.

        Returns (batch, expired): expired covers the WHOLE heap, not just the
        popped entries — a low-priority item starved by a sustained
        high-priority stream must still resolve typed at its deadline, not
        stall its caller until the queue drains."""
        with self._cv:
            while not self._heap:
                if self._closed:
                    return [], []
                self._cv.wait(timeout=0.05)
            deadline = time.perf_counter() + self.max_wait_s
            while len(self._heap) < self.max_batch and not self._closed:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                self._cv.wait(timeout=remaining)
            now = time.perf_counter()
            expired = [e for e in self._heap if e[1] <= now]
            if expired:
                self._heap = [e for e in self._heap if e[1] > now]
                heapq.heapify(self._heap)
            batch = [
                heapq.heappop(self._heap)
                for _ in range(min(self.max_batch, len(self._heap)))
            ]
            return batch, expired

    def _collect(self) -> None:
        while True:
            batch, expired = self._pop_batch()
            for _, dl_key, _, item, fut in expired:
                self.stats.expired += 1
                if self.on_expired is not None:
                    self.on_expired(item, fut)
                elif not fut.done():
                    fut.set_exception(
                        DeadlineExceeded(
                            f"deadline passed {time.perf_counter() - dl_key:.3f}s ago"
                        )
                    )
            if not batch:
                with self._cv:
                    if self._closed and not self._heap:
                        return
                continue
            items = [it for _, _, _, it, _ in batch]
            futs = [f for _, _, _, _, f in batch]
            self.stats.batches += 1
            self.stats.batched_items += len(batch)
            self.stats.batch_sizes.append(len(batch))
            try:
                if self.owns_futures:
                    self.handler(items, futs)
                else:
                    outs = self.handler(items)
                    if len(outs) != len(items):
                        raise RuntimeError(
                            f"handler returned {len(outs)} results for {len(items)} items"
                        )
                    for f, out in zip(futs, outs):
                        f.set_result(out)
            except Exception as e:  # noqa: BLE001 — propagate to every unresolved rider
                for f in futs:
                    if not f.done():
                        f.set_exception(e)

    # -- lifecycle ------------------------------------------------------------

    def close(self, timeout: Optional[float] = 10.0) -> None:
        """Stop admissions, drain the heap, and join the collector: every
        future accepted before close resolves (result, error, or expiry)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "BatchCoalescer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
