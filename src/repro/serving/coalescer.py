"""Micro-batching request coalescer (serving front door for the cache).

Concurrent callers submit single prompts; a collector thread drains the
bounded queue into batches of up to ``max_batch`` requests, waiting at most
``max_wait_ms`` after the first arrival so a lone request is never stalled
behind an empty batch. Each batch is handed to one ``handler`` call (e.g.
``EnhancedClient.complete_batch``), which amortizes the embed forward, the
device search dispatch, and the backend fan-out across every rider — the
SCALM/MeanCache observation that semantic-cache wins only materialize when
lookup overhead is shared across concurrent users.

Futures-based: ``submit`` returns a ``concurrent.futures.Future`` resolved
with that prompt's element of the handler's returned list (or its exception).
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence


@dataclass
class CoalescerStats:
    submitted: int = 0
    batches: int = 0
    batched_items: int = 0
    rejected: int = 0  # queue-full rejections (bounded admission)
    batch_sizes: List[int] = field(default_factory=list)

    @property
    def avg_batch(self) -> float:
        return self.batched_items / self.batches if self.batches else 0.0


class BatchCoalescer:
    """Bounded-queue micro-batcher in front of a batch handler.

    Knobs:
      max_batch    — largest batch handed to the handler in one call
      max_wait_ms  — how long the collector holds an open batch for riders
      max_queue    — admission bound; ``submit`` raises queue.Full beyond it
    """

    def __init__(
        self,
        handler: Callable[[List[Any]], Sequence[Any]],
        *,
        max_batch: int = 8,
        max_wait_ms: float = 2.0,
        max_queue: int = 1024,
    ):
        assert max_batch >= 1
        self.handler = handler
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.stats = CoalescerStats()
        self._q: "queue.Queue" = queue.Queue(maxsize=max_queue)
        self._closed = False
        # serializes submit() against close(): a submit that passed the
        # closed-check has enqueued before close() flips the flag, so the
        # collector's (closed and empty) exit condition can't strand it
        self._lifecycle = threading.Lock()
        self._thread = threading.Thread(target=self._collect, daemon=True)
        self._thread.start()

    # -- client side -----------------------------------------------------------

    def submit(self, item: Any) -> "Future":
        with self._lifecycle:
            if self._closed:
                raise RuntimeError("coalescer is closed")
            fut: Future = Future()
            try:
                self._q.put_nowait((item, fut))  # raises queue.Full when over max_queue
            except queue.Full:
                self.stats.rejected += 1
                raise
            self.stats.submitted += 1
            return fut

    def __call__(self, item: Any) -> Any:
        """Blocking convenience wrapper: submit and wait for the answer."""
        return self.submit(item).result()

    # -- collector -------------------------------------------------------------

    def _drain_batch(self) -> List[tuple]:
        """Block for the first request, then ride out max_wait_ms / max_batch."""
        try:
            first = self._q.get(timeout=0.05)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self._q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _collect(self) -> None:
        while not (self._closed and self._q.empty()):
            batch = self._drain_batch()
            if not batch:
                continue
            items = [it for it, _ in batch]
            futs = [f for _, f in batch]
            self.stats.batches += 1
            self.stats.batched_items += len(batch)
            self.stats.batch_sizes.append(len(batch))
            try:
                outs = self.handler(items)
                if len(outs) != len(items):
                    raise RuntimeError(
                        f"handler returned {len(outs)} results for {len(items)} items"
                    )
            except Exception as e:  # noqa: BLE001 — propagate to every rider
                for f in futs:
                    f.set_exception(e)
                continue
            for f, out in zip(futs, outs):
                f.set_result(out)

    # -- lifecycle ------------------------------------------------------------

    def close(self, timeout: Optional[float] = 10.0) -> None:
        with self._lifecycle:
            self._closed = True
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "BatchCoalescer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
