"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits: jax.Array, key, *, temperature: float = 0.0, top_k: int = 0):
    """logits [..., V] -> token ids [...]. temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
