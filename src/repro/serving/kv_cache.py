"""KV-cache slot manager for continuous batching.

The engine runs a fixed-size decode batch of `max_batch` slots; the manager
tracks which slots are live, their sequence lengths, and hands out slots to
newly admitted requests. (The cache pytree itself is the model-defined
stacked cache from models.transformer.init_cache; paged/block allocation is
a recorded §Perf follow-up — slots here are contiguous per sequence.)
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


class SlotManager:
    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.free: List[int] = list(range(max_batch))
        self.lengths = np.zeros((max_batch,), np.int32)
        self.live = np.zeros((max_batch,), bool)

    def alloc(self) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop(0)
        self.live[slot] = True
        self.lengths[slot] = 0
        return slot

    def release(self, slot: int) -> None:
        if self.live[slot]:
            self.live[slot] = False
            self.lengths[slot] = 0
            self.free.append(slot)

    @property
    def num_live(self) -> int:
        return int(self.live.sum())
