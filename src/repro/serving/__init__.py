from repro.serving.coalescer import BatchCoalescer, CoalescerStats  # noqa: F401
from repro.serving.engine import ServingEngine, ModelBackend  # noqa: F401
from repro.serving.sampler import sample_tokens  # noqa: F401
