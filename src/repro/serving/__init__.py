from repro.serving.coalescer import (  # noqa: F401
    AdmissionRejected,
    BatchCoalescer,
    CoalescerStats,
    DeadlineExceeded,
    ServiceClosed,
)
from repro.serving.engine import ServingEngine, ModelBackend  # noqa: F401
from repro.serving.sampler import sample_tokens  # noqa: F401
from repro.serving.service import CacheService, ServiceStats  # noqa: F401
