"""Async-first serving: futures, priorities, deadlines, asyncio.

Demonstrates the request-level API the paper's latency story needs: a
mixed stream where cache hits resolve in milliseconds while misses wait on
a slow backend — without the hits being dragged to miss latency — plus a
deadline that sheds a miss before it ever reaches the backend, and the
asyncio facade.

Run:  PYTHONPATH=src python examples/async_service.py
"""
import asyncio
import time

from repro.core import (
    CacheRequest,
    EnhancedClient,
    GenerativeCache,
    MockLLM,
    NgramHashEmbedder,
)
from repro.serving.service import CacheService


def build_client() -> EnhancedClient:
    cache = GenerativeCache(
        NgramHashEmbedder(), threshold=0.85, t_single=0.45, t_combined=1.0
    )
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("slow-llm", latency_s=0.4))
    cache.insert("what is semantic caching", "serving answers by meaning, not bytes")
    cache.insert("how do caches evict entries", "lru, lfu, or fifo over the slot array")
    return client


def futures_demo(client: EnhancedClient) -> None:
    print("== futures: hits resolve before the co-batched miss generates ==")
    with CacheService(client, max_batch=8, max_wait_ms=5.0) as service:
        t0 = time.perf_counter()
        miss = service.submit(CacheRequest("a brand new question", priority=0))
        hit = service.submit(CacheRequest("what is semantic caching", priority=5))
        r = hit.result()
        print(f"  hit   [{(time.perf_counter()-t0)*1e3:6.1f} ms] {r.text!r}")
        r = miss.result()
        print(f"  miss  [{(time.perf_counter()-t0)*1e3:6.1f} ms] {r.text!r}")

        # a deadline shorter than the backend's latency sheds the miss
        doomed = service.submit(CacheRequest("another fresh question", deadline_s=0.05))
        print(f"  expired -> status={doomed.result().status}")
        print(f"  service stats: {service.stats}")


async def asyncio_demo(client: EnhancedClient) -> None:
    print("== asyncio facade ==")
    with CacheService(client, max_wait_ms=5.0) as service:
        t0 = time.perf_counter()
        hit, miss = await asyncio.gather(
            service.acomplete("how do caches evict entries"),
            service.acomplete("an unseen question about schedulers"),
        )
        print(f"  gather done in {(time.perf_counter()-t0)*1e3:.1f} ms "
              f"(hit status={hit.status}, miss status={miss.status})")


def main():
    client = build_client()
    futures_demo(client)
    asyncio.run(asyncio_demo(client))


if __name__ == "__main__":
    main()
