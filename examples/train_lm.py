"""Train a small LM on the synthetic bigram stream and watch the loss fall
below the uniform baseline — exercising the full training substrate
(AdamW, grad accumulation, remat, checkpointing, preemption-safe restart).

Run:  PYTHONPATH=src python examples/train_lm.py            # ~10M params, fast on CPU
      PYTHONPATH=src python examples/train_lm.py --scale 100m --steps 300
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.data.loader import ShardedLoader
from repro.training.train_loop import init_train_state, make_train_step


def build_cfg(scale: str):
    base = get_config("qwen1.5-0.5b", smoke=True)
    if scale == "10m":
        return dataclasses.replace(
            base, num_layers=4, d_model=256, num_heads=8, num_kv_heads=8,
            head_dim=32, d_ff=1024, vocab_size=8192, attn_chunk=256,
        )
    if scale == "100m":
        return dataclasses.replace(
            base, num_layers=10, d_model=640, num_heads=10, num_kv_heads=10,
            head_dim=64, d_ff=2560, vocab_size=16384, attn_chunk=256,
        )
    raise ValueError(scale)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="10m", choices=["10m", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = build_cfg(args.scale)
    n_params = cfg.total_params()
    print(f"config: {cfg.num_layers}L d={cfg.d_model} ~{n_params/1e6:.1f}M params")

    state, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    loader = ShardedLoader(cfg.vocab_size, args.batch, args.seq, seed=0)
    step_fn = jax.jit(
        make_train_step(cfg, peak_lr=1e-3, warmup_steps=10, total_steps=args.steps),
        donate_argnums=(0,),
    )

    uniform = float(np.log(cfg.vocab_size))
    first = None
    for step in range(args.steps):
        state, metrics = step_fn(state, next(loader))
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  (uniform {uniform:.3f})")
    print(f"\nloss {first:.3f} -> {loss:.3f}; learnable structure captured: "
          f"{'YES' if loss < uniform - 0.5 else 'partial'}")


if __name__ == "__main__":
    main()
