"""HTTP gateway quickstart: the OpenAI-compatible serving surface.

Starts a gateway over a MockLLM-backed ``CacheService`` on a local port,
then talks to it like any OpenAI SDK would: a cold question generates
(``X-Cache: miss``), the repeat answers from the cache in milliseconds
(``X-Cache: hit``), and a streamed repeat replays the cached answer
token-by-token over SSE — byte-identical to the non-streamed body.

Run:  PYTHONPATH=src python examples/http_gateway.py

Against a real model instead of the mock:
      PYTHONPATH=src python -m repro.launch.serve --http 8080
"""
from repro.core import EnhancedClient, GenerativeCache, MockLLM, NgramHashEmbedder
from repro.gateway import GatewayClient, serve_in_thread
from repro.serving.service import CacheService

QUESTION = "What is an application-level denial of service attack?"


def main():
    cache = GenerativeCache(
        NgramHashEmbedder(), threshold=0.8, t_single=0.45, t_combined=1.0
    )
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("mock-model", latency_s=0.2))
    service = CacheService(client, max_batch=8, max_wait_ms=2.0)

    # pace_ms paces the cached replay so a streamed hit still *reads* like
    # a live generation; own_service ties the service drain to gateway stop
    runner = serve_in_thread(service, pace_ms=5.0, own_service=True)
    try:
        port = runner.gateway.port
        print(f"gateway on http://127.0.0.1:{port}\n")
        with GatewayClient("127.0.0.1", port) as http:
            # first hit pays the one-off jit compile of the hit-path search;
            # the second shows the steady-state cached latency
            for label in ("cold ", "warm1", "warm2"):
                reply = http.chat(QUESTION)
                print(f"{label} X-Cache={reply.headers['x-cache']:<5} "
                      f"latency={reply.headers['x-service-latency-ms']}ms  "
                      f"-> {reply.text[:48]}...")

            streamed = http.chat(QUESTION, stream=True)
            print(f"sse   X-Cache={streamed.headers['x-cache']:<5} "
                  f"chunks={len(streamed.events)} done={streamed.done}")
            assert streamed.text == http.chat(QUESTION).text  # byte parity

            stats = http.cache_stats().json()
            print(f"\nstats: {stats['gateway']['by_cache_class']} "
                  f"hit_fraction={stats['gateway']['hit_fraction']:.2f}")
    finally:
        clean = runner.stop()
        print(f"drained {'clean' if clean else 'DIRTY'}")


if __name__ == "__main__":
    main()
