"""Adaptive thresholds in action (§2, §3.1): content type, model cost,
connectivity, and the two feedback servos.

Run:  PYTHONPATH=src python examples/adaptive_tuning.py
"""
import random

from repro.core.adaptive import (
    DEFAULT_PRICE_TABLE,
    CostController,
    QualityRateController,
    ThresholdPolicy,
)


def main():
    p = ThresholdPolicy(base=0.8)
    print("== effective t_s varies per query/runtime context (§2)")
    rows = [
        ("text query", "Tell me about the french revolution", {}),
        ("code query", "Write a python function to reverse a list", {}),
        ("expensive model", "Tell me about X", {"model_info": DEFAULT_PRICE_TABLE["gpt-4-32k"]}),
        ("cheap model", "Tell me about X", {"model_info": DEFAULT_PRICE_TABLE["gpt-3.5-turbo-0125"]}),
        ("offline", "Tell me about X", {"connectivity": 0.0}),
        ("big response budget", "Tell me about X",
         {"model_info": DEFAULT_PRICE_TABLE["gpt-4-32k"], "max_tokens": 4096}),
    ]
    for name, q, ctx in rows:
        print(f"   {name:20s} t_s = {p.compute(q, ctx):.3f}")

    print("\n== quality-rate servo: drive quality toward t4 = 0.8 (§3.1)")
    rnd = random.Random(0)
    p2 = ThresholdPolicy(base=0.55)
    ctl = QualityRateController(p2, target=0.8, band=0.03, step=0.01, window=40)
    for i in range(400):
        p_high = min(1.0, max(0.0, (p2.base - 0.4) / 0.45))
        ctl.record(rnd.random() < p_high)
        if i % 100 == 0:
            print(f"   step {i:3d}: t_s={p2.base:.3f} quality_rate={ctl.quality_rate:.2f}")
    print(f"   settled: t_s={p2.base:.3f}, quality_rate={ctl.quality_rate:.2f}")

    print("\n== cost servo: steer hit rate toward (c2-c1)/c2")
    p3 = ThresholdPolicy(base=0.95)
    cctl = CostController(p3, target_cost_per_request=0.25, step=0.01)
    rnd = random.Random(1)
    for _ in range(600):
        p_hit = min(1.0, max(0.0, (0.98 - p3.base) / 0.35))
        hit = rnd.random() < p_hit
        cctl.record(0.0 if hit else 1.0, hit)
    print(f"   target hit rate={cctl.target_hit_rate:.2f} "
          f"measured={cctl.measured_hit_rate:.2f} final t_s={p3.base:.3f}")


if __name__ == "__main__":
    main()
