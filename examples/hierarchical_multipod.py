"""Hierarchical caching (§4 Figure 1): client L1s over shared cooperating
L2s, with privacy hints — plus the mesh-sharded store that realizes the same
topology on a TPU pod (pod-local shard = L1, cross-pod merge = L2).

Run:  PYTHONPATH=src python examples/hierarchical_multipod.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core import GenerativeCache, HierarchicalCache, NgramHashEmbedder


def host_side_hierarchy():
    emb = NgramHashEmbedder()

    def gc(cap):
        return GenerativeCache(emb, threshold=0.85, t_single=0.45, t_combined=1.0, capacity=cap)

    l1a, l1b = gc(64), gc(64)  # two clients
    l2 = gc(512)  # shared L2
    peer = gc(512)  # a cooperating peer L2
    h_a = HierarchicalCache(l1a, l2, peers=[peer])
    h_b = HierarchicalCache(l1b, l2, peers=[peer])

    print("== client A asks; the answer lands in A's L1 and the shared L2")
    h_a.insert("What is tcp congestion control?", "TCP answer")
    print(f"   L1a={len(l1a.store)} L2={len(l2.store)}")

    print("== client B gets an L2 hit, promoted into B's L1")
    r = h_b.lookup("Please explain tcp congestion control.")
    print(f"   hit={r.hit} level={r.level}; L1b now has {len(l1b.store)} entries")

    print("== peer cooperation: content only a peer L2 holds is still served")
    peer.insert("What is raft consensus?", "raft answer")
    r = h_a.lookup("Explain the raft consensus protocol")
    print(f"   hit={r.hit} level={r.level}")

    print("== privacy hint: personal queries stay out of shared levels (§4)")
    h_a.insert("What are my lab results for patient 1234?", "personal", cache_l2=False)
    r = h_b.lookup("What are my lab results for patient 1234?")
    print(f"   other client hit={r.hit} (expected False); L1a={len(l1a.store)}")

    print("== generative synthesis ACROSS levels")
    l1a.insert("What is quantum entanglement?", "entanglement answer")
    l2.insert("What is the history of quantum entanglement?", "history answer")
    r = h_a.lookup("What is quantum entanglement, and what is the history of quantum entanglement?")
    print(f"   hit={r.hit} level={r.level} generative={r.generative}")


def batched_hierarchy():
    emb = NgramHashEmbedder()

    def gc(cap):
        # looser thresholds than the walkthrough above so the n-gram
        # embedder's paraphrase scores (~0.6-0.7) register as hits
        return GenerativeCache(emb, threshold=0.55, t_single=0.4, t_combined=1.0, capacity=cap)

    l1, l2, peer = gc(64), gc(512), gc(512)
    h = HierarchicalCache(l1, l2, peers=[peer])
    l2.insert("What is tcp congestion control?", "TCP answer")
    peer.insert("What is raft consensus?", "raft answer")

    print("\n== batched hierarchy: one search dispatch per level for the batch")
    rs = h.lookup_batch([
        "Please explain tcp congestion control.",
        "Explain the raft consensus protocol",
        "What is the airspeed velocity of an unladen swallow?",
    ])
    for r in rs:
        print(f"   hit={r.hit} level={r.level}")
    print(f"   lower-level winners promoted: L1 now has {len(l1.store)} entries")


def mesh_sharded_store():
    from repro.distributed.sharded_store import ShardedVectorStore
    from repro.launch.mesh import make_test_mesh

    print("\n== mesh-sharded store: pod-local shards + cross-pod top-k merge")
    mesh = make_test_mesh(shape=(2, 4), axes=("pod", "data"))
    emb = NgramHashEmbedder(dim=64)
    store = ShardedVectorStore(mesh, dim=64, capacity=256, k=4)
    questions = [f"What is topic number {i}?" for i in range(24)]
    vecs = emb.embed(questions)
    store.add_batch(vecs, questions, [f"answer to {q}" for q in questions])
    probe = emb.embed(["Please explain topic number 7"])
    scores, idx = store.search(probe)
    q, a = store.payloads[int(idx[0, 0])]
    print(f"   best match: {q!r} (score {scores[0,0]:.3f}) across {store.n_shards} shards")


if __name__ == "__main__":
    host_side_hierarchy()
    batched_hierarchy()
    mesh_sharded_store()
