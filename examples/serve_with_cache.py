"""End-to-end serving driver (assignment deliverable b): batched requests
through the full stack — GenerativeCache front, continuous-batching engine
over a real JAX model behind.

Run:  PYTHONPATH=src python examples/serve_with_cache.py [--arch qwen1.5-0.5b]
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
