"""Quickstart: the paper's §3 worked example end-to-end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    EnhancedClient,
    GenerativeCache,
    MockLLM,
    ModelCostInfo,
    NgramHashEmbedder,
)

Q1 = "What is an application-level denial of service attack?"
Q2 = "What are the most effective techniques for defending against denial-of-service attacks?"
Q3 = ("What is an application-level denial of service attack, and what are the most "
      "effective techniques for defending against such attacks?")


def main():
    # A generative cache: t_single < t_s < t_combined  (§3)
    cache = GenerativeCache(
        NgramHashEmbedder(),
        threshold=0.88, t_single=0.45, t_combined=1.0,
        mode="secondary", synthesis_mode="template",
    )
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("gpt-3.5-turbo-0125", latency_s=0.15),
                            ModelCostInfo(0.5, 1.5, 3.0))
    client.register_backend(MockLLM("gpt-4-32k", latency_s=0.6),
                            ModelCostInfo(60.0, 120.0, 20.0))

    print("== 1. populate the cache with two LLM answers")
    for q in (Q1, Q2):
        r = client.query(q)
        print(f"   [{'cache' if r.from_cache else r.model:>18}] {q[:60]}")

    print("\n== 2. Q3 was never asked — generative caching synthesizes it")
    r3 = client.query(Q3)
    assert r3.from_cache and r3.cache_result.generative
    print(f"   hit={r3.from_cache} generative={r3.cache_result.generative} "
          f"combined_similarity={r3.cache_result.combined_similarity:.2f} "
          f"sources={len(r3.cache_result.sources)}")
    print("   " + r3.text.splitlines()[0])

    print("\n== 3. paraphrases now hit the cache directly")
    r = client.query("Please explain what an application-level denial of service attack is.")
    print(f"   hit={r.from_cache} sim={r.cache_result.similarity:.3f} "
          f"latency={r.latency_s*1e3:.1f}ms (vs ~150ms LLM)")

    print("\n== 4. feedback servos the threshold (§3.1)")
    before = client.policy.base
    for _ in range(6):
        r = client.query(Q1)
        client.feedback(r, satisfied=False)  # unhappy with cached answers
    print(f"   t_s: {before:.3f} -> {client.policy.base:.3f} (raised on low quality)")

    print(f"\nstats: {client.stats}")


if __name__ == "__main__":
    main()
