"""HTTP gateway e2e over real sockets: OpenAI-shaped bodies, the X-Cache
header contract (all four values), streamed-vs-non-streamed byte parity,
typed error mapping (400/404/405/429/503/504), concurrent admission
control, and drain-resolves-everything on shutdown. Plus the astream
facade's parity with the sync path."""
import asyncio
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import pytest

from repro.core import (
    CacheRequest,
    EnhancedClient,
    GenerativeCache,
    MockLLM,
    NgramHashEmbedder,
)
from repro.core.request import split_stream_tokens
from repro.core.tiers import HostRamTier
from repro.core.vector_store import InMemoryVectorStore
from repro.gateway import GatewayClient, serve_in_thread
from repro.serving.service import CacheService

from tests.test_service import GatedLLM

Q_A = "how does the storage subsystem behave under heavy load"
Q_B = "how does the routing subsystem behave under heavy load"


def _service(backend=None, *, tier1: bool = False, threshold: float = 0.8,
             **svc_kw) -> CacheService:
    emb = NgramHashEmbedder()
    store = None
    if tier1:
        store = InMemoryVectorStore(
            emb.dim, capacity=2, eviction="lru",
            tier1=HostRamTier(emb.dim, capacity=16),
        )
    cache = GenerativeCache(
        emb, threshold=threshold, t_single=0.45, t_combined=1.0,
        store=store, cache_synthesized=False,
    )
    client = EnhancedClient(cache=cache)
    client.register_backend(backend or MockLLM("backend", latency_s=0.0))
    return CacheService(client, max_batch=8, max_wait_ms=1.0, **svc_kw)


@pytest.fixture()
def gw():
    """A live gateway over a fast MockLLM service; yields (runner, client)."""
    runner = serve_in_thread(_service(), own_service=True)
    with GatewayClient("127.0.0.1", runner.gateway.port) as http:
        yield runner, http
    runner.stop()


# -- the OpenAI surface --------------------------------------------------------


def test_chat_miss_then_hit_headers_and_body_shape(gw):
    _, http = gw
    cold = http.chat(Q_A)
    assert cold.status == 200
    body = cold.json()
    assert body["object"] == "chat.completion"
    assert body["choices"][0]["message"]["role"] == "assistant"
    assert cold.headers["x-cache"] == "miss"
    assert "x-request-id" in cold.headers
    assert float(cold.headers["x-service-latency-ms"]) >= 0

    warm = http.chat(Q_A)
    assert warm.headers["x-cache"] == "hit"
    assert float(warm.headers["x-cache-similarity"]) >= 0.99
    assert warm.headers["x-cache-level"] == "semantic"
    assert warm.text == cold.text


def test_completions_surface_and_echoed_model(gw):
    _, http = gw
    r = http.completion("a plain completion prompt", max_tokens=16)
    assert r.status == 200
    body = r.json()
    assert body["object"] == "text_completion"
    assert body["choices"][0]["text"] == r.text
    assert body["model"]  # resolved model echoes back


def test_streamed_hit_byte_identical_to_nonstreamed(gw):
    _, http = gw
    plain = http.chat(Q_A)  # prime the cache
    plain = http.chat(Q_A)
    assert plain.headers["x-cache"] == "hit"

    sse = http.chat(Q_A, stream=True)
    assert sse.status == 200
    assert sse.headers["content-type"].startswith("text/event-stream")
    assert sse.headers["x-cache"] == "hit"  # headers resolved before stream
    assert sse.done  # saw data: [DONE]
    assert sse.text == plain.text  # byte parity after SSE reassembly
    assert len(sse.events) >= len(split_stream_tokens(plain.text))
    # chat stream frame contract: role delta first, finish_reason last
    assert sse.events[0]["choices"][0]["delta"].get("role") == "assistant"
    assert sse.events[-1]["choices"][0]["finish_reason"] == "stop"
    assert all(e["object"] == "chat.completion.chunk" for e in sse.events)


def test_streamed_miss_byte_identical_to_repeat(gw):
    _, http = gw
    sse = http.completion("streamed cold prompt never seen", stream=True)
    assert sse.status == 200 and sse.headers["x-cache"] == "miss"
    plain = http.completion("streamed cold prompt never seen")
    assert plain.headers["x-cache"] == "hit"
    assert sse.text == plain.text


def test_all_four_x_cache_values_over_http():
    # threshold high enough that the combined prompt matches neither source
    # outright (each lands in the (t_single, t_s) band, summing past
    # t_combined -> the generative rule fires)
    runner = serve_in_thread(_service(tier1=True, threshold=0.93),
                             own_service=True)
    try:
        with GatewayClient("127.0.0.1", runner.gateway.port) as http:
            # miss, then hit
            assert http.completion(Q_A).headers["x-cache"] == "miss"
            assert http.completion(Q_A).headers["x-cache"] == "hit"
            # generative: both sources cached, combined prompt synthesizes
            assert http.completion(Q_B).headers["x-cache"] == "miss"
            combo = http.completion(f"{Q_A} and also {Q_B}")
            assert combo.headers["x-cache"] == "generative"
            # tier1: capacity-2 tier 0 demoted Q_A by now; its re-ask promotes
            tier1 = http.completion("some third filler prompt")  # churn
            assert tier1.headers["x-cache"] == "miss"
            promoted = http.completion(Q_A)
            assert promoted.headers["x-cache"] == "tier1"
            assert "tier1" in promoted.headers["x-cache-level"]
    finally:
        assert runner.stop()


# -- ops endpoints -------------------------------------------------------------


def test_healthz_and_cache_stats(gw):
    _, http = gw
    h = http.healthz()
    assert h.status == 200 and h.json()["status"] == "ok"

    http.chat(Q_A)
    http.chat(Q_A)
    stats = http.cache_stats().json()
    assert stats["gateway"]["by_cache_class"]["miss"] == 1
    assert stats["gateway"]["by_cache_class"]["hit"] == 1
    assert stats["service"]["submitted"] >= 2
    assert stats["gateway"]["hit_fraction"] == pytest.approx(0.5)


# -- typed error mapping -------------------------------------------------------


def test_malformed_json_is_400(gw):
    runner, http = gw
    conn = http._connection()
    conn.request("POST", "/v1/chat/completions", body=b"{nope",
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    body = json.loads(resp.read())
    assert resp.status == 400
    assert body["error"]["type"] == "invalid_request_error"
    http.close()  # server closes after a parse-level 400


def test_bad_fields_are_400(gw):
    _, http = gw
    assert http.request("POST", "/v1/chat/completions", {"messages": []}).status == 400
    assert http.request("POST", "/v1/completions", {}).status == 400  # no prompt
    r = http.request("POST", "/v1/completions",
                     {"prompt": "x", "max_tokens": "many"})
    assert r.status == 400 and "max_tokens" in r.json()["error"]["message"]
    r = http.request("POST", "/v1/chat/completions",
                     {"messages": [{"role": "user"}]})
    assert r.status == 400


def test_unknown_route_404_and_wrong_method_405(gw):
    _, http = gw
    assert http.request("GET", "/v2/everything").status == 404
    r = http.request("POST", "/healthz", {})
    assert r.status == 405 and r.headers["allow"] == "GET"
    assert http.request("GET", "/v1/chat/completions").status == 405


def test_deadline_exceeded_maps_to_504():
    runner = serve_in_thread(
        _service(MockLLM("slow", latency_s=0.5)), own_service=True
    )
    try:
        with GatewayClient("127.0.0.1", runner.gateway.port) as http:
            r = http.completion("too slow to make it", deadline_ms=30)
            assert r.status == 504
            assert r.json()["error"]["code"] == "deadline_exceeded"
            sse = http.completion("still too slow to make it", deadline_ms=30,
                                  stream=True)
            assert sse.status == 504  # typed error, not a broken stream
    finally:
        assert runner.stop()


def test_admission_rejected_maps_to_429_with_retry_after():
    backend = GatedLLM()
    runner = serve_in_thread(
        _service(backend, max_inflight=1), own_service=True
    )
    try:
        port = runner.gateway.port

        def one(i: int):
            with GatewayClient("127.0.0.1", port, timeout=30.0) as c:
                return c.completion(f"admission probe {i}")

        with ThreadPoolExecutor(max_workers=6) as pool:
            first = pool.submit(one, 0)
            assert backend.entered.wait(timeout=10)  # slot taken, gate shut
            rest = [pool.submit(one, i) for i in range(1, 6)]
            shed = [f.result() for f in rest]
            backend.gate.set()
            ok = first.result()
        assert ok.status == 200
        assert {r.status for r in shed} == {429}
        assert all(r.headers["retry-after"] == "1" for r in shed)
        assert all(r.json()["error"]["code"] == "admission_rejected"
                   for r in shed)
    finally:
        assert runner.stop()


def test_draining_gateway_returns_503_and_close_is_clean():
    runner = serve_in_thread(_service(), own_service=True)
    with GatewayClient("127.0.0.1", runner.gateway.port) as http:
        assert http.completion("before drain").status == 200
        assert runner.stop()
        with pytest.raises(Exception):  # listener closed: refused/reset
            http.completion("after drain")


def test_drain_resolves_every_inflight_request():
    backend = GatedLLM()
    runner = serve_in_thread(_service(backend), own_service=True)
    port = runner.gateway.port
    results = []

    def one(i: int):
        with GatewayClient("127.0.0.1", port, timeout=30.0) as c:
            results.append(c.completion(f"inflight during drain {i}"))

    threads = [threading.Thread(target=one, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    assert backend.entered.wait(timeout=10)  # requests are inside the service
    stopper = threading.Thread(target=lambda: results.append(runner.stop()))
    stopper.start()
    time.sleep(0.1)  # drain is now waiting on the gated backend
    backend.gate.set()
    for t in threads:
        t.join(timeout=30)
    stopper.join(timeout=30)
    statuses = sorted(r.status for r in results if hasattr(r, "status"))
    assert statuses == [200, 200, 200, 200]  # nobody dropped mid-drain
    assert True in [r for r in results if isinstance(r, bool)]  # clean drain


# -- astream facade ------------------------------------------------------------


def test_astream_reassembles_byte_identical_to_sync():
    service = _service()
    try:
        prompt = "a multi token answer  with doubled spaces\nand a newline"
        sync = service.submit(CacheRequest(prompt)).result()

        async def collect():
            chunks = []
            async for ch in service.astream(CacheRequest(prompt)):
                chunks.append(ch)
            return chunks

        chunks = asyncio.run(collect())
        assert "".join(c.text for c in chunks) == sync.text
        assert [c.index for c in chunks] == list(range(len(chunks)))
        assert [c.final for c in chunks] == [False] * (len(chunks) - 1) + [True]
        assert chunks[0].response.status == "hit"  # same resolved response
    finally:
        service.close()


def test_astream_chunk_tokens_groups_without_changing_bytes():
    service = _service()
    try:
        prompt = "another prompt with several words in the answer"
        sync = service.submit(CacheRequest(prompt)).result()

        async def collect(n):
            return [c async for c in service.astream(CacheRequest(prompt),
                                                     chunk_tokens=n)]

        one = asyncio.run(collect(1))
        grouped = asyncio.run(collect(3))
        assert len(grouped) < len(one)
        assert "".join(c.text for c in grouped) == sync.text
    finally:
        service.close()


def test_astream_shed_raises_before_first_chunk():
    backend = GatedLLM()
    service = _service(backend, max_inflight=1)
    try:
        blocker = service.submit(CacheRequest("occupy the only slot"))
        assert backend.entered.wait(timeout=10)

        async def go():
            agen = service.astream(CacheRequest("shed me"))
            await agen.__anext__()

        from repro.serving.coalescer import AdmissionRejected

        with pytest.raises(AdmissionRejected):
            asyncio.run(go())
        backend.gate.set()
        blocker.result(timeout=10)
    finally:
        service.close()
