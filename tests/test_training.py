"""Training substrate: optimizer math (incl. 8-bit moments), grad accum
invariance, schedules, gradient compression, checkpoint format."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, st

from repro.training.grad_compress import compress_with_error_feedback, init_error_state
from repro.training.optimizer import (
    AdamWConfig,
    _dequantize,
    _quantize,
    adamw_update,
    global_norm,
    init_opt_state,
)
from repro.training.schedule import warmup_cosine


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 3.0
    q = _quantize(x)
    err = jnp.abs(_dequantize(q) - x)
    per_row_scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    assert bool(jnp.all(err <= per_row_scale * 0.51 + 1e-6))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_quantize_preserves_sign_and_zero(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 32))
    x = x.at[0, 0].set(0.0)
    d = _dequantize(_quantize(x))
    assert float(d[0, 0]) == 0.0
    big = jnp.abs(x) > jnp.max(jnp.abs(x), -1, keepdims=True) * 0.05
    assert bool(jnp.all(jnp.where(big, jnp.sign(d) == jnp.sign(x), True)))


def _toy_params(key, stacked=False):
    shape = (8, 16, 32) if stacked else (16, 32)
    return {"w": jax.random.normal(key, shape) * 0.1}


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("stacked", [False, True])
def test_adamw_descends_quadratic(quantized, stacked):
    cfg = AdamWConfig(quantized=quantized, weight_decay=0.0)
    params = _toy_params(jax.random.PRNGKey(0), stacked)
    target = jax.tree.map(lambda p: jnp.ones_like(p), params)
    opt = init_opt_state(params, cfg)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    step = jnp.zeros((), jnp.int32)
    for i in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, step + i, jnp.asarray(0.05), cfg)
    assert float(loss(params)) < l0 * 0.2


def test_quantized_tracks_fp32_closely():
    key = jax.random.PRNGKey(1)
    params = _toy_params(key, stacked=True)
    target = jax.tree.map(jnp.ones_like, params)

    def loss(p):
        return jnp.sum((p["w"] - target["w"]) ** 2)

    outs = {}
    for quantized in (False, True):
        cfg = AdamWConfig(quantized=quantized, weight_decay=0.0)
        p = jax.tree.map(lambda x: x, params)
        opt = init_opt_state(p, cfg)
        for i in range(30):
            g = jax.grad(loss)(p)
            p, opt, _ = adamw_update(p, g, opt, jnp.asarray(i), jnp.asarray(0.05), cfg)
        outs[quantized] = float(loss(p))
    assert abs(outs[True] - outs[False]) < 0.15 * max(outs[False], 1e-3)


def test_grad_accum_matches_full_batch():
    import dataclasses

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.training.train_loop import _microbatch_grads

    cfg = get_config("qwen1.5-0.5b", smoke=True)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
    g1, l1, _ = _microbatch_grads(dataclasses.replace(cfg, grad_accum=1), params, batch, jnp.float32)
    g4, l4, _ = _microbatch_grads(dataclasses.replace(cfg, grad_accum=4), params, batch, jnp.float32)
    assert abs(float(l1) - float(l4)) < 0.05
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=0.02, rtol=0.05)


def test_schedule_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10, total_steps=100)) for s in range(100)]
    assert lrs[0] < 0.11 and abs(lrs[10] - 1.0) < 1e-6
    assert lrs[99] < 0.2 and all(b <= a + 1e-6 for a, b in zip(lrs[10:], lrs[11:]))


def test_grad_compression_error_feedback_unbiased():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 64))}
    err = init_error_state(g)
    total_sent = jnp.zeros_like(g["w"])
    for _ in range(20):
        sent, err = compress_with_error_feedback(g, err)
        total_sent = total_sent + sent["w"]
    # over many rounds, mean transported gradient -> true gradient
    np.testing.assert_allclose(np.asarray(total_sent / 20), np.asarray(g["w"]), atol=0.02)


def test_global_norm_matches_naive():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 16)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (32,))}
    naive = np.sqrt(sum(float(jnp.sum(jnp.square(l))) for l in jax.tree.leaves(tree)))
    assert abs(float(global_norm(tree)) - naive) < 1e-4
