"""Deadline propagation into the ServingEngine: a request whose deadline
passes mid-generation is canceled, frees its decode slot (capacity returns
to the continuous batch), and surfaces as a typed ``deadline_exceeded``
resolution through ModelBackend -> CacheService."""
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    DEADLINE_EXCEEDED,
    CacheRequest,
    EnhancedClient,
    GenerativeCache,
    MockLLM,
    NgramHashEmbedder,
)
from repro.serving.engine import ModelBackend, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    return ServingEngine(cfg, max_batch=1, max_seq=96)


def test_expired_slot_frees_engine_capacity(engine):
    """max_batch=1: request A expires mid-generation, B is pending behind
    it. Canceling A must free the only slot so B decodes to completion."""
    engine.generate([np.arange(4)], max_new_tokens=2)  # warm the jits
    now = time.perf_counter()
    reqs = engine.generate_ex(
        [np.arange(5), np.arange(5) + 7],
        max_new_tokens=60,
        deadlines=[now + 1e-4, None],  # A: expires ~immediately; B: none
    )
    a, b = reqs
    assert a.expired and a.done
    assert len(a.out_tokens) < 60  # canceled mid-generation, partial decode
    assert not b.expired
    assert len(b.out_tokens) == 60  # B got the freed slot and ran to the end
    assert engine.metrics.get("deadline_cancels", 0) >= 1
    assert engine.slots.free  # the slot came back after the batch drained


def test_expired_in_queue_never_claims_a_slot(engine):
    before = engine.metrics["prefill_tokens"]
    reqs = engine.generate_ex(
        [np.arange(6)], max_new_tokens=8,
        deadlines=[time.perf_counter() - 1.0],  # already past at submit
    )
    assert reqs[0].expired and reqs[0].out_tokens == []
    assert engine.metrics["prefill_tokens"] == before  # no prefill happened


def test_model_backend_marks_expired_responses(engine):
    backend = ModelBackend("m", engine)
    now = time.perf_counter()
    resps = backend.generate_batch(
        ["first prompt", "second prompt"], max_tokens=64,
        deadlines=[None, now - 1.0],
    )
    assert not resps[0].expired and resps[0].text
    assert resps[1].expired


def test_deadline_probe_not_inherited_by_overriding_subclass():
    """A subclass overriding generate_batch WITHOUT the deadlines kwarg
    must be probed on its own method, not inherit the parent's cached
    answer (which would feed it an unexpected kwarg and break failover)."""
    from repro.core.client import EnhancedClient, LLMResponse

    class Legacy(MockLLM):
        def generate_batch(self, prompts, max_tokens=256, temperature=0.0):
            return [LLMResponse(f"legacy:{p}", self.name) for p in prompts]

    modern, legacy = MockLLM("modern"), Legacy("legacy")
    assert EnhancedClient._accepts_deadlines(modern) is True
    assert EnhancedClient._accepts_deadlines(legacy) is False
    client = EnhancedClient(cache=GenerativeCache(NgramHashEmbedder()))
    client.register_backend(legacy)
    resps = client._generate_batch_with_failover(
        "legacy", ["p"], 16, 0.0, deadlines=[time.perf_counter() + 60]
    )
    assert resps[0].text == "legacy:p"  # called without the kwarg, no failover


def test_service_resolves_midgen_expiry_typed():
    """A deadline that survives the queue but dies mid-generation resolves
    with DEADLINE_EXCEEDED (no cache insert), via the deadline-aware
    backend path (MockLLM honors ``deadlines``)."""
    cache = GenerativeCache(NgramHashEmbedder(), threshold=0.85, t_single=0.45,
                            t_combined=1.0)
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("slow", latency_s=0.15))
    svc = client.service
    adds_before = cache.stats.adds
    fut = svc.submit(CacheRequest("a never cached prompt", deadline_s=0.05))
    resp = fut.result(timeout=10)
    assert resp.status == DEADLINE_EXCEEDED and resp.text is None
    assert cache.stats.adds == adds_before  # expired answers are not cached
    assert svc.stats.expired == 1 and svc.stats.generated == 0
    # a request with headroom still generates normally afterwards
    ok = svc.submit(CacheRequest("another prompt", deadline_s=30.0)).result(timeout=10)
    assert ok.status == "generated" and ok.text
    svc.close()
