"""Bad: retrace hazards (expect RA201 x4, RA202 x1)."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("k",))
def topk(x, *, k):
    return jax.lax.top_k(x, k)


def per_request(x, sizes):
    out = []
    for s in sizes:
        fn = jax.jit(lambda v: v * 2)  # RA201: jit built per iteration, uncached
        out.append(fn(x))
    y = jax.jit(lambda v: v + 1)(x)  # RA201: immediate invocation
    scores = topk(x, k=[1, 2])  # RA201: unhashable static arg
    n = topk(x, k=len(sizes))  # RA201: per-request size as static arg
    return out, y, scores, n


@jax.jit
def branchy(x):
    if x:  # RA202: Python branch on a traced value
        return x + 1
    return x - 1
