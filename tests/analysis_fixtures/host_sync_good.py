"""Good: host conversions stay on the host side of the jit boundary."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def program(x):
    return jnp.sum(x) * 2.0


def host_side(x):
    arr = np.asarray(program(x))  # host function: syncing here is the point
    return float(arr[0]), int(arr.size)
