"""Bad: guarded attribute touched without its lock (expect RA301 x1)."""
import threading


class Service:
    def __init__(self):
        self._lock = threading.Lock()
        self._inflight = 0  # guarded-by: _lock

    def submit(self):
        self._inflight += 1  # RA301: no lock held

    def release(self):
        with self._lock:
            self._inflight -= 1
