"""Bad: host syncs reachable from a jit region (expect RA101 x3)."""
import jax
import jax.numpy as jnp
import numpy as np


def helper(x):
    return float(x[0])  # RA101 via call-graph reachability


@jax.jit
def program(x):
    y = jnp.sum(x)
    z = y.item()  # RA101: blocking device->host sync
    w = np.asarray(y)  # RA101: materializes on host mid-trace
    return z + helper(x) + w
