"""Bad: donated buffer read after the donating call (expect RA401 x1)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter(buf, idx, rows):
    return buf.at[idx].set(rows)


class Bank:
    def __init__(self):
        self.buf = jnp.zeros((4, 2))

    def set_rows(self, idx, rows):
        out = scatter(self.buf, idx, rows)  # donates self.buf, never rebinds
        return out + self.buf.sum()  # RA401: self.buf is dead here
