"""Bad: unguarded int32 counter + f32 narrowing of absolute timestamps
(expect RA501 x1, RA502 x2)."""
import time

import numpy as np

_TICK_COMPACT_AT = 2**31 - 2**20


class Bank:
    def __init__(self):
        self._tick = 1

    def compact_ticks(self):
        self._tick = 1

    def next_tick(self):
        self._tick += 1  # RA501: no rebase guard in this function
        return self._tick

    def stamp(self):
        return np.float32(time.time())  # RA502: absolute epoch in f32

    def narrow(self, created_at):
        return created_at.astype(np.float32)  # RA502: *_at stamp narrowed
