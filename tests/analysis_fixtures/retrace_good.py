"""Good: cached jits, bucketed statics, branch-free traced math."""
import functools

import jax
import jax.numpy as jnp

_cache = {}


@functools.partial(jax.jit, static_argnames=("k",))
def topk(x, *, k):
    return jax.lax.top_k(x, k)


def bucket_len(n):
    b = 16
    while b < n:
        b *= 2
    return b


def lookup(x, sizes):
    for s in sizes:
        if s not in _cache:
            _cache[s] = jax.jit(lambda v, s=s: v * s)  # cached by subscript
    return topk(x, k=bucket_len(len(sizes)))  # bucketed static: O(log n) variants


@jax.jit
def no_branch(x):
    return jnp.where(x > 0, x, -x)
