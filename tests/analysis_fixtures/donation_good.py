"""Good: donated buffers rebound from the jit's results in one statement."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter(buf, idx, rows):
    return buf.at[idx].set(rows)


class Bank:
    def __init__(self):
        self.buf = jnp.zeros((4, 2))

    def set_rows(self, idx, rows):
        self.buf = scatter(self.buf, idx, rows)  # rebound at the call site
        return self.buf
