"""Good: guarded counter increments, f32 only for relative offsets."""
import numpy as np

_TICK_COMPACT_AT = 2**31 - 2**20


class Bank:
    def __init__(self):
        self._tick = 1

    def _compact_ticks(self):
        self._tick = 1

    def next_tick(self):
        if self._tick >= _TICK_COMPACT_AT:
            self._compact_ticks()
        self._tick += 1
        return self._tick

    def rel_stamp(self, created_rel):
        return np.float32(created_rel)  # relative seconds: f32 is plenty
