"""Good: every guarded access holds the lock (directly, via a condition
alias, or via a documented holds[] contract)."""
import threading


class Service:
    def __init__(self):
        self._lock = threading.Lock()
        self._capacity = threading.Condition(self._lock)
        self._inflight = 0  # guarded-by: _lock
        self._closed = False  # guarded-by: _lock

    def submit(self):
        with self._capacity:  # condition built over _lock counts as holding it
            self._inflight += 1

    def close(self):
        with self._lock:
            self._closed = True

    def _drain(self):  # repro: holds[_lock]
        return self._inflight
