"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED config of the same family and runs one forward /
train step on CPU, asserting output shapes and no NaNs; plus prefill+decode
consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as T
from repro.models.layers import unembed_logits


def _batch(cfg, key, B=2, S=32):
    if cfg.modality == "audio":
        return {"tokens": jax.random.randint(key, (B, cfg.num_codebooks, S), 0, cfg.vocab_size)}
    if cfg.modality == "vision":
        return {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "vision_embeds": jax.random.normal(key, (B, cfg.vision_patches, cfg.d_frontend)),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


def _last_logits(params, cfg, batch):
    h, _, _, _ = T.forward(params, cfg, batch)
    last = h[:, -1]
    if cfg.modality == "audio":
        return jnp.einsum("bd,kdv->bkv", last.astype(jnp.float32), params["heads"].astype(jnp.float32))
    table = params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]["table"]
    return unembed_logits(table, last, cfg)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params, specs = T.init_params(cfg, key)
    # spec tree mirrors param tree
    assert jax.tree.structure(jax.tree.map(lambda _: 0, params)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda s: isinstance(s, tuple) or s is None)
    )
    batch = _batch(cfg, key)
    loss, metrics = T.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    # one gradient step moves the loss
    grads = jax.grad(lambda p: T.loss_fn(p, cfg, batch)[0])(params)
    gn = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.square(l.astype(jnp.float32)))), grads, 0.0
    )
    assert np.isfinite(gn) and gn > 0
    params2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype), params, grads)
    loss2, _ = T.loss_fn(params2, cfg, batch)
    assert float(loss2) < float(loss), f"{arch}: step did not reduce loss"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    if arch == "deepseek-v3-671b":
        # capacity drops make MoE routing batch-dependent; remove them for the
        # consistency check (see models/moe.py docstring)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if arch == "llama4-scout-17b-a16e":
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(cfg, key)
    B, S = 2, 33
    batch = _batch(cfg, key, B, S)
    ref = _last_logits(params, cfg, batch)
    if cfg.modality == "audio":
        prompt = {"tokens": batch["tokens"][..., : S - 1]}
        last_tok = batch["tokens"][..., S - 1 :]
    else:
        prompt = dict(batch, tokens=batch["tokens"][:, : S - 1])
        last_tok = batch["tokens"][:, S - 1 :]
    cache, cache_specs = T.init_cache(cfg, B, 64)
    _, cache = T.prefill(params, cfg, prompt, cache)
    npos = S - 1 + (cfg.vision_patches if cfg.modality == "vision" else 0)
    logits, cache = T.decode_step(params, cfg, last_tok, jnp.full((B,), npos, jnp.int32), cache)
    rel = float(jnp.max(jnp.abs(ref - logits))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.05, f"{arch}: prefill+decode diverges from forward (rel={rel})"


@pytest.mark.parametrize("arch", ["gemma3-4b", "gemma2-27b", "llama4-scout-17b-a16e"])
def test_local_global_pattern_differs_from_all_global(arch):
    """The sliding-window pattern must actually change the computation."""
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(cfg, key)
    batch = _batch(cfg, key, 1, 24)
    h1, _, _, _ = T.forward(params, cfg, batch)
    cfg_g = dataclasses.replace(cfg, attn_pattern=("global",), window_size=0)
    h2, _, _, _ = T.forward(params, cfg_g, batch)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-4


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters on the FULL configs."""
    rows = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2-1.3b": (48, 2048, 64, 0, 0, 50280),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }
    for arch, (L, d, H, KH, dff, V) in rows.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L and cfg.d_model == d, arch
        assert cfg.num_heads == H and cfg.num_kv_heads == KH, arch
        assert cfg.vocab_size == V, arch
        if arch == "deepseek-v3-671b":
            assert cfg.moe.d_ff_expert == dff and cfg.moe.num_experts == 256 and cfg.moe.top_k == 8
            assert cfg.mla is not None and cfg.mla.kv_lora_rank == 512
        elif arch == "llama4-scout-17b-a16e":
            assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 1
        elif arch == "mamba2-1.3b":
            assert cfg.ssm.d_state == 128
        elif arch == "zamba2-7b":
            assert cfg.ssm.d_state == 64 and cfg.hybrid_period > 0
        else:
            assert cfg.d_ff == dff, arch


def test_param_count_deepseek_scale():
    """deepseek-v3 totals ~671B params, ~37B active (sanity of the config)."""
    cfg = get_config("deepseek-v3-671b")
    total = cfg.total_params()
    active = cfg.active_params_per_token()
    assert 6.0e11 < total < 7.5e11, total
    assert 3.0e10 < active < 4.5e10, active
