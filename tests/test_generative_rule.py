"""Generative-hit rule boundary cases (§3): t_single < t_s < t_combined.

Vectors are crafted so cosine similarities are exact by construction:
entries are orthogonal unit vectors e0, e1 and the query is
q = s0*e0 + s1*e1 + sqrt(1 - s0^2 - s1^2)*e_other, giving cos(q, ei) = si.
"""
import numpy as np
import pytest

from repro.core.embeddings import NgramHashEmbedder
from repro.core.generative_cache import GenerativeCache

DIM = 256
T_SINGLE, T_S, T_COMBINED = 0.3, 0.8, 1.2


def unit(i: int) -> np.ndarray:
    v = np.zeros(DIM, np.float32)
    v[i] = 1.0
    return v


def query_vec(s0: float, s1: float) -> np.ndarray:
    rest = 1.0 - s0 * s0 - s1 * s1
    assert rest >= 0, "similarities must satisfy s0^2 + s1^2 <= 1"
    return (s0 * unit(0) + s1 * unit(1) + np.sqrt(rest) * unit(2)).astype(np.float32)


@pytest.fixture(params=["primary", "secondary"])
def cache(request):
    c = GenerativeCache(
        NgramHashEmbedder(DIM), threshold=T_S, t_single=T_SINGLE,
        t_combined=T_COMBINED, mode=request.param, cache_synthesized=False,
    )
    c.insert("entry zero", "A0", vec=unit(0))
    c.insert("entry one", "A1", vec=unit(1))
    return c


def test_threshold_ordering(cache):
    assert cache.t_single < cache.threshold < cache.t_combined


def test_sum_just_above_t_combined_is_generative_hit(cache):
    # s0 + s1 = 1.205 > 1.2, each in (t_single, t_s)
    r = cache.lookup("q", vec=query_vec(0.6025, 0.6025))
    assert r.hit and r.generative
    assert r.combined_similarity == pytest.approx(1.205, abs=1e-3)
    assert "A0" in r.response and "A1" in r.response


def test_sum_just_below_t_combined_is_miss(cache):
    # s0 + s1 = 1.195 < 1.2
    r = cache.lookup("q", vec=query_vec(0.5975, 0.5975))
    assert not r.hit
    assert r.combined_similarity == pytest.approx(1.195, abs=1e-3)


def test_below_t_single_excluded_from_X(cache):
    # e1's 0.25 < t_single: X = {e0}, sum = 0.7 < t_combined even though the
    # raw sum 0.95 + anything outside X must not count
    r = cache.lookup("q", vec=query_vec(0.7, 0.25))
    assert not r.hit
    assert len(r.sources) == 1
    assert r.combined_similarity == pytest.approx(0.7, abs=1e-3)


def test_just_above_t_single_joins_X(cache):
    # 0.52 > t_single joins X: sum = 1.22 > t_combined -> synthesis from both
    r = cache.lookup("q", vec=query_vec(0.7, 0.52))
    assert r.hit and r.generative
    assert len(r.sources) == 2


def test_single_overwhelming_match_is_direct_hit(cache):
    # best similarity 0.85 > t_s: served directly, no synthesis
    r = cache.lookup("q", vec=query_vec(0.85, 0.45))
    assert r.hit and not r.generative
    assert r.response == "A0"
    assert r.level == "semantic"


def test_generative_hit_count_in_stats(cache):
    cache.lookup("q", vec=query_vec(0.65, 0.65))
    assert cache.stats.generative_hits == 1
    assert cache.stats.hits == 1


def test_synthesized_answer_cached_when_enabled():
    c = GenerativeCache(
        NgramHashEmbedder(DIM), threshold=T_S, t_single=T_SINGLE,
        t_combined=T_COMBINED, cache_synthesized=True,
    )
    c.insert("entry zero", "A0", vec=unit(0))
    c.insert("entry one", "A1", vec=unit(1))
    qv = query_vec(0.65, 0.65)
    r = c.lookup("combined question", vec=qv)
    assert r.hit and r.generative
    # the synthesized answer is now a direct semantic hit for the same vector
    r2 = c.lookup("combined question", vec=qv)
    assert r2.hit and not r2.generative
    assert r2.response == r.response
