"""Sharded zero-host-hop read path (repro.distributed.sharded_read).

Parity: the collective ``shard_map`` program must be BYTE-IDENTICAL to the
pure-numpy ``host_reference_read`` walk — winners, hit/generative classes,
candidate scores/slots, and the LRU/LFU counter deltas. Entries and queries
use dyadic coordinates (0.25/0.5/0.75/1.0) under the dot metric so numpy and
XLA f32 arithmetic cannot diverge by rounding.

Budget: one hierarchy lookup = ONE collective dispatch, ZERO host hops, ZERO
host-side counter scatters — asserted on the dataflow counters.

The in-process tests run on a mesh over however many devices this process
has (tier-1: usually 1 — a shard_map axis of size 1 still runs the
collective program). ``test_eight_device_collective`` re-executes the whole
file in a subprocess with ``--xla_force_host_platform_device_count=8`` so
the same assertions cover a real 8-shard mesh with cross-shard candidate
exchange and ownership-masked counter scatters.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import GenerativeCache, HierarchicalCache  # noqa: E402
from repro.core.embeddings import NgramHashEmbedder  # noqa: E402
from repro.core.read_path import LevelSpec  # noqa: E402
from repro.core.store_bank import StoreBank  # noqa: E402
from repro.core.vector_store import InMemoryVectorStore  # noqa: E402
from repro.distributed.sharded_read import (  # noqa: E402
    ShardedReadBank,
    host_reference_read,
)
from repro.distributed.sharded_store import ShardedVectorStore  # noqa: E402
from repro.launch.mesh import make_cache_mesh  # noqa: E402

DIM = 16
INF = float("inf")


def unit(i, scale=1.0):
    v = np.zeros(DIM, np.float32)
    v[i] = np.float32(scale)
    return v


def _mixed_bank(sh_ttl=None, staleness=0.0):
    """Replicated hot L1 (InMemory) + key-sharded L2 over the device mesh,
    adopted into one ShardedReadBank. Dyadic dot-metric fixtures:

        L1:  unit(0), unit(1), unit(2)
        L2:  unit(10), unit(11), unit(12), unit(1)
    """
    mesh = make_cache_mesh()
    rep = InMemoryVectorStore(DIM, 4, "dot", "lru")
    sh = ShardedVectorStore(
        mesh, dim=DIM, capacity=8, k=5, metric="dot",
        default_ttl_s=sh_ttl, staleness_weight=staleness,
    )
    for i in range(3):
        rep.add(unit(i), f"l1-q{i}", f"l1-a{i}")
    for i in (10, 11, 12, 1):
        sh.add(unit(i), f"l2-q{i}", f"l2-a{i}")
    srb = ShardedReadBank(mesh, [("rep", rep), ("sh", sh)])
    return mesh, rep, sh, srb


# L1 semantic (threshold-only), L2 generative (the §3 rule applies)
SPECS = (
    LevelSpec(False, True, 0.0, INF, 0, 4),
    LevelSpec(True, True, 0.3, 1.0, 4, 5),
)


def _queries():
    q = np.stack([
        unit(0),                               # L1 exact hit
        unit(10),                              # L2 exact hit
        unit(11, 0.75) + unit(12, 0.75),       # L2 generative (1.5 > t_comb)
        unit(13),                              # miss everywhere
        unit(0, 0.5),                          # below both thresholds: miss
        unit(1),                               # both levels score 1.0: L1 wins
    ])
    thr = np.full((len(q), 2), 0.9, np.float32)
    return q, thr


def _counters(srb):
    out = []
    for b in srb.banks():
        out.append((
            np.asarray(b.d_last_access).copy(),
            np.asarray(b.d_access_count).copy(),
        ))
    return out


def _expected_count_delta(srb, ref):
    """Counter model from the reference walk: +1 on every (query, level,
    col) cell the touch mask selects, landed at that level's bank slot."""
    deltas = [np.zeros(c.shape, np.int64) for _, c in _counters(srb)]
    bank_of = {}  # level -> (bank index in srb.banks(), lane or None)
    ri = 0
    for li, (kind, store) in enumerate(srb.members):
        if kind == "rep":
            bank_of[li] = (0, ri)
            ri += 1
        else:
            bank_of[li] = (1 + srb.sh_stores.index(store), None)
    tmask, idx = ref["tmask"], ref["idx"]
    for qi in range(tmask.shape[0]):
        for li in range(tmask.shape[1]):
            bi, lane = bank_of[li]
            flat = deltas[bi] if lane is None else None
            for col in range(tmask.shape[2]):
                if not tmask[qi, li, col]:
                    continue
                slot = int(idx[qi, li, col])
                if lane is not None:
                    deltas[bi][lane, slot] += 1
                else:
                    flat.reshape(-1)[slot] += 1
    return deltas


def test_fused_matches_host_reference_bitwise():
    _, rep, sh, srb = _mixed_bank()
    assert sh.n_shards == len(jax.devices())
    q, thr = _queries()
    ref = host_reference_read(srb, q, thr, SPECS)
    before = _counters(srb)
    dec = srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q)
    after = _counters(srb)

    np.testing.assert_array_equal(dec.winner, ref["winner"])
    np.testing.assert_array_equal(dec.hit, ref["hit"])
    np.testing.assert_array_equal(dec.generative, ref["generative"])
    np.testing.assert_array_equal(dec.scores, ref["scores"])
    np.testing.assert_array_equal(dec.idx, ref["idx"])
    # the walk itself: L1 beats L2 on the tie, generative classed correctly
    np.testing.assert_array_equal(ref["winner"], [0, 1, 1, 2, 2, 0])
    assert bool(dec.generative[2, 1]) and not bool(dec.generative[1, 1])

    # LRU/LFU counter deltas: exactly the reference touch mask, nothing else
    expected = _expected_count_delta(srb, ref)
    for (l0, c0), (l1, c1), exp in zip(before, after, expected):
        np.testing.assert_array_equal(
            c1.astype(np.int64) - c0.astype(np.int64), exp
        )
        touched = exp > 0
        assert (l1[touched] > l0[touched]).all()
        np.testing.assert_array_equal(l1[~touched], l0[~touched])

    # sharded levels report store-global flat slots join_candidates resolves
    win_slot = int(dec.idx[1, 1, 0])
    assert sh.payloads[win_slot] == ("l2-q10", "l2-a10")


def test_touch_false_leaves_counters():
    _, _, _, srb = _mixed_bank()
    q, thr = _queries()
    before = _counters(srb)
    srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q, touch=False)
    for (l0, c0), (l1, c1) in zip(before, _counters(srb)):
        np.testing.assert_array_equal(c0, c1)
        np.testing.assert_array_equal(l0, l1)


def test_router_masks_lane_visibility():
    _, _, _, srb = _mixed_bank()
    q, thr = _queries()
    router = np.ones((len(q), 2), bool)
    router[1, 1] = False  # hide L2 from the L2-exact-hit query
    router[5, 0] = False  # hide L1 from the tie query -> L2 must win it
    ref = host_reference_read(srb, q, thr, SPECS, router=router)
    dec = srb.fused_read(
        None, [None] * len(q), thr, SPECS, vecs=q, router=router, touch=False
    )
    np.testing.assert_array_equal(dec.winner, ref["winner"])
    np.testing.assert_array_equal(dec.scores, ref["scores"])
    assert int(dec.winner[1]) == 2  # routed-away lane cannot serve the hit
    assert int(dec.winner[5]) == 1  # ...and the walk falls through to L2


def test_lifecycle_pre_topk_parity(monkeypatch):
    _, _, sh, srb = _mixed_bank(sh_ttl=30.0, staleness=0.5)
    sh.add(unit(14), "l2-q14", "l2-a14", ttl_s=5.0)  # dead at now+15
    assert srb.lifecycle_active()
    now = StoreBank.rel_now() + 15.0
    monkeypatch.setattr(StoreBank, "rel_now", staticmethod(lambda: now))
    q, thr = _queries()
    q = np.concatenate([q, unit(14)[None]])
    thr = np.concatenate([thr, np.full((1, 2), 0.9, np.float32)])
    ref = host_reference_read(srb, q, thr, SPECS, now=now)
    dec = srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q, touch=False)
    np.testing.assert_array_equal(dec.scores, ref["scores"])
    np.testing.assert_array_equal(dec.winner, ref["winner"])
    # staleness penalty applied pre-top-k: ~1.0 - 0.5 * (15/30) = 0.75 < 0.9
    # (a hair more — the entry aged a few ms between insert and now-capture)
    assert abs(float(dec.scores[1, 1, 0]) - 0.75) < 0.01
    assert int(dec.winner[1]) == 2
    # the expired row is invisible, not merely penalized: its ~0.75
    # penalized dot can never surface (the best survivor is a live zero-dot
    # entry minus its staleness penalty)
    assert float(dec.scores[6, 1, 0]) < 0.0
    assert int(dec.winner[6]) == 2


def test_store_fused_matches_host_paths():
    mesh = make_cache_mesh()
    s = ShardedVectorStore(mesh, dim=DIM, capacity=8, k=3, metric="dot")
    for i in range(5):
        s.add(unit(i), f"q{i}", f"a{i}")
    q = np.stack([unit(0), unit(4), unit(2, 0.5), unit(7)])

    fs, fi = s.search(q)
    hs, hi = s.search_host(q)
    np.testing.assert_array_equal(fs, hs)
    np.testing.assert_array_equal(fi, hi)

    fb = s.search_batch(q, k=3, touch=False)
    hb = s.search_batch_host(q, k=3, touch=False)
    assert fb == hb

    fl = s.lookup_batch(q, np.full(len(q), 0.9))
    hl = s.lookup_batch_host(q, np.full(len(q), 0.9))
    assert fl == hl
    assert fl[0] == (1.0, ("q0", "a0")) and fl[3] is None


def test_dispatch_and_host_hop_budget():
    _, _, _, srb = _mixed_bank()
    q, thr = _queries()
    srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q)  # warm/flush
    banks = srb.banks()
    d0 = [b.dispatches for b in banks]
    h0 = [b.host_hops for b in banks]
    c0 = [b.counter_scatters for b in banks]
    sd0, sh0, sc0 = srb.dispatches, srb.host_hops, srb.counter_scatters
    srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q)
    assert srb.dispatches - sd0 == 1  # ONE collective dispatch
    assert srb.host_hops - sh0 == 0 and srb.counter_scatters - sc0 == 0
    for b, d, h, c in zip(banks, d0, h0, c0):
        assert b.dispatches == d  # member banks never dispatch on their own
        assert b.host_hops == h  # zero host hops anywhere in the read
        assert b.counter_scatters == c  # touches ride the collective program


def _hier():
    emb = NgramHashEmbedder(dim=DIM)
    mesh = make_cache_mesh()
    l1 = GenerativeCache(emb, threshold=0.6, t_single=0.45, t_combined=1.0,
                         capacity=16)
    l2 = GenerativeCache(
        emb, threshold=0.6, t_single=0.45, t_combined=1.0,
        store=ShardedVectorStore(mesh, dim=emb.dim, capacity=16, k=4),
    )
    return l1, l2, HierarchicalCache(l1, l2)


def test_hierarchy_serves_through_sharded_bank():
    l1, l2, h = _hier()
    srb = h.ensure_sharded_bank()
    assert srb is not None and h.ensure_sharded_bank() is srb  # cached
    l1.insert("what is the capital of france", "Paris")
    l2.insert("how tall is the eiffel tower", "330 m")
    h.lookup_batch(["warm"])  # adoption + compile + pending flush
    d0 = srb.dispatches
    res = h.lookup_batch([
        "what is the capital of france",
        "how tall is the eiffel tower",
        "unrelated quantum chromodynamics question",
    ])
    assert srb.dispatches - d0 == 1
    assert srb.host_hops == 0
    assert [r.hit for r in res] == [True, True, False]
    assert res[0].level.startswith("L1:")
    assert res[1].level.startswith("L2:")
    # the L2 winner was promoted into L1 by the deferred writeback
    d1 = srb.dispatches
    res2 = h.lookup_batch(["how tall is the eiffel tower"])
    assert res2[0].level.startswith("L1:") and srb.dispatches - d1 == 1


def test_hierarchy_router_knob():
    l1, l2, h0 = _hier()
    l2.insert("who wrote les miserables", "Victor Hugo")
    h = HierarchicalCache(
        l1, l2, router=lambda qs, cs: np.array([[True, False]] * len(qs))
    )
    assert h.ensure_sharded_bank() is not None
    res = h.lookup_batch(["who wrote les miserables"])
    assert not res[0].hit  # L2 is routed away for every query
    h_open = HierarchicalCache(l1, l2)
    assert h_open.lookup_batch(["who wrote les miserables"])[0].hit


def test_ineligible_levels_return_none():
    emb = NgramHashEmbedder(dim=DIM)
    l1 = GenerativeCache(emb, capacity=16)
    l2 = GenerativeCache(emb, capacity=16)
    # no sharded level: the single-host bank path owns this hierarchy
    assert HierarchicalCache(l1, l2).ensure_sharded_bank() is None

    mesh = make_cache_mesh()
    l2s = GenerativeCache(
        emb, store=ShardedVectorStore(mesh, dim=emb.dim, capacity=16)
    )
    hc = HierarchicalCache(l1, l2s)
    assert hc.ensure_sharded_bank() is not None

    class CustomStore(InMemoryVectorStore):
        def search_batch(self, q_vecs, k=4, touch=True):
            return super().search_batch(q_vecs, k=k, touch=touch)

    l1c = GenerativeCache(emb, store=CustomStore(emb.dim, 16))
    assert HierarchicalCache(l1c, l2s).ensure_sharded_bank() is None


def test_pinned_staging_cpu_fallback():
    from repro.kernels.backend import pinned_host_supported, stage_pinned

    rows = np.arange(2 * DIM, dtype=np.float32).reshape(2, DIM)
    staged = stage_pinned(rows)
    np.testing.assert_array_equal(np.asarray(staged), rows)
    if not pinned_host_supported():  # CPU: pageable block passes through
        assert staged is rows


def test_shard_mask_degrades_to_survivors():
    """Resilience leg: a dead shard's candidates score -inf inside the
    collective program and its counters stay untouched, so lookups degrade
    to the surviving shards' winners — verified against the masked host
    reference walk. (On the 1-device mesh the only shard can't be masked;
    the 8-device subprocess rerun covers the real degradation.)"""
    _, _, sh, srb = _mixed_bank()
    q, thr = _queries()
    n_shards = srb.n_shards
    if n_shards == 1:
        with pytest.raises(ValueError):
            srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q,
                           shard_mask=np.zeros(1, bool))
        ref = host_reference_read(srb, q, thr, SPECS)
        dec = srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q,
                             touch=False, shard_mask=np.ones(1, bool))
        np.testing.assert_array_equal(dec.winner, ref["winner"])
        np.testing.assert_array_equal(dec.scores, ref["scores"])
        assert not srb.degraded  # an all-alive mask is not a degraded read
        return

    # kill the shard owning unit(10)'s L2 entry (the row-1 exact hit)
    clean = host_reference_read(srb, q, thr, SPECS)
    cap_shard = sh.capacity // n_shards
    dead = int(clean["idx"][1, 1, 0]) // cap_shard
    mask = np.ones(n_shards, bool)
    mask[dead] = False

    ref = host_reference_read(srb, q, thr, SPECS, shard_mask=mask)
    before = _counters(srb)
    dec = srb.fused_read(None, [None] * len(q), thr, SPECS, vecs=q,
                         shard_mask=mask)
    after = _counters(srb)

    assert srb.degraded and srb.degraded_reads == 1
    np.testing.assert_array_equal(dec.winner, ref["winner"])
    np.testing.assert_array_equal(dec.hit, ref["hit"])
    np.testing.assert_array_equal(dec.generative, ref["generative"])
    finite = np.isfinite(ref["scores"])
    np.testing.assert_array_equal(dec.scores[finite], ref["scores"][finite])
    np.testing.assert_array_equal(dec.idx[finite], ref["idx"][finite])
    # row 1 lost its exact L2 hit with the shard; row 0's L1 hit survives
    assert bool(clean["hit"][1, 1]) and not bool(dec.hit[1, 1])
    assert bool(dec.hit[0, 0])
    # counters: exactly the masked reference's touch mask, nothing on the
    # dead shard's slots
    expected = _expected_count_delta(srb, ref)
    for (l0, c0), (l1, c1), exp in zip(before, after, expected):
        np.testing.assert_array_equal(
            c1.astype(np.int64) - c0.astype(np.int64), exp
        )


def test_eight_device_collective():
    """The whole file again on a forced 8-virtual-device mesh: real
    cross-shard candidate exchange, ownership-masked counter scatters."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", os.path.abspath(__file__),
         "-k", "not eight_device", "-p", "no:cacheprovider"],
        env=env, cwd=root, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
