"""ShardedVectorStore tier-1 demotion ring: eviction victims land in the
host-RAM tier keyed by their home shard (instead of vanishing), promotions
restore them byte-identical and prefer the freed home-lane slot, and
age-based clears cascade — matching ``InMemoryVectorStore`` semantics."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.embeddings import NgramHashEmbedder  # noqa: E402
from repro.core.semantic_cache import SemanticCache  # noqa: E402
from repro.core.tiers import HostRamTier, TierEntry  # noqa: E402
from repro.distributed.sharded_store import ShardedVectorStore  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402

DIM = 8


def unit(i: int) -> np.ndarray:
    v = np.zeros(DIM, np.float32)
    v[i] = 1.0
    return v


def _sharded(capacity=3, tier_cap=16, **kw):
    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    tier = HostRamTier(DIM, capacity=tier_cap)
    store = ShardedVectorStore(
        mesh, dim=DIM, capacity=capacity, k=3, tier1=tier, **kw
    )
    return store, tier


def test_eviction_demotes_victim_into_tier1():
    s, tier = _sharded(capacity=3)
    keys = [s.add(unit(i), f"q{i}", f"a{i}") for i in range(3)]
    s.search_batch(unit(0)[None], k=1)  # touch q0 -> q1 is the LRU victim
    s.add(unit(3), "q3", "a3")
    assert len(tier) == 1
    sc, slots = tier.search(unit(1), k=1)
    e = tier.get(int(slots[0, 0]))
    assert sc[0, 0] == pytest.approx(1.0, abs=1e-5)
    assert (e.key, e.query, e.response) == (keys[1], "q1", "a1")
    assert 0 <= e.meta["home_shard"] < s.n_shards


def test_demotion_preserves_stamps_and_access_count():
    s, tier = _sharded(capacity=3, default_ttl_s=3600.0)
    s.add(unit(0), "q0", "a0")
    s.add(unit(1), "q1", "a1")
    s.add(unit(2), "q2", "a2")
    for _ in range(3):  # bump q0's frequency counter, then evict it anyway
        s.search_batch(unit(0)[None], k=1)
    s.search_batch(unit(1)[None], k=1)
    s.search_batch(unit(2)[None], k=1)
    s.add(unit(3), "q3", "a3")  # FIFO-of-recency: q0 touched first -> victim
    victims = [e for e, _ in tier.snapshot_entries()]
    assert len(victims) == 1
    e = victims[0]
    assert e.access_count == 3
    assert e.expires_at - e.created_at == pytest.approx(3600.0, abs=5.0)


def test_promote_restores_identity_and_prefers_home_slot():
    s, tier = _sharded(capacity=4)
    keys = [s.add(unit(i), f"q{i}", f"a{i}") for i in range(4)]
    for _ in range(2):
        s.search_batch(unit(0)[None], k=1)
    home_idx = s._key_to_slot[keys[0]]
    s.remove(keys[0])  # frees the slot without demoting (explicit delete)
    assert len(tier) == 0
    # hand-demote q0 as if it had been evicted, then promote it back
    s._restore_batch(
        unit(0)[None],
        [TierEntry(
            key=keys[0], query="q0", response="a0",
            meta={"home_shard": home_idx // s.cap_local},
            created_at=s.bank.to_abs(0.0) + 5.0,
            expires_at=float("inf"),
            access_count=7,
        )],
    )
    idx = s._key_to_slot[keys[0]]
    assert idx == home_idx  # freed home-lane slot reused, nobody evicted
    assert s.payloads[idx] == ("q0", "a0")
    assert len(s) == 4 and all(p is not None for p in s.payloads[:4])
    lane, within = s._lane_within(idx)
    assert int(s.bank.access_count[lane, within]) == 7
    sc, idxs = s.search(unit(0)[None])
    assert sc[0, 0] == pytest.approx(1.0, abs=1e-5) and int(idxs[0, 0]) == idx


def test_demote_restore_roundtrip_via_tier_pop():
    s, tier = _sharded(capacity=2)
    ka = s.add(unit(0), "qa", "ra")
    s.add(unit(1), "qb", "rb")
    s.search_batch(unit(0)[None], k=1)  # count 1 on qa
    s.add(unit(2), "qc", "rc")  # evicts qb; qa survives
    s.add(unit(3), "qd", "rd")  # now qa demotes too
    assert ka not in s._key_to_slot and len(tier) == 2
    sc, slots = tier.search(unit(0), k=1)
    e, vec = tier.pop(int(slots[0, 0]))
    s._restore_batch(vec[None], [e])
    idx = s._key_to_slot[ka]
    assert s.payloads[idx] == ("qa", "ra")
    lane, within = s._lane_within(idx)
    assert int(s.bank.access_count[lane, within]) == 1
    # restoring displaced a live entry: it demoted into the tier, not dropped
    assert len(tier) == 2


def test_clear_cascades_into_tier1():
    s, tier = _sharded(capacity=2)
    for i in range(4):
        s.add(unit(i), f"q{i}", f"a{i}")
    assert len(tier) == 2
    dropped = s.clear()
    assert dropped == 4 and len(s) == 0 and len(tier) == 0


def test_consult_tier1_promotes_through_semantic_cache():
    """The sharded store keeps (query, response) payloads instead of Entry
    rows; consult_tier1 must reconstruct the hit from the TierEntry."""
    emb = NgramHashEmbedder(dim=DIM)
    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    tier = HostRamTier(DIM, capacity=16)
    store = ShardedVectorStore(mesh, dim=DIM, capacity=2, k=2, tier1=tier)
    cache = SemanticCache(emb, threshold=0.85, store=store)
    va = emb.embed(["oldest question"])[0]
    store.add(va, "oldest question", "oldest answer")
    store.add(emb.embed(["middle question"])[0], "middle question", "middle answer")
    store.add(emb.embed(["newest question"])[0], "newest question", "newest answer")
    assert len(tier) == 1  # oldest demoted
    out = cache.consult_tier1(
        ["oldest question"], np.asarray(va)[None], [0.85], [0]
    )
    assert 0 in out
    r = out[0]
    assert r.hit and r.level == "tier1" and r.response == "oldest answer"
    # promoted out of the ring; the entry it displaced demoted into it
    assert {e.response for e, _ in tier.snapshot_entries()} != {"oldest answer"}
    sc, _ = store.search(np.asarray(va)[None])
    assert sc[0, 0] == pytest.approx(1.0, abs=1e-4)  # back on device
