"""Async-first CacheService + priority scheduler: hits resolve before
co-batched misses generate, priority ordering under contention, deadline
expiry without a backend call, typed admission control / close errors, and
the asyncio facade (stdlib ``asyncio.run`` harness — no pytest-asyncio)."""
import asyncio
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import (
    CacheRequest,
    EnhancedClient,
    GenerativeCache,
    LLMBackend,
    LLMResponse,
    MockLLM,
    NgramHashEmbedder,
)
from repro.core.request import DEADLINE_EXCEEDED, GENERATED, HIT
from repro.serving.coalescer import (
    AdmissionRejected,
    BatchCoalescer,
    DeadlineExceeded,
    ServiceClosed,
)
from repro.serving.service import CacheService


def _client(latency_s: float = 0.0, backend=None):
    cache = GenerativeCache(
        NgramHashEmbedder(), threshold=0.85, t_single=0.45, t_combined=1.0
    )
    client = EnhancedClient(cache=cache)
    client.register_backend(backend or MockLLM("backend", latency_s=latency_s))
    return client, cache


class GatedLLM(LLMBackend):
    """First generate_batch call blocks on ``gate``; later calls record the
    prompt order — lets tests pile work behind a busy dispatcher."""

    name = "gated"

    def __init__(self):
        self.order = []
        self.gate = threading.Event()
        self.entered = threading.Event()

    def generate_batch(self, prompts, max_tokens: int = 256, temperature: float = 0.0):
        if not self.entered.is_set():
            self.entered.set()
            assert self.gate.wait(timeout=10)
        self.order.extend(prompts)
        return [LLMResponse(f"generated: {p}", self.name) for p in prompts]


# -- the headline invariant ----------------------------------------------------


def test_hit_future_resolves_before_cobatched_miss_generates():
    client, cache = _client(latency_s=0.5)
    cache.insert("what is a cache", "a cache stores answers")
    cache.lookup_batch(["warm", "warm 2"])  # compile outside the assertion window
    with CacheService(client, max_batch=8, max_wait_ms=20.0) as svc:
        miss_fut = svc.submit(CacheRequest("completely unrelated question zq"))
        hit_fut = svc.submit(CacheRequest("what is a cache"))
        hit = hit_fut.result(timeout=5)
        assert hit.status == HIT and hit.from_cache
        assert hit.text == "a cache stores answers"
        assert not miss_fut.done()  # the 0.5s generation is still in flight
        miss = miss_fut.result(timeout=5)
        assert miss.status == GENERATED and not miss.from_cache
    assert svc.stats.hits == 1 and svc.stats.generated == 1


def test_generated_answer_backfills_cache():
    client, cache = _client()
    with CacheService(client, max_wait_ms=1.0) as svc:
        first = svc.submit(CacheRequest("novel question about jax")).result(timeout=5)
        assert first.status == GENERATED
        again = svc.submit(CacheRequest("novel question about jax")).result(timeout=5)
        assert again.status == HIT and again.text == first.text


# -- priority / deadline scheduling --------------------------------------------


def test_priority_ordering_under_contention():
    backend = GatedLLM()
    client, _ = _client(backend=backend)
    svc = CacheService(client, max_wait_ms=1.0, dispatch_batch=1, dispatch_wait_ms=1.0)
    filler = svc.submit(CacheRequest("filler"))
    assert backend.entered.wait(timeout=10)  # dispatcher now blocked in the backend
    futs = [
        svc.submit(CacheRequest(p, priority=pr))
        for p, pr in [("low prio q", 0), ("high prio q", 9), ("mid prio q", 3)]
    ]
    time.sleep(0.05)  # let the lookup stage forward all three misses
    backend.gate.set()
    for f in [filler] + futs:
        assert f.result(timeout=10).status == GENERATED
    svc.close()
    assert backend.order[1:] == ["high prio q", "mid prio q", "low prio q"]


def test_deadline_expiry_resolves_without_backend_call():
    backend = GatedLLM()
    client, _ = _client(backend=backend)
    svc = CacheService(client, max_wait_ms=1.0)
    filler = svc.submit(CacheRequest("filler"))
    assert backend.entered.wait(timeout=10)
    doomed = svc.submit(CacheRequest("urgent but doomed", deadline_s=0.05))
    time.sleep(0.15)  # deadline passes while the dispatcher is blocked
    backend.gate.set()
    resp = doomed.result(timeout=10)
    assert resp.status == DEADLINE_EXCEEDED and resp.expired
    assert resp.text is None
    assert filler.result(timeout=10).status == GENERATED
    svc.close()
    assert "urgent but doomed" not in backend.order  # never generated
    assert svc.stats.expired == 1


def test_hit_served_even_past_deadline():
    # deadlines shed *generation* load; an instant hit is still worth serving
    client, cache = _client()
    cache.insert("cached q", "cached a")
    with CacheService(client, max_wait_ms=1.0) as svc:
        resp = svc.submit(CacheRequest("cached q", deadline_s=30.0)).result(timeout=5)
        assert resp.status == HIT


# -- admission control ----------------------------------------------------------


def test_admission_rejection_is_typed_and_drain_survives():
    backend = GatedLLM()
    client, _ = _client(backend=backend)
    svc = CacheService(client, max_wait_ms=1.0, max_inflight=2)
    f1 = svc.submit(CacheRequest("first"))
    assert backend.entered.wait(timeout=10)
    f2 = svc.submit(CacheRequest("second"))
    with pytest.raises(AdmissionRejected):
        svc.submit(CacheRequest("over budget"))
    assert svc.stats.rejected == 1
    backend.gate.set()
    assert f1.result(timeout=10).status == GENERATED
    assert f2.result(timeout=10).status == GENERATED
    # the drain thread survived the rejection: new work is accepted and served
    assert svc.submit(CacheRequest("after the storm")).result(timeout=10).status == GENERATED
    svc.close()


def test_submit_after_close_raises_typed_service_closed():
    client, _ = _client()
    svc = CacheService(client, max_wait_ms=1.0)
    assert svc.submit(CacheRequest("one")).result(timeout=10).status == GENERATED
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit(CacheRequest("too late"))
    with pytest.raises(ServiceClosed):
        svc.complete([CacheRequest("too late")])


# -- sync compatibility wrappers -------------------------------------------------


def test_sync_wrappers_ride_the_service():
    client, cache = _client()
    r1 = client.query("some question")
    assert not r1.from_cache
    r2 = client.query("some question")
    assert r2.from_cache and r2.cost_usd == 0.0
    rs = client.complete_batch(["some question", "another question"])
    assert rs[0].from_cache and not rs[1].from_cache
    assert client.stats.requests == 4 and client.stats.cache_hits == 2


def test_complete_requests_per_request_hints():
    client, cache = _client()
    reqs = [
        CacheRequest("public question"),
        CacheRequest("private question", cache_l1=False, cache_l2=False),
    ]
    rs = client.complete_requests(reqs)
    assert all(not r.from_cache for r in rs)
    stored = [e.query for e in cache.store._entries if e is not None]
    assert "public question" in stored and "private question" not in stored


def test_query_many_mixed_models_grouped_dispatch():
    client, _ = _client()
    m2 = MockLLM("m2")
    client.register_backend(m2)
    rs = client.query_many(["q a", "q b", "q c"], models=["backend", "m2", "backend"],
                           use_cache=False)
    assert [r.model for r in rs] == ["backend", "m2", "backend"]


# -- scheduler (reworked BatchCoalescer) unit tests ------------------------------


def test_coalescer_priority_order_under_contention():
    batches = []
    gate, entered = threading.Event(), threading.Event()

    def handler(items):
        if not entered.is_set():
            entered.set()
            assert gate.wait(timeout=10)
        batches.append(list(items))
        return items

    with BatchCoalescer(handler, max_batch=2, max_wait_ms=1.0) as co:
        warm = co.submit("warm")
        assert entered.wait(timeout=10)
        futs = [co.submit(x, priority=p) for x, p in [("lo", 0), ("hi", 9), ("mid", 5)]]
        time.sleep(0.02)
        gate.set()
        for f in [warm] + futs:
            f.result(timeout=10)
    assert [x for b in batches[1:] for x in b] == ["hi", "mid", "lo"]


def test_coalescer_deadline_default_exception():
    gate, entered = threading.Event(), threading.Event()

    def handler(items):
        if not entered.is_set():
            entered.set()
            assert gate.wait(timeout=10)
        return items

    with BatchCoalescer(handler, max_batch=4, max_wait_ms=1.0) as co:
        co.submit("warm")
        assert entered.wait(timeout=10)
        doomed = co.submit("doomed", deadline_s=0.01)
        time.sleep(0.05)
        gate.set()
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=10)
        assert co.stats.expired == 1


def test_coalescer_close_flushes_pending_futures():
    co = BatchCoalescer(lambda xs: [x + 1 for x in xs], max_batch=4, max_wait_ms=50.0)
    futs = [co.submit(i) for i in range(10)]
    co.close()
    assert all(f.done() for f in futs)
    assert sorted(f.result() for f in futs) == [i + 1 for i in range(10)]


def test_coalescer_submit_after_close_typed():
    co = BatchCoalescer(lambda xs: xs, max_batch=2)
    co.close()
    with pytest.raises(ServiceClosed):
        co.submit(1)
    assert isinstance(ServiceClosed("x"), RuntimeError)  # old callers still catch


def test_coalescer_admission_rejected_is_queue_full():
    import queue

    gate, entered = threading.Event(), threading.Event()

    def handler(items):
        if not entered.is_set():
            entered.set()
            assert gate.wait(timeout=10)
        return items

    co = BatchCoalescer(handler, max_batch=1, max_wait_ms=1.0, max_queue=2)
    f0 = co.submit("warm")
    assert entered.wait(timeout=10)
    fs = [co.submit(i) for i in range(2)]
    with pytest.raises(AdmissionRejected):
        co.submit("overflow")
    assert isinstance(AdmissionRejected("x"), queue.Full)  # old callers still catch
    assert co.stats.rejected == 1
    gate.set()
    for f in [f0] + fs:
        f.result(timeout=10)
    co.close()


# -- asyncio facade --------------------------------------------------------------


def test_asyncio_facade_roundtrip():
    client, cache = _client(latency_s=0.05)
    cache.insert("what is a cache", "a cache stores answers")

    async def main():
        with CacheService(client, max_wait_ms=2.0) as svc:
            hit = await svc.acomplete("what is a cache")
            miss = await svc.asubmit(CacheRequest("a new question xq"))
            pair = await asyncio.gather(
                svc.asubmit(CacheRequest("what is a cache")),
                svc.asubmit(CacheRequest("another new question yq", priority=5)),
            )
            return hit, miss, pair

    hit, miss, pair = asyncio.run(main())
    assert hit.status == HIT and hit.from_cache
    assert miss.status == GENERATED
    assert pair[0].status == HIT and pair[1].status == GENERATED


def test_asyncio_gather_mixed_stream_hits_fast():
    client, cache = _client(latency_s=0.3)
    cache.insert("hot query", "hot answer")
    cache.lookup_batch(["warm", "warm 2"])

    async def main():
        with CacheService(client, max_wait_ms=5.0) as svc:
            t0 = time.perf_counter()
            miss_task = svc.asubmit(CacheRequest("cold query zz"))
            hit = await svc.acomplete("hot query")
            hit_elapsed = time.perf_counter() - t0
            await miss_task
            return hit, hit_elapsed, time.perf_counter() - t0

    hit, hit_elapsed, total = asyncio.run(main())
    assert hit.status == HIT
    assert hit_elapsed < total  # the hit did not wait for the miss


def test_concurrent_submitters_share_batches():
    client, cache = _client()
    hot = [f"hot question {i}" for i in range(8)]
    cache.insert_batch(hot, [f"answer {i}" for i in range(8)])
    with CacheService(client, max_batch=8, max_wait_ms=20.0) as svc:
        with ThreadPoolExecutor(max_workers=8) as pool:
            resps = list(pool.map(
                lambda q: svc.submit(CacheRequest(q)).result(timeout=10), hot
            ))
    assert all(r.status == HIT for r in resps)
    lookup_stats, _ = svc.scheduler_stats
    assert max(lookup_stats.batch_sizes) > 1  # concurrency actually coalesced


def test_submit_many_blocks_for_capacity_instead_of_shedding():
    client, _ = _client(latency_s=0.05)
    svc = CacheService(client, max_wait_ms=1.0, max_inflight=2)
    prompts = ["alpha falcon dawn", "brine cobalt ember", "cedar glyph mirth",
               "dune harbor nickel", "elm quartz saffron", "fjord lichen topaz"]
    futs = svc.submit_many([CacheRequest(p) for p in prompts])
    assert len(futs) == 6
    assert [f.result(timeout=10).status for f in futs] == [GENERATED] * 6
    assert svc.stats.rejected == 0  # waited, never shed
    svc.close()


def test_query_many_larger_than_inflight_budget():
    client, _ = _client()
    client.service.max_inflight = 3  # force capacity waits in the bulk path
    rs = client.query_many([f"q {i}" for i in range(10)], use_cache=False)
    assert len(rs) == 10 and all(r.text for r in rs)


def test_coalescer_starved_low_priority_deadline_still_expires():
    """A deadlined item that never wins a pop (sustained high-priority load)
    must still resolve typed: expiry sweeps the whole heap at each drain."""
    gate, entered = threading.Event(), threading.Event()

    def handler(items):
        if not entered.is_set():
            entered.set()
            assert gate.wait(timeout=10)
        return items

    co = BatchCoalescer(handler, max_batch=2, max_wait_ms=1.0)
    warm = co.submit("warm")
    assert entered.wait(timeout=10)
    doomed = co.submit("doomed", priority=0, deadline_s=0.02)
    highs = [co.submit(f"hi{i}", priority=9) for i in range(4)]
    time.sleep(0.05)  # deadline passes while blocked behind the gated batch
    gate.set()
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=10)
    for f in [warm] + highs:
        f.result(timeout=10)
    co.close()
    assert co.stats.expired == 1


# -- in-flight miss dedup ------------------------------------------------------


def _wait_for(predicate, timeout=10.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if predicate():
            return True
        time.sleep(0.005)
    return False


def test_paraphrase_burst_of_misses_generates_once():
    """Near-identical queued misses coalesce onto ONE backend generation:
    the follower futures resolve from the leader's result (the async-path
    fix for the cold paraphrase burst in ROADMAP)."""
    backend = GatedLLM()
    client, cache = _client(backend=backend)
    cache.lookup_batch(["warm 1"])  # compile outside the timing-sensitive window
    with CacheService(client, max_batch=8, max_wait_ms=2.0) as svc:
        blocker = svc.submit(CacheRequest("blocker question zzz"))
        assert backend.entered.wait(timeout=10)
        burst = [svc.submit(CacheRequest("what color is a ripe apple"))
                 for _ in range(3)]
        distinct = svc.submit(CacheRequest("submarine hull engineering basics"))
        # every queued miss must reach the dispatcher before the gate opens,
        # or it would ride a later batch (and legitimately dedup nothing)
        assert _wait_for(lambda: svc.scheduler_stats[1].submitted >= 5)
        backend.gate.set()
        rs = [f.result(timeout=10) for f in burst]
        assert all(r.status == GENERATED for r in rs)
        assert len({r.text for r in rs}) == 1  # one generation, shared result
        assert backend.order.count("what color is a ripe apple") == 1
        assert distinct.result(timeout=10).status == GENERATED
        assert blocker.result(timeout=10).status == GENERATED
    assert svc.stats.deduped == 2
    assert svc.stats.generated == 3  # blocker + burst leader + distinct
    # only the leader pays: followers carry zero marginal cost
    assert sum(r.cost_usd for r in rs) == rs[0].cost_usd


def test_dissimilar_misses_do_not_dedup():
    backend = GatedLLM()
    client, _ = _client(backend=backend)
    with CacheService(client, max_batch=8, max_wait_ms=2.0) as svc:
        blocker = svc.submit(CacheRequest("blocker question zzz"))
        assert backend.entered.wait(timeout=10)
        a = svc.submit(CacheRequest("how do transformers compute attention"))
        b = svc.submit(CacheRequest("best chocolate cake recipe for birthdays"))
        assert _wait_for(lambda: svc.scheduler_stats[1].submitted >= 3)
        backend.gate.set()
        assert a.result(timeout=10).text != b.result(timeout=10).text
        blocker.result(timeout=10)
    assert svc.stats.deduped == 0


def test_force_fresh_requests_never_coalesce():
    backend = GatedLLM()
    client, _ = _client(backend=backend)
    with CacheService(client, max_batch=8, max_wait_ms=2.0) as svc:
        blocker = svc.submit(CacheRequest("blocker question zzz"))
        assert backend.entered.wait(timeout=10)
        futs = [svc.submit(CacheRequest("identical fresh prompt", force_fresh=True))
                for _ in range(2)]
        assert _wait_for(lambda: svc.scheduler_stats[1].submitted >= 3)
        backend.gate.set()
        for f in futs:
            assert f.result(timeout=10).status == GENERATED
        blocker.result(timeout=10)
    assert svc.stats.deduped == 0
    assert backend.order.count("identical fresh prompt") == 2


def test_dedup_disabled_generates_per_miss():
    backend = GatedLLM()
    client, _ = _client(backend=backend)
    with CacheService(client, max_batch=8, max_wait_ms=2.0,
                      dedup_misses=False) as svc:
        blocker = svc.submit(CacheRequest("blocker question zzz"))
        assert backend.entered.wait(timeout=10)
        futs = [svc.submit(CacheRequest("identical prompt twice")) for _ in range(2)]
        assert _wait_for(lambda: svc.scheduler_stats[1].submitted >= 3)
        backend.gate.set()
        for f in futs:
            f.result(timeout=10)
        blocker.result(timeout=10)
    assert svc.stats.deduped == 0
    assert backend.order.count("identical prompt twice") == 2


def test_sync_complete_path_does_not_dedup():
    """The inline complete() path must stay decision-identical to B
    sequential lookups: no dedup (each miss generates)."""
    client, _ = _client()
    svc = CacheService(client)
    rs = svc.complete([CacheRequest("same sync prompt"), CacheRequest("same sync prompt")])
    assert [r.status for r in rs] == [GENERATED, GENERATED]
    assert svc.stats.deduped == 0


def test_dedup_disabled_on_non_cosine_metric():
    """The dedup criterion is cosine-vs-threshold; a euclidean/dot cache's
    threshold lives in a different score space, so dedup must not fire."""
    from repro.serving.service import _Pending

    cache = GenerativeCache(NgramHashEmbedder(), threshold=0.85, t_single=0.45,
                            t_combined=1.0, metric="euclidean")
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("backend"))
    svc = CacheService(client)
    t0 = time.perf_counter()
    pendings = [
        _Pending(CacheRequest("identical prompt"), rid, "backend", t0, None,
                 vec=np.ones(cache.embedder.dim, np.float32))
        for rid in range(2)
    ]
    assert svc._dedup_misses(pendings, [0, 1]) == {}


class StallingDeadlineLLM(LLMBackend):
    """Deadline-aware backend that stalls exactly long enough for a deadline
    carried into ``generate_batch`` to pass mid-generation: those prompts
    come back ``expired=True``, the rest generate normally. First call
    blocks on ``gate`` like GatedLLM so tests can pile work behind it."""

    name = "stalling"

    def __init__(self, stall_s: float = 1.3):
        self.stall_s = stall_s
        self.calls = []
        self.gate = threading.Event()
        self.entered = threading.Event()

    def generate_batch(self, prompts, max_tokens: int = 256,
                       temperature: float = 0.0, deadlines=None):
        self.calls.append((tuple(prompts), deadlines))
        if not self.entered.is_set():
            self.entered.set()
            assert self.gate.wait(timeout=10)
        if deadlines is not None and any(d is not None for d in deadlines):
            time.sleep(self.stall_s)
        now = time.perf_counter()
        out = []
        for i, p in enumerate(prompts):
            dl = deadlines[i] if deadlines is not None else None
            if dl is not None and now > dl:
                out.append(LLMResponse("", self.name, expired=True))
            else:
                out.append(LLMResponse(f"generated: {p}", self.name))
        return out


def test_deduped_follower_regenerates_when_leader_expires_mid_generation():
    """Regression: a deduped follower must not inherit its leader's
    mid-generation deadline expiry. A follower with headroom re-dispatches
    and generates; one whose own deadline also passed resolves with its OWN
    typed DEADLINE_EXCEEDED response (own request_id, own latency)."""
    backend = StallingDeadlineLLM(stall_s=1.3)
    client, cache = _client(backend=backend)
    cache.lookup_batch(["warm 1"])  # compile outside the timing-sensitive window
    with CacheService(client, max_batch=8, max_wait_ms=2.0) as svc:
        blocker = svc.submit(CacheRequest("blocker question zzz"))
        assert backend.entered.wait(timeout=10)
        # leader first (it becomes the dedup leader), then two followers
        lead_f = svc.submit(CacheRequest("the shared doomed prompt", deadline_s=1.0))
        free_f = svc.submit(CacheRequest("the shared doomed prompt"))
        tight_f = svc.submit(CacheRequest("the shared doomed prompt", deadline_s=1.0))
        assert _wait_for(lambda: svc.scheduler_stats[1].submitted >= 4)
        backend.gate.set()
        lead = lead_f.result(timeout=15)
        free = free_f.result(timeout=15)
        tight = tight_f.result(timeout=15)
        blocker.result(timeout=15)
    # the leader's deadline passed while the backend stalled
    assert lead.status == DEADLINE_EXCEEDED and lead.text is None
    # the deadline-free follower regenerated instead of inheriting the expiry
    assert free.status == GENERATED
    assert free.text == "generated: the shared doomed prompt"
    assert free.request_id != lead.request_id
    # the tight follower had no headroom left: its OWN typed expiry, own rid
    assert tight.status == DEADLINE_EXCEEDED
    assert tight.request_id not in (lead.request_id, free.request_id)
    assert svc.stats.deduped == 2
    assert svc.stats.expired == 2  # leader mid-generation + tight follower
    assert svc.stats.generated == 2  # blocker + the follower's regeneration
    # three backend calls: blocker, the stalled dedup group, the regen retry
    assert len(backend.calls) == 3
    prompts, ddls = backend.calls[2]
    assert prompts == ("the shared doomed prompt",) and ddls is None
