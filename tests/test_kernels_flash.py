"""flash_attention + decode_attention Pallas kernels vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

CASES = [
    # B, S, H, KH, Dh, window, softcap
    (2, 256, 4, 2, 64, 0, 0.0),
    (1, 256, 8, 8, 32, 64, 0.0),
    (2, 512, 4, 1, 64, 128, 50.0),  # MQA + window + softcap (gemma2 shape)
    (1, 128, 4, 4, 128, 0, 30.0),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(case, dtype):
    B, S, H, KH, Dh, window, cap = case
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, Dh), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KH, Dh), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KH, Dh), dtype)
    o1 = flash_attention(q, k, v, window=window, softcap=cap, block_q=64, block_k=64)
    o2 = flash_attention_ref(q, k, v, window=window, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (128, 64)])
def test_flash_block_shape_invariance(blocks):
    bq, bk = blocks
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 32))
    o1 = flash_attention(q, k, v, block_q=bq, block_k=bk)
    o2 = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)


DECODE_CASES = [
    (2, 512, 4, 2, 64, 0, 0.0),
    (3, 1024, 8, 8, 32, 256, 0.0),
    (2, 512, 4, 1, 64, 128, 50.0),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_matches_ref(case, dtype):
    B, S, H, KH, Dh, window, cap = case
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, Dh), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KH, Dh), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KH, Dh), dtype)
    lengths = jnp.array([max(1, S // (i + 2)) for i in range(B)])
    o1 = decode_attention(q, k, v, lengths, window=window, softcap=cap, block_s=128)
    o2 = decode_attention_ref(q, k, v, lengths, window=window, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([128, 256]),
    h=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    window=st.sampled_from([0, 32, 100]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_flash_softmax_rows_normalized(s, h, g, window, seed):
    """Property: flash output lies in the convex hull of V rows (softmax
    weights sum to 1) — max |o| <= max |v|."""
    kh = h // g
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, s, h, 32))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, s, kh, 32))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, s, kh, 32))
    o = flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    assert float(jnp.max(jnp.abs(o))) <= float(jnp.max(jnp.abs(v))) + 1e-4


def test_decode_matches_flash_last_row():
    """Decode over a filled cache == last row of prefill flash attention."""
    B, S, H, KH, Dh = 2, 256, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KH, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KH, Dh))
    full = flash_attention(q, k, v, block_q=64, block_k=64)
    dec = decode_attention(q[:, -1], k, v, jnp.array([S, S]), block_s=64)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec), atol=2e-5, rtol=2e-5)
