"""Resilience subsystem: breaker FSM, deadline-aware retry, typed failover
errors, seeded fault injection, and the serve-stale degradation ladder.

Everything here is deterministic: breakers run on an injectable fake
clock, retry jitter is pinned by seeded draws, and every chaos fixture
goes through a ``FaultInjector`` with a fixed seed — the same schedule
produces the same faults on every run.
"""
import json
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")  # the cache under the service is jax-backed

from repro.core import (  # noqa: E402
    EnhancedClient,
    GenerativeCache,
    MockLLM,
    NgramHashEmbedder,
)
from repro.core.client import LLMResponse  # noqa: E402
from repro.core.request import GENERATED, STALE, CacheRequest  # noqa: E402
from repro.gateway.errors import map_exception  # noqa: E402
from repro.resilience import (  # noqa: E402
    CLOSED,
    HALF_OPEN,
    OPEN,
    AllBackendsFailed,
    BackendFailure,
    CircuitBreaker,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    RetryBudget,
    RetryPolicy,
)
from repro.serving.service import CacheService  # noqa: E402


class Clock:
    """Injectable monotonic clock for breaker tests — no sleeping."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class FlakyBackend:
    """Minimal LLMBackend: fails on demand, counts real calls."""

    supports_deadlines = False

    def __init__(self, name="flaky", fail=True):
        self.name = name
        self.fail = fail
        self.calls = 0

    def generate(self, prompt, max_tokens=256, temperature=0.0):
        return self.generate_batch([prompt], max_tokens, temperature)[0]

    def generate_batch(self, prompts, max_tokens=256, temperature=0.0):
        self.calls += 1
        if self.fail:
            raise ConnectionError(f"{self.name} unreachable")
        return [
            LLMResponse(f"[{self.name}] answer to: {p}", self.name,
                        tokens_in=1, tokens_out=1)
            for p in prompts
        ]


FAST_RETRY = RetryPolicy(max_attempts=2, base_backoff_s=0.0, jitter=0.0)


# -- circuit breaker FSM --------------------------------------------------------


def test_breaker_trip_open_halfopen_close():
    clk = Clock()
    br = CircuitBreaker("b", failure_threshold=3, recovery_s=5.0, time_fn=clk)
    assert br.state == CLOSED and br.allow()
    assert not br.record_failure()
    assert not br.record_failure()
    assert br.record_failure()  # third consecutive failure trips
    assert br.state == OPEN
    assert not br.allow() and not br.allow()  # fast-fail: no call burned
    assert br.snapshot()["open_skips"] == 2
    clk.t = 4.99
    assert not br.allow()  # recovery window not elapsed yet
    clk.t = 5.0
    assert br.allow()  # admitted as THE half-open probe
    assert br.state == HALF_OPEN
    assert not br.allow()  # probe budget is 1
    br.record_success()
    assert br.state == CLOSED and br.allow()
    snap = br.snapshot()
    assert snap["trips"] == 1 and snap["consecutive_failures"] == 0


def test_breaker_halfopen_failure_reopens_with_fresh_timer():
    clk = Clock()
    br = CircuitBreaker("b", failure_threshold=1, recovery_s=1.0, time_fn=clk)
    assert br.record_failure()
    clk.t = 1.0
    assert br.allow()  # the probe
    assert br.record_failure()  # failed probe -> OPEN again, a second trip
    assert br.state == OPEN
    clk.t = 1.9
    assert not br.allow()  # timer restarted at the SECOND trip
    clk.t = 2.0
    assert br.allow()
    br.record_success()
    assert br.state == CLOSED
    assert br.snapshot()["trips"] == 2


def test_breaker_health_score_trips_flapper():
    # 2 fail / 1 success repeating never reaches 3 consecutive failures,
    # but the EMA health score sinks below the floor and trips anyway —
    # the mode a consecutive-only breaker cannot catch
    br = CircuitBreaker("b", failure_threshold=3, health_alpha=0.4,
                        health_floor=0.45)
    tripped = False
    for _ in range(20):
        if br.record_failure() or br.record_failure():
            tripped = True
            break
        br.record_success()
    assert tripped
    assert br.snapshot()["consecutive_failures"] < 3  # not the consecutive rule


# -- retry policy + budget ------------------------------------------------------


def test_backoff_deterministic_and_capped():
    pol = RetryPolicy(max_attempts=4, base_backoff_s=0.1, max_backoff_s=0.3,
                      multiplier=2.0, jitter=0.5)
    assert pol.backoff_s(1, draw=0.5) == pytest.approx(0.1)  # midpoint: no jitter
    assert pol.backoff_s(2, draw=0.5) == pytest.approx(0.2)
    assert pol.backoff_s(3, draw=0.5) == pytest.approx(0.3)  # capped
    assert pol.backoff_s(4, draw=0.5) == pytest.approx(0.3)
    assert pol.backoff_s(1, draw=0.0) == pytest.approx(0.05)  # -jitter edge
    assert pol.backoff_s(1, draw=1.0) == pytest.approx(0.15)  # +jitter edge


def test_retry_budget_token_bucket():
    b = RetryBudget(capacity=2.0, ratio=0.5)
    assert b.try_spend() and b.try_spend()
    assert not b.try_spend()  # dry
    b.deposit(2)  # two first attempts credit 2 * 0.5 = 1 token
    assert b.try_spend()
    assert not b.try_spend()
    snap = b.snapshot()
    assert snap["spent"] == 3 and snap["refused"] == 2


# -- client failover ------------------------------------------------------------


def test_all_backends_failed_is_typed_with_causes():
    b1, b2 = FlakyBackend("m1"), FlakyBackend("m2")
    client = EnhancedClient(retry_policy=FAST_RETRY)
    client.register_backend(b1)
    client.register_backend(b2)
    with pytest.raises(AllBackendsFailed) as ei:
        client._generate_batch_with_failover(None, ["q"], 64, 0.0)
    err = ei.value
    assert isinstance(err, ConnectionError)  # legacy except clauses still catch
    assert [c.backend for c in err.causes] == ["m1", "m2"]
    assert all(c.attempts == 2 for c in err.causes)
    assert err.causes[0].kinds == ["ConnectionError", "ConnectionError"]
    assert err.to_dict()["causes"][1]["backend"] == "m2"
    assert client.stats.all_backends_failed == 1
    assert client.stats.llm_errors == 4  # 2 backends x 2 attempts
    assert client.stats.retries == 2


def test_breaker_skips_dead_backend_then_probes_it_back():
    clk = Clock()
    b1, b2 = FlakyBackend("m1"), FlakyBackend("m2", fail=False)
    client = EnhancedClient(
        retry_policy=RetryPolicy(max_attempts=1),
        breaker_factory=lambda name: CircuitBreaker(
            name, failure_threshold=1, recovery_s=60.0, time_fn=clk
        ),
    )
    client.register_backend(b1)
    client.register_backend(b2)
    r1 = client._generate_batch_with_failover(None, ["q1"], 64, 0.0)
    assert r1[0].model == "m2" and b1.calls == 1
    assert client.breakers["m1"].state == OPEN
    assert client.stats.breaker_trips == 1
    r2 = client._generate_batch_with_failover(None, ["q2"], 64, 0.0)
    assert r2[0].model == "m2"
    assert b1.calls == 1  # open breaker: skipped without a call
    assert client.stats.breaker_open_skips == 1
    clk.t = 61.0  # recovery elapsed; next walk probes m1 (now healthy)
    b1.fail = False
    r3 = client._generate_batch_with_failover(None, ["q3"], 64, 0.0)
    assert r3[0].model == "m1"
    assert client.breakers["m1"].state == CLOSED
    assert client.breaker_snapshot()["m1"]["trips"] == 1


def test_deadline_expiry_is_not_a_backend_failure():
    b = FlakyBackend("dead")
    client = EnhancedClient(retry_policy=FAST_RETRY)
    client.register_backend(b)
    past = time.perf_counter() - 0.01
    rows = client._generate_batch_with_failover(None, ["q"], 64, 0.0,
                                                deadlines=[past])
    assert rows[0].expired
    assert b.calls == 0  # expiry burns no backend call...
    assert client.stats.llm_errors == 0  # ...and is not an error
    assert client.stats.all_backends_failed == 0


def test_no_retry_without_deadline_headroom():
    b = FlakyBackend("dead")
    client = EnhancedClient(
        retry_policy=RetryPolicy(max_attempts=5, base_backoff_s=10.0, jitter=0.0)
    )
    client.register_backend(b)
    deadline = time.perf_counter() + 0.5  # the 10 s backoff would sail past it
    t0 = time.perf_counter()
    with pytest.raises(AllBackendsFailed) as ei:
        client._generate_batch_with_failover(None, ["q"], 64, 0.0,
                                             deadlines=[deadline])
    assert time.perf_counter() - t0 < 0.4  # never slept the backoff
    assert b.calls == 1 and ei.value.causes[0].attempts == 1
    assert client.stats.retries == 0


def test_retry_budget_exhaustion_stops_retries():
    b = FlakyBackend("dead")
    budget = RetryBudget(capacity=1.0, ratio=0.0)
    client = EnhancedClient(
        retry_policy=RetryPolicy(max_attempts=10, base_backoff_s=0.0, jitter=0.0),
        retry_budget=budget,
    )
    client.register_backend(b)
    with pytest.raises(AllBackendsFailed):
        client._generate_batch_with_failover(None, ["q"], 64, 0.0)
    assert b.calls == 2  # first attempt + the single budgeted retry
    snap = budget.snapshot()
    assert snap["spent"] == 1 and snap["refused"] == 1


# -- fault injector -------------------------------------------------------------


def test_fault_injector_deterministic_across_runs():
    def run():
        inj = FaultInjector(seed=7)
        inj.schedule("b", FaultSpec("error", p=0.5))
        return [inj.plan("b")[1] is not None for _ in range(64)]

    a, b = run(), run()
    assert a == b
    assert any(a) and not all(a)  # p=0.5 actually branches both ways


def test_flap_schedule_phases_down_first():
    inj = FaultInjector(seed=0)
    inj.schedule("b", FaultSpec("flap", period=3))
    got = []
    for _ in range(12):
        _, spec = inj.plan("b")
        got.append(spec.kind if spec else None)
    assert got == ["flap"] * 3 + [None] * 3 + ["flap"] * 3 + [None] * 3


def test_faulty_backend_window_and_counters():
    inj = FaultInjector(seed=0)
    fb = inj.wrap_backend(MockLLM("m"))
    inj.schedule("m", FaultSpec("error", start=1, stop=3))
    assert fb.generate_batch(["a"])[0].text  # call 0: before the window
    for _ in range(2):  # calls 1-2: inside it
        with pytest.raises(InjectedFault):
            fb.generate_batch(["a"])
    assert fb.generate_batch(["a"])[0].text  # call 3: past the window
    snap = inj.snapshot()
    assert snap["calls"]["m"] == 4
    assert snap["injected"] == {"m:error": 2}


def test_hang_blocks_until_deadline_then_raises_typed():
    inj = FaultInjector(seed=0)
    fb = inj.wrap_backend(MockLLM("m"))
    inj.schedule("m", FaultSpec("hang", hang_s=5.0))
    deadline = time.perf_counter() + 0.05
    t0 = time.perf_counter()
    with pytest.raises(InjectedFault) as ei:
        fb.generate_batch(["a"], deadlines=[deadline])
    dt = time.perf_counter() - t0
    assert 0.04 <= dt < 1.0  # slept to the deadline, NOT the 5 s hang_s
    assert ei.value.kind == "hang"


# -- serve-stale ladder (service level) -----------------------------------------


def _stale_stack():
    cache = GenerativeCache(NgramHashEmbedder(), threshold=0.8, capacity=64,
                            cache_synthesized=False)
    client = EnhancedClient(cache=cache, retry_policy=FAST_RETRY)
    backend = FlakyBackend("origin", fail=False)
    client.register_backend(backend)
    service = CacheService(client, max_batch=4, max_wait_ms=1.0)
    return service, client, cache, backend


def test_serve_stale_byte_parity_then_refusals():
    service, client, _, backend = _stale_stack()
    try:
        r0 = service.submit(
            CacheRequest("alpha question about pandas", ttl_s=0.05)
        ).result(timeout=30)
        assert r0.status == GENERATED
        time.sleep(0.12)  # entry is now expired
        backend.fail = True

        # without the opt-in, the outage surfaces as the typed error
        with pytest.raises(AllBackendsFailed):
            service.submit(
                CacheRequest("alpha question about pandas")
            ).result(timeout=30)

        r1 = service.submit(
            CacheRequest("alpha question about pandas", allow_stale=True)
        ).result(timeout=30)
        assert r1.status == STALE and r1.from_cache
        assert r1.cache_status == "stale"
        assert r1.resolved_level == "stale"
        assert r1.cache_result.level.startswith("stale:")
        assert r1.text == r0.text  # byte parity with the original answer

        # a bound tighter than the entry's age refuses the stale answer
        with pytest.raises(AllBackendsFailed):
            service.submit(
                CacheRequest("alpha question about pandas", allow_stale=True,
                             max_stale_s=1e-4)
            ).result(timeout=30)

        assert service.stats.stale_served == 1
        assert service.stats.backend_unavailable == 2
        assert client.stats.all_backends_failed >= 3
    finally:
        service.close()


def test_gateway_serves_stale_header_and_maps_503():
    from repro.gateway.app import serve_in_thread
    from repro.gateway.client import GatewayClient

    service, _, _, backend = _stale_stack()
    r0 = service.submit(
        CacheRequest("beta question about llamas", ttl_s=0.05)
    ).result(timeout=30)
    time.sleep(0.12)
    backend.fail = True
    runner = serve_in_thread(service, own_service=True)
    try:
        with GatewayClient("127.0.0.1", runner.gateway.port, timeout=30.0) as gw:
            ok = gw.request(
                "POST", "/v1/completions",
                {"prompt": "beta question about llamas", "allow_stale": True},
            )
            assert ok.status == 200
            assert ok.headers.get("x-cache") == "stale"
            assert ok.text == r0.text  # byte parity over the wire

            bad = gw.request(
                "POST", "/v1/completions",
                {"prompt": "beta question about llamas"},
            )
            assert bad.status == 503
            assert bad.headers.get("retry-after")
            assert bad.json()["error"]["code"] == "backend_unavailable"

            neg = gw.request(
                "POST", "/v1/completions",
                {"prompt": "x", "max_stale_s": -1},
            )
            assert neg.status == 400
    finally:
        runner.stop()


def test_map_exception_all_backends_failed_envelope():
    exc = AllBackendsFailed([
        BackendFailure("m1", attempts=2,
                       errors=["ConnectionError('x')"] * 2,
                       kinds=["ConnectionError"] * 2),
        BackendFailure("m2", skipped=True),
    ])
    status, headers, body = map_exception(exc)
    assert status == 503
    assert ("Retry-After", "1") in headers
    err = json.loads(body)["error"]
    assert err["type"] == "service_unavailable"
    assert err["code"] == "backend_unavailable"
    assert "m1" in err["message"] and "breaker open" in err["message"]
    assert exc.skipped_backends == ["m2"]


# -- stats surfaces -------------------------------------------------------------


def test_healthz_degrades_when_every_breaker_is_open():
    from repro.gateway.app import serve_in_thread
    from repro.gateway.client import GatewayClient

    service, client, _, backend = _stale_stack()
    backend.fail = True
    runner = serve_in_thread(service, own_service=True)
    try:
        with GatewayClient("127.0.0.1", runner.gateway.port, timeout=30.0) as gw:
            h0 = gw.request("GET", "/healthz").json()
            assert h0["status"] == "ok"
            assert h0["breakers"]["origin"]["state"] == CLOSED
            client.breakers["origin"].force_open()
            h1 = gw.request("GET", "/healthz").json()
            assert h1["status"] == "degraded"
            assert h1["breakers"]["origin"]["state"] == OPEN
            stats = gw.request("GET", "/v1/cache/stats").json()
            assert stats["breakers"]["origin"]["trips"] == 1
            assert "retry_budget" in stats
            assert "stale_served" in stats["service"]
            assert "breaker_trips" in stats["client"]
    finally:
        runner.stop()


def test_fault_injector_feeds_client_stats_deterministically():
    # wraps a real failover walk in a seeded flap schedule: the SAME seed
    # must produce the SAME retry/trip/error counters every run
    def run():
        inj = FaultInjector(seed=3)
        inner = MockLLM("flappy")
        inj.schedule("flappy", FaultSpec("flap", period=2))
        client = EnhancedClient(
            retry_policy=RetryPolicy(max_attempts=2, base_backoff_s=0.0, jitter=0.0),
            breaker_factory=lambda name: CircuitBreaker(
                name, failure_threshold=2, recovery_s=0.0
            ),
        )
        client.register_backend(inj.wrap_backend(inner))
        served = 0
        for i in range(8):
            try:
                client._generate_batch_with_failover(None, [f"q{i}"], 16, 0.0)
                served += 1
            except AllBackendsFailed:
                pass
        s = client.stats
        return (served, s.llm_errors, s.retries, s.breaker_trips,
                inj.snapshot()["injected"])

    assert run() == run()
