"""Batch parity: the batched pipeline (embed_batch / lookup_batch /
search_batch / sharded lookup / complete_batch / coalescer) must return
results identical to N sequential single-query calls on the same snapshot,
for both the jnp and use_pallas=True (interpret) search paths."""
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.configs.contriever import smoke as contriever_smoke
from repro.core import (
    EnhancedClient,
    GenerativeCache,
    InMemoryVectorStore,
    MockLLM,
    NgramHashEmbedder,
    SemanticCache,
    ThresholdPolicy,
)
from repro.core.adaptive import ModelCostInfo
from repro.core.embeddings import ContrieverEncoder
from repro.serving.coalescer import BatchCoalescer

QUERIES = [
    "What is an application-level denial of service attack?",
    "How do I defend against denial of service attacks?",
    "What is the best recipe for chocolate cake?",
    "Explain how transformers work",
    "what is an application level denial of service attack",
    "How does the attention mechanism work in transformers?",
]


def _fill(store_kwargs, n=40, dim=32, seed=0):
    rng = np.random.default_rng(seed)
    store = InMemoryVectorStore(dim, **store_kwargs)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    for i, v in enumerate(vecs):
        store.add(v, f"q{i}", f"a{i}")
    return store, vecs


@pytest.mark.parametrize("use_pallas", [False, True])
def test_search_batch_matches_search(use_pallas):
    store, vecs = _fill({"capacity": 64, "use_pallas": use_pallas})
    rng = np.random.default_rng(1)
    probes = np.concatenate([vecs[:4], rng.normal(size=(4, 32)).astype(np.float32)])
    batch = store.search_batch(probes, k=4)
    for q, row in zip(probes, batch):
        seq = store.search(q, k=4)
        assert [e.key for _, e in row] == [e.key for _, e in seq]
        np.testing.assert_allclose(
            [s for s, _ in row], [s for s, _ in seq], atol=1e-6
        )


def _two_caches(factory):
    emb = NgramHashEmbedder()
    a, b = factory(emb), factory(emb)
    pairs = [(QUERIES[0], "A0"), (QUERIES[2], "A2"), (QUERIES[3], "A3")]
    for q, ans in pairs:
        v = emb.embed_one(q)
        a.insert(q, ans, vec=v)
        b.insert(q, ans, vec=v)
    return a, b


def _assert_result_parity(rb, rs):
    assert rb.hit == rs.hit
    assert rb.generative == rs.generative
    assert rb.response == rs.response
    assert rb.similarity == pytest.approx(rs.similarity, abs=1e-6)
    assert rb.combined_similarity == pytest.approx(rs.combined_similarity, abs=1e-6)
    assert rb.threshold_used == pytest.approx(rs.threshold_used, abs=1e-9)
    assert [e.key for _, e in rb.sources] == [e.key for _, e in rs.sources]


def test_semantic_lookup_batch_parity():
    batched, seq = _two_caches(lambda e: SemanticCache(e, threshold=0.7))
    for rb, q in zip(batched.lookup_batch(QUERIES), QUERIES):
        _assert_result_parity(rb, seq.lookup(q))
    assert batched.stats.lookups == len(QUERIES)
    assert batched.stats.hits == seq.stats.hits


@pytest.mark.parametrize("mode", ["primary", "secondary"])
def test_generative_lookup_batch_parity(mode):
    batched, seq = _two_caches(
        lambda e: GenerativeCache(e, threshold=0.85, t_single=0.4, t_combined=1.0,
                                  mode=mode, cache_synthesized=False)
    )
    for rb, q in zip(batched.lookup_batch(QUERIES), QUERIES):
        _assert_result_parity(rb, seq.lookup(q))


def test_lookup_batch_vectorized_thresholds_parity():
    policy = ThresholdPolicy(base=0.75)
    batched, seq = _two_caches(
        lambda e: SemanticCache(e, threshold=0.75, policy=policy)
    )
    contexts = [
        {"model_info": ModelCostInfo(60.0, 120.0, 20.0)},  # pricey -> lower t_s
        None,
        {"connectivity": 0.2},  # offline-ish -> lower t_s
        {"user_threshold_offset": 0.1},
        None,
        {"max_tokens": 64, "model_info": ModelCostInfo(0.5, 1.5, 3.0)},
    ]
    for rb, (q, c) in zip(batched.lookup_batch(QUERIES, contexts), zip(QUERIES, contexts)):
        _assert_result_parity(rb, seq.lookup(q, c))


def test_pallas_lookup_batch_parity():
    emb = NgramHashEmbedder()
    caches = [
        SemanticCache(emb, threshold=0.7, capacity=128, use_pallas=p)
        for p in (True, False)
    ]
    for q in QUERIES[:3]:
        v = emb.embed_one(q)
        for c in caches:
            c.insert(q, f"ans:{q[:10]}", vec=v)
    ra, rb_ = (c.lookup_batch(QUERIES) for c in caches)
    for x, y in zip(ra, rb_):
        assert x.hit == y.hit
        assert x.similarity == pytest.approx(y.similarity, abs=1e-4)


def test_sharded_search_batch_matches_single_and_inmemory():
    jax = pytest.importorskip("jax")
    from repro.distributed.sharded_store import ShardedVectorStore
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    dim, n = 16, 12
    rng = np.random.default_rng(3)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    sharded = ShardedVectorStore(mesh, dim=dim, capacity=16, k=3)
    local = InMemoryVectorStore(dim, capacity=16)
    for i, v in enumerate(vecs):
        sharded.add(v, f"q{i}", f"a{i}")
        local.add(v, f"q{i}", f"a{i}")
    probes = vecs[:5]
    batch = sharded.search_batch(probes)
    for q, row in zip(probes, batch):
        single = sharded.search_batch(q[None])[0]
        assert [(p[0]) for _, p in row] == [(p[0]) for _, p in single]
        np.testing.assert_allclose([s for s, _ in row], [s for s, _ in single], atol=1e-6)
        ref = local.search(q, k=3)
        np.testing.assert_allclose(
            [s for s, _ in row], [s for s, _ in ref], atol=1e-5
        )
        assert [p[0] for _, p in row] == [e.query for _, e in ref]
    # thresholded lookup_batch: strict > on the best candidate, else None
    hits = sharded.lookup_batch(probes, 0.99)
    assert [h[1][0] for h in hits] == [f"q{i}" for i in range(5)]  # self-hits
    assert sharded.lookup_batch(probes, 1.1) == [None] * 5
    per_query_thr = [0.99, 1.1, 0.99, 1.1, 0.99]
    mixed = sharded.lookup_batch(probes, per_query_thr)
    assert [h is None for h in mixed] == [False, True, False, True, False]


def test_sharded_add_batch_matches_sequential():
    jax = pytest.importorskip("jax")
    from repro.distributed.sharded_store import ShardedVectorStore
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    dim, n = 16, 12
    rng = np.random.default_rng(7)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    qs, rs = [f"q{i}" for i in range(n)], [f"a{i}" for i in range(n)]
    seq = ShardedVectorStore(mesh, dim=dim, capacity=8, k=3)  # wraps round-robin
    bat = ShardedVectorStore(mesh, dim=dim, capacity=8, k=3)
    idx_seq = [seq.add(v, q, r) for v, q, r in zip(vecs, qs, rs)]
    idx_bat = bat.add_batch(vecs, qs, rs)
    assert idx_seq == idx_bat
    assert seq.payloads == bat.payloads
    assert seq.size == bat.size and seq._rr == bat._rr
    np.testing.assert_allclose(np.asarray(seq._db), np.asarray(bat._db), atol=0)
    assert np.array_equal(np.asarray(seq._valid), np.asarray(bat._valid))
    probes = vecs[-3:]
    for row_s, row_b in zip(seq.search_batch(probes), bat.search_batch(probes)):
        assert [(s, p) for s, p in row_s] == [(s, p) for s, p in row_b]
    # odd-sized batches ride the power-of-two bucket padding unchanged
    extra = rng.normal(size=(3, dim)).astype(np.float32)
    assert bat.add_batch(extra, ["x0", "x1", "x2"], ["y0", "y1", "y2"]) == \
        [seq.add(v, f"x{i}", f"y{i}") for i, v in enumerate(extra)]
    assert seq.payloads == bat.payloads
    np.testing.assert_allclose(np.asarray(seq._db), np.asarray(bat._db), atol=0)


def test_embed_batch_matches_per_text_embedding():
    enc = ContrieverEncoder(contriever_smoke())
    texts = QUERIES[:3]  # batch of 3 pads to a bucket of 4
    batched = enc.embed_batch(texts)
    singles = np.stack([enc.embed_one(t) for t in texts])
    assert batched.shape == singles.shape
    np.testing.assert_allclose(batched, singles, atol=1e-5)


def test_embed_batch_empty():
    emb = NgramHashEmbedder()
    out = emb.embed_batch([])
    assert out.shape == (0, emb.dim)


def test_complete_batch_partitions_hits_and_misses():
    emb = NgramHashEmbedder()
    cache = GenerativeCache(emb, threshold=0.85, t_single=0.45, t_combined=1.0)
    client = EnhancedClient(cache=cache)
    backend = MockLLM("m1")
    client.register_backend(backend)
    prompts = QUERIES[:4]
    r1 = client.complete_batch(prompts)
    assert [r.from_cache for r in r1] == [False] * 4
    assert backend.calls == 4
    r2 = client.complete_batch(prompts)
    assert [r.from_cache for r in r2] == [True] * 4
    assert backend.calls == 4  # hits never reach the backend
    assert [r.text for r in r2] == [r.text for r in r1]
    assert client.stats.requests == 8 and client.stats.cache_hits == 4


def test_complete_batch_matches_sequential_query_decisions():
    def build():
        emb = NgramHashEmbedder()
        c = EnhancedClient(cache=GenerativeCache(
            emb, threshold=0.85, t_single=0.45, t_combined=1.0))
        c.register_backend(MockLLM("m1"))
        return c

    a, b = build(), build()
    warm = QUERIES[:3]
    a.complete_batch(warm)
    for q in warm:
        b.query(q)
    probes = [QUERIES[0], QUERIES[4], "completely unrelated gardening question"]
    ra = a.complete_batch(probes)
    rb = [b.query(q) for q in probes]
    assert [r.from_cache for r in ra] == [r.from_cache for r in rb]
    assert [r.text for r in ra] == [r.text for r in rb]


def test_complete_batch_failover():
    from repro.resilience import RetryPolicy

    emb = NgramHashEmbedder()
    client = EnhancedClient(
        cache=SemanticCache(emb, threshold=0.9),
        retry_policy=RetryPolicy(max_attempts=2, base_backoff_s=0.0, jitter=0.0),
    )
    client.register_backend(MockLLM("dead", fail=True))
    client.register_backend(MockLLM("alive"))
    rs = client.complete_batch(["hello", "world"])
    assert [r.model for r in rs] == ["alive", "alive"]
    # errors are counted per failover ATTEMPT on the batch, never per prompt:
    # 2 attempts against the dead backend, regardless of batch width
    assert client.stats.llm_errors == 2
    assert client.stats.retries == 1


def test_coalescer_batches_concurrent_requests():
    calls = []

    def handler(items):
        calls.append(len(items))
        return [x * 2 for x in items]

    with BatchCoalescer(handler, max_batch=8, max_wait_ms=50.0) as co:
        with ThreadPoolExecutor(max_workers=16) as pool:
            outs = list(pool.map(co, range(32)))
    assert outs == [x * 2 for x in range(32)]
    assert co.stats.batches == len(calls)
    assert co.stats.batched_items == 32
    assert max(calls) > 1  # concurrency actually coalesced


def test_coalescer_propagates_handler_errors():
    def handler(items):
        raise ValueError("boom")

    with BatchCoalescer(handler, max_batch=4, max_wait_ms=1.0) as co:
        fut = co.submit("x")
        with pytest.raises(ValueError, match="boom"):
            fut.result(timeout=5)


def test_coalescer_rejects_after_close():
    co = BatchCoalescer(lambda items: items, max_batch=2)
    co.close()
    with pytest.raises(RuntimeError):
        co.submit(1)


def test_coalescer_single_request_not_stalled():
    with BatchCoalescer(lambda items: items, max_batch=64, max_wait_ms=10.0) as co:
        t0 = time.perf_counter()
        assert co("solo") == "solo"
        assert time.perf_counter() - t0 < 2.0  # released at max_wait, not never
