"""L1/L2 hierarchy (§4) and the enhanced client (§5)."""
import time

import pytest

from repro.core import (
    EnhancedClient,
    GenerativeCache,
    HierarchicalCache,
    MockLLM,
    ModelCostInfo,
    NgramHashEmbedder,
    ThresholdPolicy,
)

Q1 = "What is an application-level denial of service attack?"
Q2 = "What are the most effective techniques for defending against denial-of-service attacks?"


@pytest.fixture
def emb():
    return NgramHashEmbedder()


def _gc(emb, **kw):
    kw.setdefault("threshold", 0.85)
    kw.setdefault("t_single", 0.45)
    kw.setdefault("t_combined", 1.0)
    return GenerativeCache(emb, **kw)


def test_l2_hit_promotes_to_l1(emb):
    l1, l2 = _gc(emb, capacity=16), _gc(emb, capacity=64)
    h = HierarchicalCache(l1, l2)
    l2.insert(Q1, "A1")
    r = h.lookup(Q1)
    assert r.hit and r.level.startswith("L2")
    assert h.lookup(Q1).level.startswith("L1")  # promoted


def test_peer_l2_cooperation(emb):
    l1, l2, peer = _gc(emb), _gc(emb), _gc(emb)
    h = HierarchicalCache(l1, l2, peers=[peer])
    peer.insert(Q1, "A1")
    r = h.lookup(Q1)
    assert r.hit and "peer" in r.level


def test_privacy_hints_keep_personal_out_of_l2(emb):
    l1, l2 = _gc(emb), _gc(emb)
    h = HierarchicalCache(l1, l2)
    h.insert("What are my test results for patient id 1234?", "personal", cache_l2=False)
    assert len(l1.store) == 1
    assert len(l2.store) == 0


def test_generative_across_levels(emb):
    """Q1 cached in L1, Q2 in L2 -> combined generative hit pools both."""
    l1, l2 = _gc(emb), _gc(emb)
    h = HierarchicalCache(l1, l2)
    l1.insert(Q1, "A1")
    l2.insert(Q2, "A2")
    q3 = ("What is an application-level denial of service attack, and what are the "
          "most effective techniques for defending against such attacks?")
    r = h.lookup(q3)
    assert r.hit and r.generative and "multi-level" in r.level
    assert "A1" in r.response and "A2" in r.response


def test_client_cache_roundtrip(emb):
    client = EnhancedClient(cache=_gc(emb))
    client.register_backend(MockLLM("m1"))
    r1 = client.query(Q1)
    assert not r1.from_cache
    r2 = client.query(Q1)
    assert r2.from_cache and r2.cost_usd == 0.0
    assert client.stats.cache_hits == 1 and client.stats.llm_calls == 1


def test_client_force_fresh_adds_second_response(emb):
    cache = _gc(emb)
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("m1", responder=lambda p: f"r{time.perf_counter_ns()}"))
    client.query(Q1)
    r = client.query(Q1, force_fresh=True)  # §5.2: user explicitly wants a new response
    assert not r.from_cache
    assert len(cache.store) == 2  # both responses cached for the same query


def test_client_failover(emb):
    from repro.resilience import RetryPolicy

    client = EnhancedClient(
        cache=_gc(emb),
        retry_policy=RetryPolicy(max_attempts=2, base_backoff_s=0.0, jitter=0.0),
    )
    client.register_backend(MockLLM("dead", fail=True))
    client.register_backend(MockLLM("alive"))
    r = client.query("hello there")
    assert r.model == "alive"
    assert client.stats.llm_errors == 2  # both attempts against the dead backend
    assert client.stats.retries == 1


def test_client_parallel_dispatch(emb):
    client = EnhancedClient(cache=None)
    client.register_backend(MockLLM("slow", latency_s=0.05))
    prompts = [f"question {i}" for i in range(8)]
    t0 = time.perf_counter()
    rs = client.query_many(prompts, use_cache=False)
    elapsed = time.perf_counter() - t0
    assert len(rs) == 8
    assert elapsed < 8 * 0.05  # parallel speedup (paper §5.2)


def test_client_broadcast_multiple_llms(emb):
    client = EnhancedClient(cache=None)
    client.register_backend(MockLLM("m1"))
    client.register_backend(MockLLM("m2"))
    out = client.broadcast("same question")
    assert set(out) == {"m1", "m2"}


def test_model_escalation_on_dissatisfaction(emb):
    client = EnhancedClient(cache=None)
    client.register_backend(MockLLM("cheap"), ModelCostInfo(0.5, 1.5, 1))
    client.register_backend(MockLLM("pricey"), ModelCostInfo(60, 120, 10))
    r = client.query("q1", use_cache=False)
    assert r.model == "cheap"
    client.feedback(r, satisfied=False)
    r2 = client.query("q2", use_cache=False)
    assert r2.model == "pricey"
    client.feedback(r2, satisfied=True)
    assert client.query("q3", use_cache=False).model == "cheap"


def test_cost_accounting(emb):
    client = EnhancedClient(cache=_gc(emb))
    client.register_backend(MockLLM("m"), ModelCostInfo(1.0, 2.0, 1))
    r = client.query("a question with some words")
    assert r.cost_usd > 0
    assert client.stats.total_cost_usd == pytest.approx(r.cost_usd)


def test_max_tokens_limits_response(emb):
    client = EnhancedClient(cache=None)
    client.register_backend(MockLLM("m", responder=lambda p: "word " * 100))
    r = client.query("q", max_tokens=5, use_cache=False)
    assert len(r.text.split()) <= 5
