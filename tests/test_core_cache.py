"""Core cache behavior: semantic cache, generative caching (§3), eviction,
persistence, GPTCache-like baseline parity."""
import numpy as np
import pytest

from repro.core import (
    GPTCacheLike,
    GenerativeCache,
    InMemoryVectorStore,
    NgramHashEmbedder,
    SemanticCache,
)

Q1 = "What is an application-level denial of service attack?"
Q2 = "What are the most effective techniques for defending against denial-of-service attacks?"
Q3 = (
    "What is an application-level denial of service attack, and what are the most "
    "effective techniques for defending against such attacks?"
)


@pytest.fixture
def emb():
    return NgramHashEmbedder()


def test_exact_match_hits(emb):
    c = SemanticCache(emb, threshold=0.9)
    c.insert(Q1, "A1")
    r = c.lookup(Q1)
    assert r.hit and r.response == "A1" and r.similarity > 0.999


def test_paraphrase_hits_unrelated_misses(emb):
    c = SemanticCache(emb, threshold=0.7)
    c.insert(Q1, "A1")
    assert c.lookup("Please explain what an application-level denial of service attack is.").hit
    assert not c.lookup("What is the best recipe for chocolate cake?").hit


def test_generative_q1_q2_q3(emb):
    """The paper's §3 worked example: Q3 synthesized from Q1 + Q2."""
    c = GenerativeCache(emb, threshold=0.9, t_single=0.45, t_combined=1.0)
    c.insert(Q1, "A1: an app-level DoS attack explanation")
    c.insert(Q2, "A2: defenses against DoS")
    r = c.lookup(Q3)
    assert r.hit and r.generative
    assert r.combined_similarity > 1.0
    assert len(r.sources) == 2
    assert "A1" in r.response and "A2" in r.response
    # synthesized answer was cached: a Q3 paraphrase now hits
    r2 = c.lookup(
        "What is an application level denial of service attack and what are "
        "effective techniques for defending against those attacks?"
    )
    assert r2.hit


def test_generative_thresholds_order(emb):
    c = GenerativeCache(emb, threshold=0.8, t_single=0.6, t_combined=1.4)
    assert c.t_single < c.threshold < c.t_combined


def test_generative_primary_vs_secondary(emb):
    for mode in ("primary", "secondary"):
        c = GenerativeCache(emb, threshold=0.9, t_single=0.45, t_combined=1.0, mode=mode)
        c.insert(Q1, "A1")
        c.insert(Q2, "A2")
        assert c.lookup(Q3).hit, mode


def test_generative_miss_below_combined(emb):
    c = GenerativeCache(emb, threshold=0.9, t_single=0.45, t_combined=10.0)
    c.insert(Q1, "A1")
    c.insert(Q2, "A2")
    assert not c.lookup(Q3).hit


def test_eviction_lru(emb):
    store = InMemoryVectorStore(emb.dim, capacity=2, eviction="lru")
    c = SemanticCache(emb, threshold=0.95, store=store)
    c.insert("query one about topic alpha", "A")
    c.insert("query two about topic beta", "B")
    c.lookup("query one about topic alpha")  # touch A
    c.insert("query three about topic gamma", "C")  # evicts B (LRU)
    assert c.lookup("query one about topic alpha").hit
    assert not c.lookup("query two about topic beta").hit


def test_eviction_fifo(emb):
    store = InMemoryVectorStore(emb.dim, capacity=2, eviction="fifo")
    c = SemanticCache(emb, threshold=0.95, store=store)
    c.insert("first question about dogs", "A")
    c.insert("second question about cats", "B")
    c.insert("third question about fish", "C")
    assert not c.lookup("first question about dogs").hit
    assert c.lookup("second question about cats").hit


def test_persistence_roundtrip(tmp_path, emb):
    c = SemanticCache(emb, threshold=0.9)
    c.insert(Q1, "A1")
    c.insert(Q2, "A2")
    c.save(str(tmp_path / "cache"))
    c2 = SemanticCache(emb, threshold=0.9)
    c2.load_store(str(tmp_path / "cache"))
    assert c2.lookup(Q1).hit
    assert c2.lookup(Q2).response == "A2"


def test_load_store_preserves_flags_and_class(tmp_path, emb):
    """A save/load cycle must not silently rebuild the store with default
    constructor flags: use_pallas (and any store subclass) survive."""
    c = SemanticCache(emb, threshold=0.9, use_pallas=True, capacity=64)
    c.insert(Q1, "A1")
    c.save(str(tmp_path / "pallas"))
    c.load_store(str(tmp_path / "pallas"))
    assert c.store.use_pallas
    assert c.store.capacity == 64
    assert c.lookup(Q1).hit

    class TracingStore(InMemoryVectorStore):
        pass

    c2 = SemanticCache(emb, threshold=0.9, store=TracingStore(emb.dim, 32))
    c2.insert(Q2, "A2")
    c2.save(str(tmp_path / "custom"))
    c2.load_store(str(tmp_path / "custom"))
    assert type(c2.store) is TracingStore
    assert c2.lookup(Q2).response == "A2"


def test_insert_batch_matches_sequential_inserts(emb):
    a, b = SemanticCache(emb, threshold=0.9), SemanticCache(emb, threshold=0.9)
    pairs = [(Q1, "A1"), (Q2, "A2"), (Q3, "A3")]
    for q, ans in pairs:
        a.insert(q, ans)
    keys = b.insert_batch([q for q, _ in pairs], [ans for _, ans in pairs])
    assert len(keys) == 3 and b.stats.adds == 3
    for q, ans in pairs:
        assert a.lookup(q).response == b.lookup(q).response == ans


def test_warm_start(emb):
    c = SemanticCache(emb, threshold=0.9)
    c.warm_start([(Q1, "A1"), (Q2, "A2")])
    assert c.lookup(Q1).hit and c.lookup(Q2).hit


def test_gptcache_like_same_decisions(emb):
    ours = SemanticCache(emb, threshold=0.8)
    baseline = GPTCacheLike(emb, threshold=0.8)
    pairs = [(Q1, "A1"), (Q2, "A2"), ("how do transformers work", "A3")]
    for q, a in pairs:
        v = emb.embed_one(q)
        ours.insert(q, a, vec=v)
        baseline.insert(q, a, vec=v)
    for probe in [Q1, "explain transformers", "recipe for pancakes"]:
        v = emb.embed_one(probe)
        r1, r2 = ours.lookup(probe, vec=v), baseline.lookup(probe, vec=v)
        assert r1.hit == r2.hit
        assert abs(r1.similarity - r2.similarity) < 1e-4


def test_pallas_backed_store_matches_jnp(emb):
    a = SemanticCache(emb, threshold=0.8, use_pallas=True, capacity=512)
    b = SemanticCache(emb, threshold=0.8, use_pallas=False, capacity=512)
    for i in range(20):
        q = f"question number {i} about subject {i % 5}"
        v = emb.embed_one(q)
        a.insert(q, f"A{i}", vec=v)
        b.insert(q, f"A{i}", vec=v)
    for probe in ["question number 3 about subject 3", "unrelated cooking query"]:
        v = emb.embed_one(probe)
        ra, rb = a.lookup(probe, vec=v), b.lookup(probe, vec=v)
        assert ra.hit == rb.hit
        assert abs(ra.similarity - rb.similarity) < 1e-4


def test_remove_entry(emb):
    c = SemanticCache(emb, threshold=0.9)
    key = c.insert(Q1, "A1")
    assert c.lookup(Q1).hit
    assert c.store.remove(key)
    assert not c.lookup(Q1).hit
