"""Embedding models, tokenizer determinism, similarity metrics (+ hypothesis
properties on the similarity invariants the cache relies on)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, st

from repro.core import similarity as sim
from repro.core.embeddings import ContrieverEncoder, NgramHashEmbedder, get_embedder
from repro.core.tokenizer import HashTokenizer
from repro.configs.contriever import smoke as contriever_smoke


def test_tokenizer_deterministic_across_instances():
    a, b = HashTokenizer(), HashTokenizer()
    s = "What is an application-level denial of service attack?"
    assert a.encode(s) == b.encode(s)


def test_tokenizer_batch_padding():
    tok = HashTokenizer()
    ids, mask = tok.encode_batch(["short", "a much longer sentence with many words"])
    assert ids.shape == mask.shape
    assert mask[0].sum() < mask[1].sum()


def test_ngram_embedder_overlap_sensitivity():
    emb = NgramHashEmbedder()
    q = "What is an application-level denial of service attack?"
    para = "Please explain what an application-level denial of service attack is."
    other = "What is the best recipe for chocolate cake?"
    v = emb.embed([q, para, other])
    s_para = float(v[0] @ v[1])
    s_other = float(v[0] @ v[2])
    assert s_para > 0.6 > s_other


def test_ngram_embedder_unit_norm():
    emb = NgramHashEmbedder()
    v = emb.embed(["a", "some longer text here", ""])
    norms = np.linalg.norm(v, axis=1)
    assert np.all(norms < 1.0 + 1e-5)


def test_contriever_encoder_shapes_and_determinism():
    enc = ContrieverEncoder(contriever_smoke())
    v1 = enc.embed(["hello world", "another sentence"])
    v2 = enc.embed(["hello world", "another sentence"])
    assert v1.shape == (2, enc.dim)
    np.testing.assert_allclose(v1, v2, atol=1e-6)
    np.testing.assert_allclose(np.linalg.norm(v1, axis=1), 1.0, atol=1e-5)


def test_registry_unknown_raises():
    with pytest.raises(KeyError):
        get_embedder("nonexistent-model")


@pytest.mark.parametrize("metric", ["cosine", "dot", "euclidean"])
def test_metric_self_similarity_maximal(metric):
    rng = np.random.default_rng(0)
    db = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    s = sim.scores(db, db[5][None], metric)
    assert int(jnp.argmax(s[0])) == 5


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_cosine_bounded(seed):
    rng = np.random.default_rng(seed)
    db = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
    s = np.asarray(sim.scores(db, q, "cosine"))
    assert np.all(s <= 1.0 + 1e-5) and np.all(s >= -1.0 - 1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 8))
def test_property_topk_sorted_and_valid(seed, k):
    rng = np.random.default_rng(seed)
    db = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    valid = jnp.asarray(rng.random(32) > 0.3)
    q = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    s, idx = sim.top_k_scores(db, valid, q, k)
    s = np.asarray(s)
    assert np.all(np.diff(s, axis=1) <= 1e-6)  # descending
    finite = np.isfinite(s)
    v = np.asarray(valid)
    assert np.all(v[np.asarray(idx)[finite]])  # finite hits only on valid rows
