"""Adaptive threshold machinery (§2, §3.1): content policy, controllers."""
import pytest

from repro.core.adaptive import (
    DEFAULT_PRICE_TABLE,
    CostController,
    ModelCostInfo,
    QualityRateController,
    ThresholdPolicy,
    classify_content,
)


def test_classify_content():
    assert classify_content("Write a python function to sort a list") == "code"
    assert classify_content("def foo(x): return x") == "code"
    assert classify_content("What is the capital of France?") == "text"
    assert classify_content("Explain the history of the Roman empire") == "text"


def test_code_gets_higher_threshold():
    p = ThresholdPolicy(base=0.8)
    t_code = p.compute("Write a python function to parse JSON")
    t_text = p.compute("Tell me about the weather in Paris")
    assert t_code > t_text


def test_expensive_model_lowers_threshold():
    """§2: gpt-4-32k requests should hit the cache more readily than 3.5."""
    p = ThresholdPolicy(base=0.8)
    cheap = p.compute("some question", {"model_info": DEFAULT_PRICE_TABLE["gpt-3.5-turbo-0125"]})
    pricey = p.compute("some question", {"model_info": DEFAULT_PRICE_TABLE["gpt-4-32k"]})
    assert pricey < cheap


def test_token_limit_scales_cost_term():
    p = ThresholdPolicy(base=0.8)
    info = DEFAULT_PRICE_TABLE["gpt-4-32k"]
    small = p.compute("q", {"model_info": info, "max_tokens": 64})
    large = p.compute("q", {"model_info": info, "max_tokens": 4096})
    assert large < small


def test_poor_connectivity_lowers_threshold():
    p = ThresholdPolicy(base=0.8)
    assert p.compute("q", {"connectivity": 0.0}) < p.compute("q", {"connectivity": 1.0})


def test_bounds_respected():
    p = ThresholdPolicy(base=0.95, t_max=0.98)
    assert p.compute("write code to do x " * 3) <= 0.98
    p2 = ThresholdPolicy(base=0.55, t_min=0.5)
    assert p2.compute("q", {"model_info": ModelCostInfo(100, 200, 60), "connectivity": 0.0}) >= 0.5


def test_quality_controller_raises_on_low_quality():
    p = ThresholdPolicy(base=0.8)
    ctl = QualityRateController(p, target=0.8, band=0.05, step=0.02, min_samples=5)
    for _ in range(10):
        ctl.record(False)  # all low-quality hits
    assert p.base > 0.8


def test_quality_controller_lowers_on_high_quality():
    p = ThresholdPolicy(base=0.8)
    ctl = QualityRateController(p, target=0.8, band=0.05, step=0.02, min_samples=5)
    for _ in range(10):
        ctl.record(True)
    assert p.base < 0.8


def test_quality_controller_converges_to_target():
    """Servo convergence: simulated user whose satisfaction rises with t_s."""
    import random

    rnd = random.Random(0)
    p = ThresholdPolicy(base=0.6)
    ctl = QualityRateController(p, target=0.8, band=0.03, step=0.01, window=40)
    for _ in range(400):
        p_high = min(1.0, max(0.0, (p.base - 0.4) / 0.45))  # quality grows with t_s
        ctl.record(rnd.random() < p_high)
    assert 0.65 < abs(ctl.quality_rate) <= 1.0
    assert 0.7 < p.base < 0.9  # settled near where p_high ~ 0.8


def test_cost_controller_targets_hit_rate():
    p = ThresholdPolicy(base=0.9)
    ctl = CostController(p, target_cost_per_request=0.25, step=0.02, min_samples=4)
    # LLM calls cost 1.0 -> target hit rate 0.75; observed 0 hits -> lower t_s
    for _ in range(10):
        ctl.record(1.0, was_hit=False)
    assert abs(ctl.target_hit_rate - 0.75) < 1e-9
    assert p.base < 0.9


def test_cost_controller_backs_off_when_over_hitting():
    p = ThresholdPolicy(base=0.7)
    ctl = CostController(p, target_cost_per_request=0.9, step=0.02, min_samples=4)
    ctl.record(1.0, was_hit=False)
    for _ in range(20):
        ctl.record(0.0, was_hit=True)  # hit rate ~1 >> target 0.1
    assert p.base > 0.7
