"""Batched hierarchy (§4): lookup_batch decision parity with the sequential
walk, batched promotion/writeback placement, the insert privacy matrix
(promote x inclusive x privacy hints over L1 + L2 + peers), the cross-level
generative pool ordering/cap fixes, and the client's batched hierarchy path."""
import numpy as np
import pytest

from repro.core import (
    EnhancedClient,
    GenerativeCache,
    HierarchicalCache,
    MockLLM,
    NgramHashEmbedder,
)

Q1 = "What is an application-level denial of service attack?"
Q2 = "What are the most effective techniques for defending against denial-of-service attacks?"
Q3 = ("What is an application-level denial of service attack, and what are the "
      "most effective techniques for defending against such attacks?")
QA = "How does the attention mechanism work in transformers?"
QB = "What is the best recipe for chocolate cake?"


@pytest.fixture
def emb():
    return NgramHashEmbedder()


def _gc(emb, **kw):
    kw.setdefault("threshold", 0.85)
    kw.setdefault("t_single", 0.45)
    kw.setdefault("t_combined", 1.0)
    return GenerativeCache(emb, **kw)


def _fresh_hier(emb, **kw) -> HierarchicalCache:
    """L1 holds QA, L2 holds Q1, peer0 holds Q2, peer1 holds QB."""
    l1, l2, p0, p1 = (_gc(emb) for _ in range(4))
    l1.insert(QA, "ATT")
    l2.insert(Q1, "A1")
    p0.insert(Q2, "A2")
    p1.insert(QB, "CAKE")
    return HierarchicalCache(l1, l2, peers=[p0, p1], **kw)


PROBES = [
    QA,                                   # L1 semantic hit
    Q1,                                   # L2 hit (promotes)
    Q2,                                   # peer hit (promotes)
    Q3,                                   # cross-level generative (Q1 + Q2)
    "completely unrelated gardening question",  # miss everywhere
]


def test_lookup_batch_parity_with_sequential_snapshot(emb):
    """Batched decisions must match B sequential lookups, each against a
    fresh snapshot of the same hierarchy (levels, responses, scores)."""
    batch = _fresh_hier(emb).lookup_batch(PROBES)
    for q, rb in zip(PROBES, batch):
        rs = _fresh_hier(emb).lookup(q)
        assert rb.hit == rs.hit
        assert rb.level == rs.level
        assert rb.generative == rs.generative
        assert rb.response == rs.response
        assert rb.similarity == pytest.approx(rs.similarity, abs=1e-6)
        assert rb.combined_similarity == pytest.approx(rs.combined_similarity, abs=1e-6)
        assert rb.threshold_used == pytest.approx(rs.threshold_used, abs=1e-9)
        assert [(e.query, e.response) for _, e in rb.sources] == \
               [(e.query, e.response) for _, e in rs.sources]
        np.testing.assert_allclose([s for s, _ in rb.sources],
                                   [s for s, _ in rs.sources], atol=1e-6)
    levels = [r.level for r in batch]
    assert levels[0].startswith("L1") and levels[1].startswith("L2:")
    assert "peer" in levels[2] and levels[3] == "multi-level:generative"
    assert not batch[4].hit


def test_lookup_batch_promotes_lower_level_hits(emb):
    h = _fresh_hier(emb)
    first = h.lookup_batch(PROBES)
    assert first[1].level.startswith("L2:")
    # L2/peer winners (and the synthesized answer) landed in L1 in one scatter
    again = h.lookup_batch(PROBES[:4])
    assert all(r.level.startswith("L1") for r in again)


def test_lookup_batch_no_promotion_when_disabled(emb):
    h = _fresh_hier(emb, promote=False)
    h.lookup_batch([Q1, Q2])
    assert len(h.l1.store) == 1  # only the seeded QA entry
    assert h.lookup_batch([Q1])[0].level.startswith("L2:")


def test_lookup_batch_does_not_write_levels_below_the_winner(emb):
    """Sequentially, levels below a hit are never probed — a lower level must
    not accrue synthesized entries from queries an upper level served."""
    l1, l2 = _gc(emb), _gc(emb)
    l1.insert(Q3, "DIRECT")
    l2.insert(Q1, "A1")
    l2.insert(Q2, "A2")
    h = HierarchicalCache(l1, l2)
    r = h.lookup_batch([Q3])[0]
    assert r.hit and r.level == "L1:semantic"
    assert len(l2.store) == 2  # no synthesized writeback into the shared level

    # but when L2 wins with a synthesized answer, it does cache it — and
    # in-batch duplicates synthesize (and write back) exactly once
    h2 = HierarchicalCache(_gc(emb), l2_ := _gc(emb))
    l2_.insert(Q1, "A1")
    l2_.insert(Q2, "A2")
    r2, r2dup = h2.lookup_batch([Q3, Q3])
    assert r2.hit and r2.generative and r2.level == "L2:generative"
    assert r2dup.response == r2.response
    assert len(l2_.store) == 3  # ONE synthesized answer cached in the winning level
    assert len(h2.l1.store) == 1  # and promoted into L1 once


def test_lookup_batch_dedupes_promotions_of_repeated_queries(emb):
    """A coalesced batch of identical queries must promote once, like the
    sequential walk — not flush L1 with clones of the same entry."""
    h = _fresh_hier(emb)
    rs = h.lookup_batch([Q1] * 8)
    assert all(r.hit and r.level.startswith("L2:") for r in rs)
    assert len(h.l1.store) == 2  # seeded QA + ONE promoted copy of Q1


def test_lookup_batch_empty_and_stats(emb):
    h = _fresh_hier(emb)
    assert h.lookup_batch([]) == []
    h.lookup_batch(PROBES)
    # L1 was looked up for every query; L2 only for those L1 missed
    assert h.l1.stats.lookups == len(PROBES)
    assert h.l2.stats.lookups == len(PROBES) - 1  # QA stopped at L1
    assert h.l2.stats.hits == 1  # Q1 only; hits below winning levels retracted


@pytest.mark.parametrize("promote", [True, False])
@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("cache_l1,cache_l2", [
    (True, True), (True, False), (False, True), (False, False),
])
def test_insert_privacy_matrix(emb, promote, inclusive, cache_l1, cache_l2):
    """Privacy hints always win — inclusivity must never copy an entry into a
    level the caller excluded (the §4 leak), and peers are never written."""
    l1, l2, peer = _gc(emb), _gc(emb), _gc(emb)
    h = HierarchicalCache(l1, l2, peers=[peer], inclusive=inclusive, promote=promote)
    h.insert("personal query one", "R1", cache_l1=cache_l1, cache_l2=cache_l2)
    assert len(l1.store) == (1 if cache_l1 else 0)
    assert len(l2.store) == (1 if cache_l2 else 0)
    assert len(peer.store) == 0
    h.insert_batch(["personal query two", "personal query three"], ["R2", "R3"],
                   cache_l1=cache_l1, cache_l2=cache_l2)
    assert len(l1.store) == (3 if cache_l1 else 0)
    assert len(l2.store) == (3 if cache_l2 else 0)
    assert len(peer.store) == 0


@pytest.mark.parametrize("batched", [False, True])
@pytest.mark.parametrize("inclusive", [False, True])
def test_inclusive_mirrors_peer_winners_into_l2(emb, inclusive, batched):
    """inclusive=True: a peer hit is promoted into L1 AND copied into our L2
    (it came from a shared level, so nothing private is exposed); L2 winners
    are never duplicated back into L2."""
    l1, l2, peer = _gc(emb), _gc(emb), _gc(emb)
    h = HierarchicalCache(l1, l2, peers=[peer], inclusive=inclusive)
    peer.insert(Q1, "A1")
    l2.insert(QB, "CAKE")
    if batched:
        rs = h.lookup_batch([Q1, QB])
    else:
        rs = [h.lookup(Q1), h.lookup(QB)]
    assert "peer" in rs[0].level and rs[1].level.startswith("L2:")
    assert len(l1.store) == 2  # both winners promoted
    assert len(l2.store) == (2 if inclusive else 1)  # peer winner mirrored iff inclusive


def _vec_with_cos(rng, probe, cos, dim):
    r = rng.normal(size=dim).astype(np.float32)
    r -= (r @ probe) * probe
    r /= np.linalg.norm(r)
    return (cos * probe + np.sqrt(1.0 - cos * cos) * r).astype(np.float32)


def test_cross_level_pool_reports_best_score_first(emb):
    """The pooled candidate set is sorted best-first: the reported similarity
    is the strongest match, not whichever level was scanned first."""
    dim = emb.dim
    rng = np.random.default_rng(0)
    probe = rng.normal(size=dim).astype(np.float32)
    probe /= np.linalg.norm(probe)
    weak = _vec_with_cos(rng, probe, 0.5, dim)
    strong = _vec_with_cos(rng, probe, 0.7, dim)

    def build():
        l1, l2 = _gc(emb, t_combined=1.1), _gc(emb, t_combined=1.1)
        l1.insert("weak entry", "WEAK", vec=weak)
        l2.insert("strong entry", "STRONG", vec=strong)
        return HierarchicalCache(l1, l2)

    for r in (build().lookup("the probe", vec=probe),
              build().lookup_batch(["the probe"], vecs=probe[None])[0]):
        assert r.hit and r.generative
        assert r.similarity == pytest.approx(0.7, abs=1e-3)
        scores = [s for s, _ in r.sources]
        assert scores == sorted(scores, reverse=True)


def test_cross_level_pool_capped_at_l1_max_sources(emb):
    """N levels x k weak matches must not clear t_combined when no capped
    pool would: the pool is limited to L1's max_sources best candidates."""
    dim = emb.dim
    rng = np.random.default_rng(1)
    probe = rng.normal(size=dim).astype(np.float32)
    probe /= np.linalg.norm(probe)
    l1 = _gc(emb, t_combined=1.2, max_sources=2)
    l2, p0, p1 = (_gc(emb, t_combined=1.2) for _ in range(3))
    for i, cache in enumerate([l1, l2, p0, p1]):
        cache.insert(f"weak {i}", f"W{i}", vec=_vec_with_cos(rng, probe, 0.55, dim))
    h = HierarchicalCache(l1, l2, peers=[p0, p1])
    # uncapped: 4 x 0.55 = 2.2 > 1.2 would be a spurious hit; capped: 1.1 < 1.2
    assert not h.lookup("the probe", vec=probe).hit
    assert not h.lookup_batch(["the probe"], vecs=probe[None])[0].hit


class _CountingLLM(MockLLM):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.batch_calls = 0

    def generate_batch(self, prompts, max_tokens=256, temperature=0.0):
        self.batch_calls += 1
        return super().generate_batch(prompts, max_tokens, temperature)


def test_complete_batch_hierarchy_one_dispatch_and_privacy(emb):
    h = _fresh_hier(emb)
    client = EnhancedClient(hierarchy=h)
    backend = _CountingLLM("m1")
    client.register_backend(backend)
    novel = ["a brand new question about databases", "another novel question about compilers"]
    out = client.complete_batch([Q1] + novel, cache_l2=False)
    assert [r.from_cache for r in out] == [True, False, False]
    assert out[0].cache_result.level.startswith("L2:")
    assert backend.batch_calls == 1  # whole miss set in ONE batched dispatch
    assert len(h.l2.store) == 1  # privacy hint kept misses out of the shared level
    # promotion of Q1 + the two miss backfills all landed in L1
    assert len(h.l1.store) == 4
    out2 = client.complete_batch([Q1] + novel, cache_l2=False)
    assert all(r.from_cache for r in out2)
    assert backend.batch_calls == 1  # hits never reach the backend


def test_complete_batch_hierarchy_backfills_l2_by_default(emb):
    h = _fresh_hier(emb)
    client = EnhancedClient(hierarchy=h)
    client.register_backend(MockLLM("m1"))
    client.complete_batch(["a brand new question about databases"])
    assert len(h.l2.store) == 2  # seeded Q1 + the backfilled miss


def test_complete_batch_hierarchy_matches_sequential_query(emb):
    def build():
        c = EnhancedClient(hierarchy=_fresh_hier(NgramHashEmbedder()))
        c.register_backend(MockLLM("m1"))
        return c

    a, b = build(), build()
    ra = a.complete_batch(PROBES)
    rb = [b.query(q) for q in PROBES]
    assert [r.from_cache for r in ra] == [r.from_cache for r in rb]
    assert [r.text for r in ra] == [r.text for r in rb]
    assert a.stats.cache_hits == b.stats.cache_hits
    assert a.stats.llm_calls == b.stats.llm_calls


def test_batched_lookup_bumps_bookkeeping_only_on_probed_levels(emb):
    """Eviction hygiene: the batched path searches every level up front, but
    LRU/LFU counters must only move on levels the sequential walk would have
    probed — L1 serving a query leaves L2's recency/frequency untouched."""
    l1, l2 = _gc(emb), _gc(emb)
    l1.insert(Q1, "A1-l1")
    l2.insert(Q1, "A1-l2")
    h = HierarchicalCache(l1, l2)
    l2_counts = l2.store._access_count.copy()
    l2_recency = l2.store._last_access.copy()
    l1_counts = l1.store._access_count.copy()

    rs = h.lookup_batch([Q1])
    assert rs[0].hit and rs[0].level.startswith("L1")
    # L1 was probed: its counters moved; L2 was only searched speculatively
    assert np.any(l1.store._access_count != l1_counts)
    assert np.array_equal(l2.store._access_count, l2_counts)
    assert np.array_equal(l2.store._last_access, l2_recency)


def test_batched_lookup_bumps_all_levels_down_to_the_winner(emb):
    """A query L2 serves was preceded by an L1 probe: both levels bump."""
    l1, l2 = _gc(emb), _gc(emb)
    l1.insert(QB, "CAKE")  # unrelated: L1 misses Q1
    l2.insert(Q1, "A1-l2")
    h = HierarchicalCache(l1, l2, promote=False)
    l1_counts = l1.store._access_count.copy()
    l2_counts = l2.store._access_count.copy()

    rs = h.lookup_batch([Q1])
    assert rs[0].hit and rs[0].level.startswith("L2")
    assert np.any(l2.store._access_count != l2_counts)
    # L1's candidates (if any cleared the search) may bump; the L2 winner must
    assert l1.store._access_count.sum() >= l1_counts.sum()


def test_batched_lookup_bookkeeping_matches_sequential_walk(emb):
    """Same queries, same pre-state: the batched walk leaves each level's
    access counters exactly where B sequential lookups would. (Primary mode:
    a secondary-mode sequential miss probes twice — k=1 then the generative
    search — while the batched path reuses one candidate set, so exact bump
    parity only holds where the sequential walk searches once per level.)"""
    def build():
        l1, l2 = (_gc(emb, mode="primary", t_combined=0.9) for _ in range(2))
        l1.insert(QA, "ATT")
        l2.insert(Q1, "A1")
        return HierarchicalCache(l1, l2, generative_across_levels=False)

    queries = [QA, Q1]  # QA: L1 hit; Q1: L1 miss -> L2 hit
    seq = build()
    for q in queries:
        seq.lookup(q)
    bat = build()
    bat.lookup_batch(queries)
    np.testing.assert_array_equal(
        seq.l1.store._access_count, bat.l1.store._access_count
    )
    np.testing.assert_array_equal(
        seq.l2.store._access_count, bat.l2.store._access_count
    )
