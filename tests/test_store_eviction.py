"""InMemoryVectorStore slot management: all three eviction policies under
wraparound, O(1) remove via the key->slot map, and freed-slot reuse (a removed
slot must be recycled before any live entry is evicted)."""
import numpy as np
import pytest

from repro.core.vector_store import InMemoryVectorStore

DIM = 8


def unit(i: int) -> np.ndarray:
    v = np.zeros(DIM, np.float32)
    v[i] = 1.0
    return v


def keys_of(store, q, k=8):
    return [e.key for _, e in store.search(q, k=k)]


@pytest.fixture
def full3():
    def make(eviction):
        s = InMemoryVectorStore(DIM, capacity=3, eviction=eviction)
        ks = [s.add(unit(i), f"q{i}", f"a{i}") for i in range(3)]
        return s, ks

    return make


def test_lru_evicts_least_recently_accessed(full3):
    s, (k0, k1, k2) = full3("lru")
    s.search(unit(0), k=1)  # touch entry 0; entry 1 is now least recent
    k3 = s.add(unit(3), "q3", "a3")
    live = {e.key for e in s._entries if e is not None}
    assert live == {k0, k2, k3}


def test_lfu_evicts_least_frequently_accessed(full3):
    s, (k0, k1, k2) = full3("lfu")
    for _ in range(2):
        s.search(unit(0), k=1)
    s.search(unit(2), k=1)
    k3 = s.add(unit(3), "q3", "a3")  # entry 1 has count 0
    live = {e.key for e in s._entries if e is not None}
    assert live == {k0, k2, k3}


def test_fifo_ignores_recency(full3):
    s, (k0, k1, k2) = full3("fifo")
    s.search(unit(0), k=1)  # recency must not save entry 0 under FIFO
    k3 = s.add(unit(3), "q3", "a3")
    k4 = s.add(unit(4), "q4", "a4")
    live = {e.key for e in s._entries if e is not None}
    assert live == {k2, k3, k4}


@pytest.mark.parametrize("eviction", ["lru", "lfu", "fifo"])
def test_wraparound_keeps_capacity_and_serves_survivors(eviction):
    s = InMemoryVectorStore(DIM, capacity=3, eviction=eviction)
    keys = [s.add(unit(i % DIM), f"q{i}", f"a{i}") for i in range(7)]
    assert len(s) == 3
    # the most recent insert always survives its own add
    assert keys[-1] in {e.key for e in s._entries if e is not None}


@pytest.mark.parametrize("eviction", ["lru", "lfu", "fifo"])
def test_remove_frees_slot_for_reuse(eviction):
    s = InMemoryVectorStore(DIM, capacity=3, eviction=eviction)
    ka = s.add(unit(0), "a", "A")
    kb = s.add(unit(1), "b", "B")
    kc = s.add(unit(2), "c", "C")
    slot_b = s._key_to_slot[kb]
    assert s.remove(kb)
    assert len(s) == 2
    # the freed slot is recycled: no live entry is evicted by the next add
    kd = s.add(unit(3), "d", "D")
    assert s._key_to_slot[kd] == slot_b
    live = {e.key for e in s._entries if e is not None}
    assert live == {ka, kc, kd}
    assert s._tail == 3  # no extra slot consumed


def test_remove_unknown_and_double_remove():
    s = InMemoryVectorStore(DIM, capacity=3)
    k = s.add(unit(0), "a", "A")
    assert not s.remove(999)
    assert s.remove(k)
    assert not s.remove(k)
    assert len(s) == 0
    assert s.search(unit(0), k=2) == []


def test_multiple_removes_then_wraparound_evicts_live_last():
    s = InMemoryVectorStore(DIM, capacity=3, eviction="lru")
    ka = s.add(unit(0), "a", "A")
    kb = s.add(unit(1), "b", "B")
    kc = s.add(unit(2), "c", "C")
    s.remove(ka)
    s.remove(kc)
    kd = s.add(unit(3), "d", "D")
    ke = s.add(unit(4), "e", "E")
    assert len(s) == 3  # both freed slots reused, b survived
    kf = s.add(unit(5), "f", "F")  # now full: LRU evicts b (oldest access)
    live = {e.key for e in s._entries if e is not None}
    assert live == {kd, ke, kf}


def test_removed_entry_not_returned_by_search():
    s = InMemoryVectorStore(DIM, capacity=4)
    k0 = s.add(unit(0), "a", "A")
    s.add(unit(1), "b", "B")
    assert s.remove(k0)
    assert k0 not in keys_of(s, unit(0))


def test_persistence_roundtrip_preserves_free_slots(tmp_path):
    s = InMemoryVectorStore(DIM, capacity=3, eviction="lru")
    ka = s.add(unit(0), "a", "A")
    kb = s.add(unit(1), "b", "B")
    slot_a = s._key_to_slot[ka]
    s.remove(ka)
    s.save(str(tmp_path / "store"))
    s2 = InMemoryVectorStore.load(str(tmp_path / "store"))
    assert len(s2) == 1
    assert s2._key_to_slot == {kb: s._key_to_slot[kb]}
    # freed slot survives the roundtrip and is reused first
    kc = s2.add(unit(2), "c", "C")
    assert s2._key_to_slot[kc] == slot_a
    assert {e.key for e in s2._entries if e is not None} == {kb, kc}


def _assert_stores_identical(a, b):
    np.testing.assert_allclose(np.asarray(a._buf), np.asarray(b._buf), atol=0)
    assert np.array_equal(np.asarray(a._valid), np.asarray(b._valid))
    assert [(e.key, e.query, e.response) if e else None for e in a._entries] == \
           [(e.key, e.query, e.response) if e else None for e in b._entries]
    assert a._key_to_slot == b._key_to_slot
    assert a.size == b.size and a._tail == b._tail and a._next_key == b._next_key


@pytest.mark.parametrize("eviction", ["lru", "lfu", "fifo"])
def test_add_batch_matches_sequential_adds_under_wraparound(eviction):
    """One multi-row scatter must leave the store entry-for-entry identical to
    N sequential adds — including policy eviction once the batch wraps."""
    a = InMemoryVectorStore(DIM, capacity=4, eviction=eviction)
    b = InMemoryVectorStore(DIM, capacity=4, eviction=eviction)
    rows = np.stack([unit(i % DIM) for i in range(11)])
    qs = [f"q{i}" for i in range(11)]
    rs = [f"a{i}" for i in range(11)]
    keys_a = [a.add(v, q, r) for v, q, r in zip(rows, qs, rs)]
    keys_b = b.add_batch(rows, qs, rs)
    assert keys_a == keys_b
    _assert_stores_identical(a, b)


@pytest.mark.parametrize("eviction", ["lru", "lfu", "fifo"])
def test_add_batch_reuses_freed_slots_before_evicting(eviction):
    a = InMemoryVectorStore(DIM, capacity=3, eviction=eviction)
    b = InMemoryVectorStore(DIM, capacity=3, eviction=eviction)
    for s in (a, b):
        k0 = s.add(unit(0), "a", "A")
        s.add(unit(1), "b", "B")
        s.add(unit(2), "c", "C")
        s.remove(k0)
    rows = np.stack([unit(3), unit(4)])
    keys_a = [a.add(v, q, r) for v, q, r in zip(rows, ["d", "e"], ["D", "E"])]
    keys_b = b.add_batch(rows, ["d", "e"], ["D", "E"])
    assert keys_a == keys_b
    _assert_stores_identical(a, b)
    assert b._tail == 3  # freed slot recycled, no extra slot consumed


def test_add_batch_empty_and_single():
    s = InMemoryVectorStore(DIM, capacity=4)
    assert s.add_batch(np.zeros((0, DIM), np.float32), [], []) == []
    assert len(s) == 0
    (k,) = s.add_batch(unit(1)[None], ["q"], ["a"], metas=[{"m": 1}])
    assert s._entries[s._key_to_slot[k]].meta == {"m": 1}
    assert keys_of(s, unit(1)) == [k]


def test_add_batch_then_search_serves_new_entries():
    s = InMemoryVectorStore(DIM, capacity=8)
    s.add_batch(np.stack([unit(0), unit(1)]), ["a", "b"], ["A", "B"])
    assert [e.response for _, e in s.search(unit(1), k=1)] == ["B"]


def test_search_batch_updates_recency_like_search():
    s = InMemoryVectorStore(DIM, capacity=3, eviction="lru")
    k0 = s.add(unit(0), "a", "A")
    k1 = s.add(unit(1), "b", "B")
    k2 = s.add(unit(2), "c", "C")
    s.search_batch(np.stack([unit(0), unit(2)]), k=1)  # batched touch of 0 and 2
    k3 = s.add(unit(3), "d", "D")  # must evict entry 1
    live = {e.key for e in s._entries if e is not None}
    assert live == {k0, k2, k3}


# -- ShardedVectorStore: key->slot map + freed-slot reuse (ported remove path) --


def _sharded(capacity=8, k=3, dim=DIM):
    jax = pytest.importorskip("jax")
    from repro.distributed.sharded_store import ShardedVectorStore
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    return ShardedVectorStore(mesh, dim=dim, capacity=capacity, k=k)


def test_sharded_remove_frees_slot_for_reuse():
    s = _sharded()
    keys = [s.add(unit(i), f"q{i}", f"a{i}") for i in range(3)]
    assert len(s) == 3
    victim_slot = s._key_to_slot[keys[1]]
    assert s.remove(keys[1])
    assert len(s) == 2
    assert s.payloads[victim_slot] is None
    # removed entry is no longer served
    rows = s.search_batch(unit(1)[None])[0]
    assert all(p != ("q1", "a1") for _, p in rows)
    # the freed slot is recycled before the round-robin cursor advances
    k_new = s.add(unit(5), "q5", "a5")
    assert s._key_to_slot[k_new] == victim_slot
    assert len(s) == 3
    top = s.search_batch(unit(5)[None])[0]
    assert top and top[0][1] == ("q5", "a5")


def test_sharded_remove_unknown_and_double():
    s = _sharded()
    k0 = s.add(unit(0), "q0", "a0")
    assert not s.remove(9999)
    assert s.remove(k0)
    assert not s.remove(k0)  # idempotent
    assert len(s) == 0


def test_sharded_add_batch_reuses_freed_slots():
    s = _sharded(capacity=8)
    keys = [s.add(unit(i), f"q{i}", f"a{i}") for i in range(4)]
    freed = [s._key_to_slot[keys[1]], s._key_to_slot[keys[2]]]
    s.remove(keys[1])
    s.remove(keys[2])
    new_keys = s.add_batch(
        np.stack([unit(5), unit(6)]), ["q5", "q6"], ["a5", "a6"]
    )
    # both freed slots were recycled (LIFO pop order) before cursor growth
    assert sorted(s._key_to_slot[k] for k in new_keys) == sorted(freed)
    assert len(s) == 4


def test_sharded_wraparound_retires_overwritten_keys():
    s = _sharded(capacity=4)
    keys = [s.add(unit(i % DIM), f"q{i}", f"a{i}") for i in range(6)]  # wraps
    assert len(s) == 4
    # the two overwritten entries' keys are gone from the map
    assert keys[0] not in s._key_to_slot and keys[1] not in s._key_to_slot
    assert all(k in s._key_to_slot for k in keys[2:])
    # removing a retired key is a no-op
    assert not s.remove(keys[0])
