"""Entry lifecycle + capacity tiers: TTL/expiry masks beat thresholds on
every read path (host, fused, sharded), eviction demotes into the host-RAM
tier and tier-1 hits promote back byte-identical, snapshots warm-start new
deployments, clear(older_than) prunes all three tiers, freed slots carry no
stale metadata, and the int32 insertion clock rebases before overflow."""
import os
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import NgramHashEmbedder, SemanticCache  # noqa: E402
from repro.core.store_bank import _TICK_COMPACT_AT  # noqa: E402
from repro.core.tiers import HostRamTier, SnapshotTier, TierEntry  # noqa: E402
from repro.core.vector_store import InMemoryVectorStore  # noqa: E402

DIM = 16


def unit(i: int, dim: int = DIM) -> np.ndarray:
    v = np.zeros(dim, np.float32)
    v[i % dim] = 1.0
    return v


def rand_units(n: int, dim: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(n, dim)).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


# -- TTL / expiry on the read paths -------------------------------------------


def test_expired_entry_never_served_host_path():
    s = InMemoryVectorStore(DIM, capacity=4)
    s.add(unit(0), "qa", "ra", ttl_s=0.05)
    kb = s.add(unit(1), "qb", "rb")
    time.sleep(0.1)
    got = s.search(unit(0), k=2)
    # the exact match is expired: it may not appear at ANY rank
    assert [e.key for _, e in got] == [kb]
    # entry object still present until pruned, but marked expired
    assert s._entries[s._key_to_slot[0]].expired()


def test_expiry_mask_beats_threshold_fused_decide():
    """Fused read program: an expired row cannot win even when its raw
    similarity clears the threshold — and the hot path stays ONE dispatch
    with ZERO host hops."""
    emb = NgramHashEmbedder()
    cache = SemanticCache(emb, threshold=0.5, capacity=8)
    # warm-up: compile the lifecycle program + scatter jits OUTSIDE the TTL
    # window (first-call compilation costs far more than a short TTL)
    cache.insert("warmup entry", "warm", ttl_s=3600.0)
    cache.lookup_batch(["warmup entry"])
    cache.insert("the quick brown fox", "stale answer", ttl_s=0.6)
    cache.insert("completely different topic entirely", "live answer")
    r = cache.lookup_batch(["the quick brown fox"])[0]
    assert r.hit and r.response == "stale answer"  # alive: raw score wins
    time.sleep(0.8)
    bank = cache.store._bank
    d0, h0 = bank.dispatches, bank.host_hops
    r = cache.lookup_batch(["the quick brown fox"])[0]
    assert not r.hit  # raw cosine is 1.0 > threshold, but the row is dead
    assert bank.dispatches == d0 + 1  # still one fused dispatch per batch
    assert bank.host_hops == h0  # and still zero host hops on the hot path


def test_staleness_penalty_raises_effective_bar():
    """An aging entry loses staleness_weight * clip(age/ttl, 0, 1): fresh it
    hits, near end-of-life the same raw score no longer clears t_s."""
    emb = NgramHashEmbedder()
    store = InMemoryVectorStore(emb.dim, capacity=8, staleness_weight=0.5)
    cache = SemanticCache(emb, threshold=0.8, store=store)
    cache.insert("warmup entry", "warm")  # compile the lifecycle program
    cache.lookup_batch(["warmup entry"])
    cache.insert("how do rockets work", "rocket answer", ttl_s=2.0)
    r = cache.lookup_batch(["how do rockets work"])[0]
    assert r.hit  # age ~0: effective score ~= raw ~= 1.0
    time.sleep(1.0)
    r = cache.lookup_batch(["how do rockets work"])[0]
    # age/ttl ~= 0.5 -> effective ~= 1.0 - 0.25 = 0.75 < 0.8
    assert not r.hit
    # host search path applies the same penalty
    sc = store.search_batch(emb.embed_one("how do rockets work")[None], k=1)[0]
    assert sc and sc[0][0] < 0.8


def test_expired_slot_reclaimed_before_live_eviction():
    s = InMemoryVectorStore(DIM, capacity=3, eviction="lru")
    ka = s.add(unit(0), "qa", "ra", ttl_s=0.05)
    kb = s.add(unit(1), "qb", "rb")
    kc = s.add(unit(2), "qc", "rc")
    time.sleep(0.1)
    kd = s.add(unit(3), "qd", "rd")  # must reclaim the dead slot, not evict
    live = {e.key for e in s._entries if e is not None}
    assert live == {kb, kc, kd}


# -- demotion / promotion ------------------------------------------------------


def test_demote_promote_roundtrip_preserves_keys_vectors_counters():
    tier = HostRamTier(DIM, capacity=16)
    s = InMemoryVectorStore(DIM, capacity=2, eviction="lru", tier1=tier)
    vecs = rand_units(4, DIM)
    ka = s.add(vecs[0], "qa", "ra")
    s.add(vecs[1], "qb", "rb")
    for _ in range(3):
        s.search(vecs[0], k=1)  # access_count(a) = 3
    count_a = int(s._access_count[s._key_to_slot[ka]])
    assert count_a == 3
    s.add(vecs[2], "qc", "rc")
    s.add(vecs[3], "qd", "rd")  # a and b demoted
    assert ka not in s._key_to_slot and len(tier) == 2
    sc, slots = tier.search(vecs[0], k=1)
    assert sc[0, 0] == pytest.approx(1.0, abs=1e-5)
    e, vec = tier.pop(int(slots[0, 0]))
    assert (e.key, e.query, e.response, e.access_count) == (ka, "qa", "ra", 3)
    np.testing.assert_allclose(vec, vecs[0], atol=1e-6)
    s._restore_batch(vec[None], [e])
    # identity fully restored: key, vector, response, AND the access count
    idx = s._key_to_slot[ka]
    assert s._entries[idx].response == "ra"
    assert int(s._access_count[idx]) == 3
    score, entry = s.search(vecs[0], k=1)[0]
    assert score == pytest.approx(1.0, abs=1e-5) and entry.key == ka


def test_tier1_hit_promotes_through_cache_lookup():
    emb = NgramHashEmbedder()
    tier = HostRamTier(emb.dim, capacity=32)
    store = InMemoryVectorStore(emb.dim, capacity=2, tier1=tier)
    cache = SemanticCache(emb, threshold=0.85, store=store)
    cache.insert("oldest question", "oldest answer")
    cache.insert("middle question", "middle answer")
    cache.insert("newest question", "newest answer")  # demotes oldest
    assert len(tier) == 1
    r = cache.lookup("oldest question")
    assert r.hit and r.level == "tier1"
    assert r.response == "oldest answer"
    assert cache.stats.tier1_hits == 1
    # promoted out of the ring; the evicted tier-0 victim demoted into it
    assert {e.response for e, _ in tier.snapshot_entries()} != {"oldest answer"}
    r2 = cache.lookup("oldest question")  # now a plain tier-0 hit
    assert r2.hit and r2.level == "semantic"


def test_working_set_4x_device_capacity_stays_servable():
    """The acceptance bar: a working set 4x the device bank keeps serving —
    evicted entries answer from tier 1, promoted hits are byte-identical to
    their pre-demotion responses, expired entries never appear."""
    emb = NgramHashEmbedder()
    cap = 16
    tier = HostRamTier(emb.dim, capacity=8 * cap)
    store = InMemoryVectorStore(emb.dim, capacity=cap, tier1=tier)
    cache = SemanticCache(emb, threshold=0.85, store=store)
    n = 4 * cap
    queries = [f"question number {i} about subject {i * 7 + 1}" for i in range(n)]
    responses = [f"answer payload {i}" for i in range(n)]
    cache.insert_batch(queries, responses)
    assert len(store) == cap and len(tier) == n - cap
    rng = np.random.default_rng(1)
    order = rng.permutation(n)
    served = {}
    for start in range(0, n, 16):
        chunk = [int(i) for i in order[start:start + 16]]
        rs = cache.lookup_batch([queries[i] for i in chunk])
        for i, r in zip(chunk, rs):
            assert r.hit, f"query {i} unservable with 4x working set"
            served[i] = r.response
    assert served == {i: responses[i] for i in range(n)}  # byte-identical
    assert cache.stats.tier1_hits > 0  # some answers really came from tier 1


# -- tier 2: snapshot export / import ------------------------------------------


def test_snapshot_export_import_warm_start_parity(tmp_path):
    tier = HostRamTier(DIM, capacity=16)
    s = InMemoryVectorStore(DIM, capacity=2, tier1=tier)
    vecs = rand_units(4, DIM, seed=3)
    for i in range(4):  # 2 land in tier 0, 2 demote to tier 1
        s.add(vecs[i], f"q{i}", f"r{i}")
    s.search(vecs[3], k=1)  # access_count(3) = 1
    snap = SnapshotTier(str(tmp_path / "snap"))
    assert snap.export_from(s) == 4
    assert snap.count() == 4
    fresh = InMemoryVectorStore(DIM, capacity=2, tier1=HostRamTier(DIM, 16))
    assert snap.import_into(fresh) == 4
    # newest entries stayed in tier 0; access counts rode along
    t0_responses = {e.response for e in fresh._entries if e is not None}
    assert t0_responses == {"r2", "r3"}
    idx3 = next(i for i, e in enumerate(fresh._entries)
                if e is not None and e.response == "r3")
    assert int(fresh._access_count[idx3]) == 1
    # every entry is servable in the warm-started store, same responses
    for i in range(4):
        sc, slots = fresh.tier1.search(vecs[i], k=1)
        if float(sc[0, 0]) > 0.99:
            e = fresh.tier1.get(int(slots[0, 0]))
            assert e.response == f"r{i}"
        else:
            score, entry = fresh.search(vecs[i], k=1)[0]
            assert score == pytest.approx(1.0, abs=1e-5)
            assert entry.response == f"r{i}"


def test_snapshot_skips_expired_entries(tmp_path):
    s = InMemoryVectorStore(DIM, capacity=4)
    s.add(unit(0), "dead", "dead answer", ttl_s=0.05)
    s.add(unit(1), "live", "live answer")
    time.sleep(0.1)
    snap = SnapshotTier(str(tmp_path / "snap"))
    assert snap.export_from(s) == 1
    fresh = InMemoryVectorStore(DIM, capacity=4)
    assert snap.import_into(fresh) == 1
    assert [e.response for e in fresh._entries if e is not None] == ["live answer"]


# -- clear(older_than) across all three tiers ----------------------------------


def test_clear_older_than_prunes_all_three_tiers(tmp_path):
    tier = HostRamTier(DIM, capacity=16)
    s = InMemoryVectorStore(DIM, capacity=2, tier1=tier)
    vecs = rand_units(5, DIM, seed=5)
    for i in range(3):  # q0 demotes to tier 1
        s.add(vecs[i], f"old{i}", f"r{i}")
    # backdate the old generation (created stamps are host-side truth)
    cutoff_age = 100.0
    for e in s._entries:
        if e is not None:
            e.created_at -= 200.0
    for te, _ in list(tier.snapshot_entries()):
        te.created_at -= 200.0
    snap = SnapshotTier(str(tmp_path / "snap"))
    snap.export_from(s)
    s.add(vecs[3], "new3", "r3")  # old1 demotes but keeps its backdate? no:
    # (old1 was re-stamped above while in tier 0, so its demoted copy is old)
    s.add(vecs[4], "new4", "r4")
    dropped = s.clear(older_than=cutoff_age)
    live_t0 = {e.query for e in s._entries if e is not None}
    assert live_t0 == {"new3", "new4"}
    assert dropped >= 1
    # tier 1 pruned through the cascade: only fresh demotions may remain
    for te, _ in tier.snapshot_entries():
        assert time.time() - te.created_at <= cutoff_age
    # tier 2 clears its files
    assert snap.count() == 3
    assert snap.clear() == 3
    assert snap.count() == 0
    assert not os.path.exists(os.path.join(snap.path, "snapshot.npz"))


def test_clear_all_and_expired_always_qualify():
    s = InMemoryVectorStore(DIM, capacity=4)
    s.add(unit(0), "a", "ra", ttl_s=0.05)
    s.add(unit(1), "b", "rb")
    time.sleep(0.1)
    # huge cutoff: nothing is "old", but the expired entry still goes
    assert s.clear(older_than=1e9) == 1
    assert len(s) == 1
    assert s.clear() == 1  # no cutoff: everything
    assert len(s) == 0


# -- persistence with lifecycle state ------------------------------------------


def test_save_load_mixed_live_expired(tmp_path):
    s = InMemoryVectorStore(DIM, capacity=4, default_ttl_s=None)
    s.add(unit(0), "dead", "dead answer", ttl_s=0.05)
    kb = s.add(unit(1), "live", "live answer", ttl_s=3600.0)
    s.add(unit(2), "immortal", "forever answer")
    time.sleep(0.1)
    s.save(str(tmp_path / "store"))
    s2 = InMemoryVectorStore.load(str(tmp_path / "store"))
    assert len(s2) == 3  # all rows reload...
    got = s2.search(unit(0), k=3)
    assert all(e.query != "dead" for _, e in got)  # ...but dead stays dead
    score, e = s2.search(unit(1), k=1)[0]
    assert e.key == kb and e.response == "live answer"
    assert np.isfinite(e.expires_at) and e.expires_at > time.time()
    _, e = s2.search(unit(2), k=1)[0]
    assert e.expires_at == float("inf")
    assert s2.clear(older_than=1e9) == 1  # the expired row prunes on demand


def test_save_load_preserves_ttl_knobs(tmp_path):
    s = InMemoryVectorStore(DIM, capacity=4, default_ttl_s=60.0, staleness_weight=0.25)
    s.add(unit(0), "q", "r")
    s.save(str(tmp_path / "store"))
    s2 = InMemoryVectorStore.load(str(tmp_path / "store"))
    assert s2.default_ttl_s == 60.0
    assert s2.staleness_weight == 0.25
    assert s2._bank.lifecycle_active()


# -- freed-slot metadata hygiene (satellite bugfix) ----------------------------


def test_freed_slot_reinsert_matches_fresh_insert_inmemory():
    """remove() + slot-reusing insert must leave NO stale recency/frequency/
    TTL metadata: the recycled slot's counters match a fresh-slot insert."""
    s = InMemoryVectorStore(DIM, capacity=4, eviction="lfu")
    s.add(unit(0), "a", "ra")
    kb = s.add(unit(1), "b", "rb", ttl_s=5.0)
    for _ in range(4):
        s.search(unit(1), k=1)  # b: access_count 4, finite expiry
    idx_b = s._key_to_slot[kb]
    assert s.remove(kb)
    bank = s._bank
    # freed: the whole metadata row is reset
    assert int(s._access_count[idx_b]) == 0
    assert int(s._last_access[idx_b]) == 0
    assert int(s._insert_seq[idx_b]) == 0
    assert bank.h_expires[0, idx_b] == np.inf
    kd = s.add(unit(2), "d", "rd")  # reuses b's slot
    assert s._key_to_slot[kd] == idx_b
    kf = s.add(unit(3), "f", "rf")  # fresh slot, same moment
    idx_f = s._key_to_slot[kf]
    # parity: recycled slot is indistinguishable from the fresh one
    assert int(s._access_count[idx_b]) == int(s._access_count[idx_f]) == 0
    assert bank.h_expires[0, idx_b] == bank.h_expires[0, idx_f] == np.inf
    # no TTL inherited: d outlives b's would-be expiry window
    assert not s._entries[idx_b].expired(now=time.time() + 3600)


def test_freed_slot_reinsert_matches_fresh_insert_sharded():
    from repro.distributed.sharded_store import ShardedVectorStore
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    s = ShardedVectorStore(mesh, dim=DIM, capacity=4, k=2, eviction="lfu")
    s.add(unit(0), "a", "ra")
    kb = s.add(unit(1), "b", "rb", ttl_s=5.0)
    for _ in range(4):
        s.search_batch(unit(1)[None], k=1)
    idx_b = s._key_to_slot[kb]
    assert s.remove(kb)
    bank = s.bank
    lane, within = divmod(idx_b, s.cap_local)
    last, cnt, seq = bank.counters_host()
    assert int(cnt[lane, within]) == 0
    assert int(last[lane, within]) == 0
    assert int(seq[lane, within]) == 0
    assert bank.h_expires[lane, within] == np.inf
    kd = s.add(unit(2), "d", "rd")  # reuses the freed slot
    assert s._key_to_slot[kd] == idx_b
    _, cnt, _ = bank.counters_host()
    assert int(cnt[lane, within]) == 0  # no inherited frequency
    assert bank.h_expires[lane, within] == np.inf  # no inherited TTL
    # the recycled entry is served (valid mask really flipped back on)
    got = s.search_batch(unit(2)[None], k=1)[0]
    assert got and got[0][1][0] == "d"


# -- int32 insertion-clock overflow (satellite bugfix) -------------------------


def test_insert_seq_rebases_before_int32_overflow():
    """FIFO victim ordering survives the insertion clock running into the
    int32 ceiling: the claim path rank-rebases instead of wrapping."""
    s = InMemoryVectorStore(DIM, capacity=3, eviction="fifo")
    ka = s.add(unit(0), "a", "ra")
    kb = s.add(unit(1), "b", "rb")
    s._seq = _TICK_COMPACT_AT  # fast-forward ~2B inserts
    kc = s.add(unit(2), "c", "rc")  # triggers compact_seqs in the claim path
    assert s._seq < _TICK_COMPACT_AT  # clock restarted near zero
    kd = s.add(unit(3), "d", "rd")  # full: fifo must evict a (oldest)
    live = {e.key for e in s._entries if e is not None}
    assert live == {kb, kc, kd}
    ke = s.add(unit(4), "e", "re")  # then b
    live = {e.key for e in s._entries if e is not None}
    assert live == {kc, kd, ke}


def test_sharded_insert_seq_rebases_before_int32_overflow():
    from repro.distributed.sharded_store import ShardedVectorStore
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    s = ShardedVectorStore(mesh, dim=DIM, capacity=3, k=2, eviction="fifo")
    s.add(unit(0), "a", "ra")
    s.add(unit(1), "b", "rb")
    s._seq = _TICK_COMPACT_AT
    s.add(unit(2), "c", "rc")
    assert s._seq < _TICK_COMPACT_AT
    s.add(unit(3), "d", "rd")  # fifo evicts a
    live = {p[0] for p in s.payloads if p is not None}
    assert live == {"b", "c", "d"}


# -- sharded store TTL ---------------------------------------------------------


def test_sharded_ttl_expiry_and_clear():
    from repro.distributed.sharded_store import ShardedVectorStore
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape=(len(jax.devices()),), axes=("data",))
    s = ShardedVectorStore(mesh, dim=DIM, capacity=4, k=2)
    s.add(unit(0), "dead", "dead answer", ttl_s=0.05)
    s.add(unit(1), "live", "live answer")
    time.sleep(0.1)
    got = s.search_batch(unit(0)[None], k=2)[0]
    assert all(p[0] != "dead" for _, p in got)  # expired never served
    assert s.clear(older_than=1e9) == 1  # expired always qualifies
    got = s.search_batch(unit(1)[None], k=1)[0]
    assert got and got[0][1][0] == "live"


# -- hierarchy + service integration -------------------------------------------


def test_hierarchy_consults_level_tiers_on_miss():
    from repro.core import GenerativeCache, HierarchicalCache

    emb = NgramHashEmbedder()
    l1_store = InMemoryVectorStore(emb.dim, capacity=2,
                                   tier1=HostRamTier(emb.dim, 32))
    l2_store = InMemoryVectorStore(emb.dim, capacity=2,
                                   tier1=HostRamTier(emb.dim, 32))
    l1 = GenerativeCache(emb, threshold=0.85, t_single=0.45, t_combined=1.0,
                         store=l1_store)
    l2 = GenerativeCache(emb, threshold=0.85, t_single=0.45, t_combined=1.0,
                         store=l2_store)
    h = HierarchicalCache(l1, l2)
    for i in range(3):  # overflow L2 so its first entry demotes to its tier
        l2.insert(f"shared question {i} topic {i * 3}", f"shared answer {i}")
    assert len(l2_store.tier1) == 1
    rs = h.lookup_batch(["shared question 0 topic 0"])
    assert rs[0].hit
    assert rs[0].level == "L2:tier1"
    assert rs[0].response == "shared answer 0"
    # promoted into L1 like any lower-level winner
    r2 = h.lookup_batch(["shared question 0 topic 0"])
    assert r2[0].hit and r2[0].level.startswith("L1:")


def test_service_ttl_backfill_and_clear():
    from repro.core import CacheRequest, EnhancedClient, GenerativeCache, MockLLM
    from repro.core.request import GENERATED, HIT
    from repro.serving.service import CacheService

    emb = NgramHashEmbedder()
    cache = GenerativeCache(emb, threshold=0.85, t_single=0.45, t_combined=1.0)
    client = EnhancedClient(cache=cache)
    client.register_backend(MockLLM("backend"))
    svc = CacheService(client)
    r1 = svc.complete([CacheRequest("what is a cache", ttl_s=0.2)])[0]
    assert r1.status == GENERATED
    r2 = svc.complete([CacheRequest("what is a cache")])[0]
    assert r2.status == HIT  # backfilled answer serves while alive
    time.sleep(0.3)
    r3 = svc.complete([CacheRequest("what is a cache")])[0]
    assert r3.status == GENERATED  # TTL carried through backfill: it expired
    n = len(cache.store)
    assert svc.clear() == n  # prune API surfaced on the service
    assert len(cache.store) == 0
